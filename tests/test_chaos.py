"""Chaos suite: every injected fault runs end-to-end on CPU and the
system must recover, deterministically.

Training faults (repro/testing/faults.py -> train(hooks=...)): hard crash
mid-run + auto-resume, SIGTERM preemption -> checkpoint-and-exit, corrupt
checkpoint on disk -> resume falls back to the previous good step, NaN
state poisoning -> in-jit guard + rollback + data-window skip, finite loss
spike -> EWMA detector + rollback, recovery-budget exhaustion ->
TrainingDiverged.

Serving faults (ServeEngine.fault_hook): NaN-poisoned decode chunk /
admission prefill -> slot quarantine + re-queue with the surviving slots'
streams bit-identical to an undisturbed run, retry-budget exhaustion ->
finish_reason='error', deadlines + bounded queue -> typed
'timeout'/'rejected' responses (never exceptions), stalled dispatch ->
stall-watchdog events.

Gated behind the ``chaos`` marker (conftest): run with ``REPRO_CHAOS=1``
or ``-m chaos`` — the tier-1 pass skips these.
"""
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.config import TrainConfig, get_config
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request
from repro.testing import faults
from repro.train.guard import TrainingDiverged
from repro.train.loop import train

pytestmark = pytest.mark.chaos


# ==========================================================================
# Training chaos
# ==========================================================================
def _cfg():
    return get_config("llama-60m").smoke()


def _tc(tmp_path, **over):
    kw = dict(steps=8, global_batch=2, seq_len=32, log_every=0,
              checkpoint_dir=str(tmp_path / "ckpt"), checkpoint_every=2,
              async_checkpoint=False)
    kw.update(over)
    return TrainConfig(**kw)


def test_crash_at_step_and_auto_resume(tmp_path):
    """Hard crash at step 5 (with a straggler delay riding along): the
    next invocation auto-resumes from the last checkpoint and completes."""
    tc = _tc(tmp_path)
    hooks = faults.train_hooks(faults.DelayAt(2, 0.02), faults.CrashAt(5))
    with pytest.raises(faults.SimulatedCrash):
        train(_cfg(), tc, hooks=hooks)
    mgr = CheckpointManager(tc.checkpoint_dir)
    assert mgr.latest_good_step() == 4       # checkpoints at 2, 4 survived
    out = train(_cfg(), tc)                  # auto-resume
    assert out["final_step"] == 8
    assert np.isfinite(out["ce_loss"])


def test_corrupt_checkpoint_resume_falls_back(tmp_path):
    """Bit-rot in the newest checkpoint: resume must restore the previous
    good one, not wedge or serve garbage."""
    tc = _tc(tmp_path, steps=4)
    train(_cfg(), tc)
    mgr = CheckpointManager(tc.checkpoint_dir)
    assert mgr.latest_step() == 4
    faults.corrupt_checkpoint(tc.checkpoint_dir, 4)
    assert mgr.latest_good_step() == 2       # corrupt newest is skipped
    out = train(_cfg(), _tc(tmp_path, steps=6))  # resumes from step 2
    assert out["final_step"] == 6
    assert np.isfinite(out["ce_loss"])


def test_nan_poisoned_state_rolls_back_and_completes(tmp_path):
    """NaN poisoning before step 5: the in-jit guard refuses the update,
    the recovery policy rolls back to step 4 and advances the data offset
    past the poisoned window, and the run completes with the whole
    incident on the ledger."""
    tc = _tc(tmp_path, steps=10)
    out = train(_cfg(), tc,
                hooks=faults.train_hooks(faults.PoisonStateAt(5)))
    assert out["final_step"] == 10
    assert np.isfinite(out["ce_loss"])
    assert out["recoveries"] >= 1
    assert out["counters"]["nonfinite_steps"] >= 1
    rollbacks = [e for e in out["events"] if e["kind"] == "rollback"]
    assert rollbacks and rollbacks[0]["restored_step"] == 4
    assert rollbacks[0]["data_offset"] >= 2  # skipped the bad window


def test_loss_spike_rolls_back_and_completes(tmp_path):
    """A finite divergence (params scaled 30x) slips past the NaN guard;
    the EWMA spike detector catches it and drives the same rollback."""
    tc = _tc(tmp_path, steps=10, loss_spike_threshold=2.0,
             spike_warmup_steps=2)
    out = train(_cfg(), tc,
                hooks=faults.train_hooks(faults.ScaleStateAt(5, factor=30.0)))
    assert out["final_step"] == 10
    assert np.isfinite(out["ce_loss"])
    assert out["recoveries"] >= 1
    # the spike either stays finite (EWMA catches it) or overflows to
    # inf (the guard catches it) — both must land on the ledger
    assert (out["counters"]["loss_spikes"] +
            out["counters"]["nonfinite_steps"]) >= 1
    assert any(e["kind"] == "rollback" for e in out["events"])


def test_recovery_budget_exhaustion_raises(tmp_path):
    """Persistent NaN with no checkpoint to roll back to: bounded retries,
    then a hard TrainingDiverged — never a silent infinite loop."""
    tc = TrainConfig(steps=8, global_batch=2, seq_len=32, log_every=0,
                     max_recoveries=2, recovery_backoff_s=0.01)
    with pytest.raises(TrainingDiverged, match="max_recoveries"):
        train(_cfg(), tc, hooks=faults.train_hooks(faults.PoisonStateAt(3)))


def test_sigterm_checkpoints_and_resumes(tmp_path):
    """SIGTERM mid-step = preemption notice: the loop finishes the step,
    checkpoints, and exits cleanly; the next invocation resumes."""
    tc = _tc(tmp_path, steps=10)
    out = train(_cfg(), tc, hooks=faults.train_hooks(faults.SigtermAt(3)))
    assert out["final_step"] == 4            # stopped right after step 3
    mgr = CheckpointManager(tc.checkpoint_dir)
    assert mgr.latest_good_step() == 4       # preemption checkpoint landed
    out = train(_cfg(), tc)
    assert out["final_step"] == 10
    assert np.isfinite(out["ce_loss"])


# ==========================================================================
# Serving chaos
# ==========================================================================
def _serve_cfg():
    # f32 keeps greedy argmax robust to path-dependent rounding, so the
    # bit-identical-streams assertions are meaningful
    return get_config("qwen2-1.5b").smoke().with_overrides(dtype="float32")


@pytest.fixture(scope="module")
def engine():
    return make_engine(_serve_cfg(), max_batch=2, max_seq=64,
                       decode_block=4)


@pytest.fixture(autouse=True)
def _clean_engine(request):
    yield
    if "engine" in request.fixturenames:
        eng = request.getfixturevalue("engine")
        eng.fault_hook = None
        eng.stall_timeout_s = None
        eng.max_queue = None
        eng.reset_stats()


def _reqs(rng, n, max_new=10):
    return [Request(uid=i, prompt=rng.randint(1, 512, (5 + i,))
                    .astype(np.int32), max_new_tokens=max_new)
            for i in range(n)]


def test_poisoned_decode_chunk_quarantined_others_bit_identical(engine,
                                                                rng):
    """NaN logits in one slot mid-chunk: that slot is quarantined and its
    request re-queued from scratch; every request — including the
    poisoned one after its retry — still emits the exact undisturbed
    greedy stream, and the incident is fully counted."""
    reqs = _reqs(rng, 3)
    baseline = {r.uid: r.tokens.copy() for r in engine.serve(reqs)}
    engine.reset_stats()
    engine.fault_hook = faults.ServeFaults(
        max_batch=2, poison_decode={1: [0]})  # slot 0, second decode chunk
    resps = engine.serve(reqs)
    stats = engine.stats()
    assert stats["quarantines"] >= 1 and stats["requeues"] >= 1
    assert stats["nonfinite_chunks"] >= 1
    assert any(e["kind"] == "quarantine" for e in engine.events)
    for r in resps:
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(r.tokens, baseline[r.uid])


def test_poisoned_prefill_quarantined_and_retried(engine, rng):
    reqs = _reqs(rng, 2, max_new=6)
    baseline = {r.uid: r.tokens.copy() for r in engine.serve(reqs)}
    engine.reset_stats()
    engine.fault_hook = faults.ServeFaults(
        max_batch=2, poison_prefill={0: [1]})  # first admission, slot 1
    resps = engine.serve(reqs)
    assert engine.stats()["quarantines"] >= 1
    for r in resps:
        assert r.finish_reason == "length"
        np.testing.assert_array_equal(r.tokens, baseline[r.uid])


def test_persistent_poison_exhausts_retries_to_error(rng):
    """A slot that NaNs on every attempt burns its retry budget and
    finishes 'error' — a typed response, not a hang or an exception."""
    eng = make_engine(_serve_cfg(), max_batch=1, max_seq=64,
                      decode_block=4)
    eng.fault_hook = faults.ServeFaults(
        max_batch=1, poison_decode={i: [0] for i in range(16)})
    resps = eng.serve([Request(uid=0, prompt=rng.randint(1, 512, (6,))
                               .astype(np.int32), max_new_tokens=10)])
    assert resps[0].finish_reason == "error"
    stats = eng.stats()
    assert stats["errors"] == 1
    assert stats["quarantines"] == eng.max_slot_retries + 1


def test_deadline_and_queue_bound_give_typed_responses(engine, rng):
    """Overflow beyond slots+max_queue is rejected at submit; an expired
    deadline finishes 'timeout' with whatever tokens it has. Both are
    typed responses with counters — never exceptions."""
    engine.max_queue = 0                     # capacity = 2 slots + 0
    reqs = _reqs(rng, 4, max_new=6)
    reqs[1].deadline_s = 0.0                 # expired before it can admit
    resps = engine.serve(reqs)
    by_uid = {r.uid: r for r in resps}
    assert by_uid[0].finish_reason == "length"
    assert by_uid[1].finish_reason == "timeout"
    assert len(by_uid[1].tokens) == 0
    for uid in (2, 3):                       # beyond capacity at submit
        assert by_uid[uid].finish_reason == "rejected"
        assert len(by_uid[uid].tokens) == 0
    stats = engine.stats()
    assert stats["rejected"] == 2 and stats["timeouts"] == 1


def test_stall_watchdog_flags_delayed_dispatch(engine, rng):
    engine.stall_timeout_s = 0.05
    engine.fault_hook = faults.ServeFaults(
        max_batch=2, delay_decode={1: 0.2})  # stall the second chunk
    engine.serve(_reqs(rng, 2))
    assert engine.stats()["stalls"] >= 1
    assert any(e["kind"] == "stall" and e["dispatch"] == "decode"
               for e in engine.events)
