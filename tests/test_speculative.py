"""Speculative-decoding tests: bit-identical greedy streams (spec vs
plain, rank- and depth-truncated drafts, ragged batching, EOS inside the
verify window), paged-KV rollback byte-identity against a never-drafted
run, dispatch assertions (draft/verify counters, no silent fallback),
pick_draft_ranks properties + cross-process determinism, and the
decode-chunk budget-clamp regression."""
import hashlib
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.core.rank_analysis import pick_draft_ranks
from repro.kernels.cola_ae import ops as cao
from repro.serve import draft as draft_mod
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request


def _cfg(**over):
    # f32 keeps greedy argmax robust to path-dependent rounding
    return get_config("qwen2-1.5b").smoke().with_overrides(
        dtype="float32", **over)


def _prompts(rng, b, p, vocab=512):
    return rng.randint(1, vocab, (b, p)).astype(np.int32)


# two draft profiles: rank-energy truncation (high acceptance even at
# random init — the kept directions carry 95% of each site's importance)
# and depth truncation (near-zero acceptance untrained — which must not
# matter: correctness never depends on the draft being any good)
DRAFTS = {"rank": dict(draft_alpha=0.95),
          "depth": dict(draft_depth=2, draft_depth_mode="stride")}


@pytest.fixture(scope="module")
def plain_eng():
    return make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4)


@pytest.fixture(scope="module", params=sorted(DRAFTS))
def spec_eng(request):
    return make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4,
                       speculate=True, spec_window=3,
                       **DRAFTS[request.param])


def _reqs(rng, lens, max_new=8, eos=None):
    return [Request(uid=i, prompt=_prompts(rng, 1, L)[0],
                    max_new_tokens=max_new, eos_id=eos)
            for i, L in enumerate(lens)]


def test_spec_stream_bit_identical_ragged(plain_eng, spec_eng, rng):
    """Greedy speculative serving emits the exact token stream of plain
    decode for a ragged continuous batch (more requests than slots):
    every consumed token is the full model's argmax by construction,
    whatever the draft proposes."""
    state = rng.get_state()
    want = plain_eng.serve(_reqs(rng, [5, 9, 3, 12]))
    rng.set_state(state)
    got = spec_eng.serve(_reqs(rng, [5, 9, 3, 12]))
    for w, g in zip(want, got):
        assert g.finish_reason == w.finish_reason
        np.testing.assert_array_equal(g.tokens, w.tokens)
    s = spec_eng.stats()
    assert s["spec_rounds"] > 0 and s["spec_drafted"] > 0
    assert s["spec_accepted"] + s["spec_rejected"] == s["spec_drafted"]
    # the rank-energy draft must actually accept something at alpha=0.95
    if spec_eng.draft_plan.alpha is not None:
        assert s["spec_accepted"] > 0
    spec_eng.reset_stats()


def test_spec_eos_inside_window(plain_eng, spec_eng, rng):
    """EOS landing mid-window: the scheduler truncates at EOS exactly as
    in plain decode (accepted tokens past EOS are dropped on consume) and
    the freed slot serves the queued follower with an unperturbed
    stream."""
    p = _prompts(rng, 1, 7)[0]
    base = plain_eng.serve([Request(uid=0, prompt=p, max_new_tokens=8)])[0]
    eos = int(base.tokens[3])  # EOS at stream offset 3: inside a window
    follower = _prompts(rng, 1, 4)[0]
    reqs = lambda: [Request(uid=0, prompt=p, max_new_tokens=8, eos_id=eos),
                    Request(uid=1, prompt=p, max_new_tokens=8, eos_id=eos),
                    Request(uid=2, prompt=follower, max_new_tokens=8)]
    want = plain_eng.serve(reqs())
    got = spec_eng.serve(reqs())
    for w, g in zip(want, got):
        assert g.finish_reason == w.finish_reason
        np.testing.assert_array_equal(g.tokens, w.tokens)
    assert got[0].finish_reason == "eos" and got[0].tokens[-1] == eos
    spec_eng.reset_stats()


def test_spec_greedy_only(spec_eng, rng):
    with pytest.raises(ValueError, match="greedy"):
        spec_eng.serve([Request(uid=0, prompt=_prompts(rng, 1, 5)[0],
                                max_new_tokens=4, temperature=0.7)])


def test_spec_window_caps_on_decode_plan():
    """No silent fallback by construction: a verify window that would
    fall off the decode kernel plan (B × window > DECODE_T_MAX) is
    rejected at engine build, never dispatched down a slower path."""
    with pytest.raises(ValueError, match="DECODE_T_MAX"):
        make_engine(_cfg(), max_batch=32, max_seq=64, speculate=True,
                    spec_window=3)


def test_spec_dispatch_counters(rng):
    """Dispatch assertion for the speculative serve stack: with the
    fused path forced onto Pallas, the draft scan and the k-position
    verify both land on the decode plan (draft_/verify_-prefixed
    counters), with zero ref fallbacks and zero training-shaped
    dispatches."""
    import dataclasses
    cfg = _cfg()
    cfg = cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, use_fused_kernel=True))
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        eng = make_engine(cfg, max_batch=2, max_seq=64, decode_block=4,
                          speculate=True, draft_alpha=0.95, spec_window=3)
        eng.serve(_reqs(rng, [5, 9], max_new=6))
    d = dict(cao.DISPATCH)
    assert d.get("verify_infer_decode", 0) > 0, d   # verify on decode plan
    assert d.get("draft_infer_decode", 0) > 0, d    # draft on decode plan
    for key in d:
        assert not key.endswith("_ref"), (key, d)   # no silent XLA math
        assert not key.startswith(("fwd_", "bwd_")), (key, d)


# ---- paged-KV rollback byte-identity -------------------------------------
def _pool(eng):
    """Cache pool bytes minus the sacrificial page (page 0 absorbs
    unowned-position writes from idle slots and the pad-parking column in
    both engines — its contents are scatter-order noise, not state)."""
    return [np.asarray(l)[:, eng.page_size:]
            for l in jax.tree.leaves(eng._caches)]


def _one_trial(seed):
    """One seeded trial of the rollback oracle: a single slot served
    speculatively must leave the paged pool byte-identical to a
    never-drafted engine's — accepted rows were computed from the same
    token history, rejected rows are zeroed exactly like the admit-time
    fresh wipe left them — with allocator invariants checked after every
    speculative round."""
    rng = np.random.RandomState(seed)
    plen = int(rng.randint(3, 12))
    max_new = int(rng.randint(2, 10))
    prompt = _prompts(rng, 1, plen)[0]

    spec = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4,
                      speculate=True, draft_alpha=0.95, spec_window=3)
    rounds = []
    orig = spec.spec_chunk

    def audited(*a, **kw):
        out = orig(*a, **kw)
        spec.alloc.check_invariants()  # after every rollback
        rounds.append(1)
        return out
    spec.spec_chunk = audited
    spec.serve([Request(uid=0, prompt=prompt, max_new_tokens=max_new)])
    assert rounds, "speculative path never dispatched"
    spec.alloc.check_invariants()

    plain = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4)
    plain.serve([Request(uid=0, prompt=prompt, max_new_tokens=max_new)])
    for ls, lp in zip(_pool(spec), _pool(plain)):
        np.testing.assert_array_equal(ls, lp)


def test_rollback_pool_byte_identical_seeded():
    for seed in (0, 1, 2):
        _one_trial(seed)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:  # CI installs hypothesis; bare envs skip this variant
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(min_value=3, max_value=1000))
    def test_rollback_pool_byte_identical_hypothesis(seed):
        _one_trial(seed)


def test_rollback_with_quarantine_and_eos(plain_eng, rng):
    """Chaos interaction: a poisoned verify round quarantines the slot
    (its round tokens dropped, pages released, request re-queued); the
    retry and an EOS-inside-window neighbour still emit plain-decode
    streams and the allocator stays consistent."""
    p = _prompts(rng, 1, 6)[0]
    follower = _prompts(rng, 1, 4)[0]
    base = plain_eng.serve([Request(uid=0, prompt=p, max_new_tokens=8)])[0]
    eos = int(base.tokens[2])
    mk = lambda: [Request(uid=0, prompt=p, max_new_tokens=8, eos_id=eos),
                  Request(uid=1, prompt=follower, max_new_tokens=6)]
    want = plain_eng.serve(mk())

    hits = []

    def hook(kind, idx):
        if kind == "decode" and idx == 0:  # poison the first spec round
            hits.append(idx)
            return {"poison": np.array([True, False])}
        return None
    spec = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4,
                       speculate=True, draft_alpha=0.95, spec_window=3)
    spec.fault_hook = hook
    got = spec.serve(mk())
    assert hits, "fault hook never fired"
    for w, g in zip(want, got):
        assert g.finish_reason == w.finish_reason
        np.testing.assert_array_equal(g.tokens, w.tokens)
    s = spec.stats()
    assert s["quarantines"] == 1 and s["requeues"] == 1
    spec.alloc.check_invariants()
    assert spec.alloc.pages_in_use == 0


# ---- pick_draft_ranks properties -----------------------------------------
def test_pick_draft_ranks_properties():
    rng = np.random.RandomState(0)
    spectra = [{"layer": i,
                "spectrum": np.sort(rng.rand(16).astype(np.float64))[::-1]}
               for i in range(4)]
    alphas = [0.1, 0.5, 0.8, 0.9, 0.99, 1.0]
    picks = [pick_draft_ranks(spectra, a) for a in alphas]
    for lo, hi in zip(picks, picks[1:]):      # monotone in alpha
        assert all(lo[l] <= hi[l] for l in lo)
    for p in picks:                           # bounded by spectrum length
        assert all(1 <= r <= 16 for r in p.values())
    capped = pick_draft_ranks(spectra, 1.0, max_rank=5)
    assert all(r == 5 for r in capped.values())
    assert all(r == 16 for r in picks[-1].values())  # alpha=1: full rank
    with pytest.raises(ValueError):
        pick_draft_ranks(spectra, 0.0)
    with pytest.raises(ValueError):
        pick_draft_ranks(spectra, 1.5)


_PLAN_DIGEST_CODE = textwrap.dedent("""
    import sys; sys.path.insert(0, 'src')
    import hashlib, json, jax
    from repro.config import get_config
    from repro.models.model import build_model
    from repro.serve import draft as draft_mod
    model = build_model(get_config("llama-60m").smoke())
    params = model.init(jax.random.PRNGKey(0))
    plan = draft_mod.plan_draft(params, alpha=0.9, depth=2,
                                depth_mode="prefix")
    blob = json.dumps(plan.describe(), sort_keys=True)
    print("DIGEST", hashlib.sha256(blob.encode()).hexdigest())
""")


def _plan_digest(hashseed):
    import os
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    r = subprocess.run([sys.executable, "-c", _PLAN_DIGEST_CODE], env=env,
                       capture_output=True, text=True, cwd=".", timeout=560)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout.strip().split()[-1]


def test_draft_plan_cross_process_deterministic():
    """plan_draft walks param dicts in sorted order and breaks importance
    ties stably — two processes with different PYTHONHASHSEED must derive
    bit-identical draft plans (a TP fleet plans per-host; divergent plans
    would shear the draft across shards)."""
    assert _plan_digest("1") == _plan_digest("2")


# ---- satellite: decode-chunk budget clamp --------------------------------
def test_decode_chunk_clamps_to_smallest_live_budget(rng):
    """Regression for the chunk-size coupling bug: k was clamped by the
    *largest* remaining budget, so one long request forced a nearly-done
    slot through a full block whose tail the scheduler dropped.  With the
    min-clamp, a (9, 2)-budget pair plus a queued 8-budget follower costs
    exactly 8 scanned steps (1 + 7) instead of 15 (8 + 7) — and every
    stream still matches its solo run.  The step-count arithmetic assumes
    admit-then-decode rounds, so the counted engine pins overlap=False;
    the overlap engine's clamp is asserted separately (its fused rounds
    scan more total steps by design — the long slot advances *during* the
    follower's chunked prefill instead of stalling)."""
    eng = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=8,
                      overlap=False)
    prompts = [_prompts(rng, 1, 5)[0] for _ in range(3)]
    budgets = [9, 2, 8]
    solo = []
    for p, n in zip(prompts, budgets):
        s = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=8,
                        overlap=False)
        solo.append(s.serve([Request(uid=0, prompt=p,
                                     max_new_tokens=n)])[0].tokens)
    resps = eng.serve([Request(uid=i, prompt=p, max_new_tokens=n)
                       for i, (p, n) in enumerate(zip(prompts, budgets))])
    for r, want in zip(resps, solo):
        np.testing.assert_array_equal(r.tokens, want)
    assert eng.stats()["decode_steps"] == 8
    # the overlap engine shares the clamp policy: identical streams, and
    # no chunk ever scans past the smallest live decode budget
    oeng = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=8)
    oresps = oeng.serve([Request(uid=i, prompt=p, max_new_tokens=n)
                         for i, (p, n) in enumerate(zip(prompts, budgets))])
    for r, want in zip(oresps, solo):
        np.testing.assert_array_equal(r.tokens, want)


# ---- megatron draft-verify parity (8 virtual devices) --------------------
def test_spec_megatron_parity_subprocess():
    """Tensor-parallel speculative serving: under a (data, model) mesh
    with the megatron profile, the sharded draft scan + sharded verify
    dispatch emit streams bit-identical to the unsharded plain engine,
    and the role-prefixed sharded decode counters prove both phases ran
    the fused sharded kernels."""
    code = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent("""
        import dataclasses
        import jax, numpy as np
        from repro.config import get_config
        from repro.kernels.cola_ae import ops as cao
        from repro.serve.engine import make_engine
        from repro.serve.scheduler import Request

        cfg = get_config("qwen2-1.5b").smoke().with_overrides(
            dtype="float32")
        fcfg = cfg.with_overrides(cola=dataclasses.replace(
            cfg.cola, use_fused_kernel=True))
        rng = np.random.RandomState(0)
        reqs = lambda: [Request(uid=i, prompt=rng.randint(
                            1, 512, (L,)).astype(np.int32),
                        max_new_tokens=6)
                        for i, L in enumerate([5, 9, 3])]
        state = rng.get_state()
        plain = make_engine(cfg, max_batch=2, max_seq=64, decode_block=4)
        want = [r.tokens.tolist() for r in plain.serve(reqs())]
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cao.reset_dispatch()
        rng.set_state(state)
        with cao.force_impl("pallas", True):
            eng = make_engine(fcfg, max_batch=2, max_seq=64,
                              decode_block=4, mesh=mesh,
                              profile="megatron", speculate=True,
                              draft_alpha=0.95, spec_window=3)
            got = [r.tokens.tolist() for r in eng.serve(reqs())]
        assert got == want, (got, want)
        d = dict(cao.DISPATCH)
        draft = sum(v for k, v in d.items()
                    if k.startswith("draft_sharded_infer_"))
        verify = sum(v for k, v in d.items()
                     if k.startswith("verify_sharded_infer_"))
        assert draft > 0 and verify > 0, d
        assert not any(k.endswith("_ref") and v for k, v in d.items()), d
        s = eng.stats()
        assert s["spec_rounds"] > 0 and s["spec_accepted"] > 0
        print("OK")
    """))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=560)
    assert r.returncode == 0 and "OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
