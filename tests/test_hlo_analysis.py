"""Loop-aware HLO cost model: validated against unrolled references and
hand-computed shapes (the roofline's measurement backbone)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import HloCostModel, analyze


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_scan_flops_scaled_by_trip_count():
    w = jnp.ones((256, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return (c @ w) @ w.T, None
        out, _ = jax.lax.scan(body, x, None, length=8)
        return out

    def unrolled(x):
        for _ in range(8):
            x = (x @ w) @ w.T
        return x

    x = jnp.ones((64, 256), jnp.float32)
    a_scan = analyze(_compile(scanned, x))
    a_unroll = analyze(_compile(unrolled, x))
    expected = 8 * 2 * 2 * 64 * 256 * 128
    assert a_scan["flops"] == pytest.approx(expected, rel=0.01)
    assert a_unroll["flops"] == pytest.approx(expected, rel=0.01)


def test_nested_scan_flops():
    w = jnp.ones((128, 128), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out

    x = jnp.ones((64, 128), jnp.float32)
    a = analyze(_compile(nested, x))
    expected = 3 * 4 * 2 * 64 * 128 * 128
    assert a["flops"] == pytest.approx(expected, rel=0.01)


def test_dot_flops_with_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)
    a = jnp.ones((4, 32, 64), jnp.float32)
    b = jnp.ones((4, 64, 16), jnp.float32)
    an = analyze(_compile(f, a, b))
    assert an["flops"] == pytest.approx(2 * 4 * 32 * 64 * 16, rel=0.01)


def test_remat_recompute_counted():
    """jax.checkpoint recompute inside a scanned stack shows up as extra
    FLOPs (the CoLA-M recompute term is measurable).  At top level XLA can
    CSE a trivial recompute away, so the test uses the scan structure the
    real models use."""
    ws = jnp.ones((4, 256, 256), jnp.float32)

    def loss(x, remat):
        def body(c, w):
            return jnp.tanh(c @ w) @ w.T, None
        if remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable,
                prevent_cse=True)
        out, _ = jax.lax.scan(body, x, ws)
        return (out ** 2).sum()

    x = jnp.ones((64, 256), jnp.float32)
    g0 = analyze(_compile(jax.grad(lambda x: loss(x, False)), x))
    g1 = analyze(_compile(jax.grad(lambda x: loss(x, True)), x))
    assert g1["flops"] > g0["flops"] * 1.1, (g0["flops"], g1["flops"])


def test_collective_bytes_on_mesh():
    """psum of a known tensor on an 8-device mesh → 2× payload bytes
    (ring all-reduce factor), counted once per occurrence."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys
        sys.path.insert(0, "src")
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.analysis.hlo import analyze
        mesh = jax.make_mesh((8,), ("d",))
        x = jnp.ones((1024, 64), jnp.float32)
        sh = NamedSharding(mesh, P("d", None))
        def f(x):
            y = jax.lax.with_sharding_constraint(x * 2, sh)
            s = y.sum()  # cross-device all-reduce of a scalar... use matmul
            z = jnp.einsum("td,td->d", y, y)  # reduce over sharded dim
            return z
        c = jax.jit(f, in_shardings=sh).lower(x).compile()
        a = analyze(c.as_text())
        assert a["bytes_total"] > 0, a
        print("OK", a["bytes_total"])
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".")
    assert "OK" in r.stdout, r.stdout + r.stderr
