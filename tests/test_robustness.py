"""Robustness units: straggler watchdog EWMA, loss-spike detector,
recovery-policy bookkeeping, metrics counters/ledger, and process-stable
parameter init (PYTHONHASHSEED independence, subprocess-proven)."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config import TrainConfig
from repro.distributed.straggler import StepWatchdog
from repro.train.guard import (LossSpikeDetector, RecoveryPolicy,
                               TrainingDiverged)
from repro.train.metrics import COUNTER_KEYS, MetricsLogger


# ---- StepWatchdog ---------------------------------------------------------
def test_watchdog_stop_without_start_is_noop():
    w = StepWatchdog()
    assert w.stop(0) == 0.0          # regression: used to TypeError
    assert w.events == [] and w.seen == 0


def test_watchdog_flags_after_warmup_and_excludes_outlier():
    seen = []
    w = StepWatchdog(threshold=2.0, warmup_steps=3,
                     on_straggler=lambda s, dt, avg: seen.append(s))
    for s in range(5):
        assert not w.observe(s, 1.0)
    avg_before = w.avg
    assert w.observe(5, 3.0)         # > 2× the EWMA after warmup
    assert w.avg == avg_before       # outlier excluded from the EWMA
    assert seen == [5]
    assert w.events[0]["step"] == 5
    assert not w.observe(6, 1.0)


def test_watchdog_warmup_suppresses_flags():
    w = StepWatchdog(threshold=2.0, warmup_steps=10)
    w.observe(0, 1.0)
    assert not w.observe(1, 100.0)   # within warmup: never flagged


# ---- LossSpikeDetector ----------------------------------------------------
def test_spike_detector_flags_and_excludes_outlier():
    d = LossSpikeDetector(threshold=2.0, ewma=0.9, warmup_steps=3)
    for s in range(5):
        assert not d.observe(s, 4.0)
    avg_before = d.avg
    assert d.observe(5, 20.0)
    assert d.avg == avg_before       # spike excluded from the EWMA
    assert d.events[0] == {"step": 5, "loss": 20.0, "avg": avg_before}


def test_spike_detector_nonfinite_is_not_a_spike():
    d = LossSpikeDetector(threshold=2.0, warmup_steps=0)
    d.observe(0, 4.0)
    # NaN/inf belong to the in-jit guard, not the spike detector
    assert not d.observe(1, float("nan"))
    assert not d.observe(2, float("inf"))
    assert d.seen == 1 and d.avg == 4.0


def test_spike_detector_disabled_and_reset():
    d = LossSpikeDetector(threshold=0.0, warmup_steps=0)
    d.observe(0, 1.0)
    assert not d.observe(1, 1000.0)  # threshold<=0 disables flagging
    assert d.avg is not None         # ...but the EWMA still tracks
    d.reset()
    assert d.avg is None and d.seen == 0


# ---- RecoveryPolicy (no-checkpoint path) ----------------------------------
class _FakePipe:
    def __init__(self):
        self.offset = 0

    def skip_window(self, n):
        self.offset += n
        return self.offset


def test_recovery_policy_skips_batch_then_hard_fails():
    tc = TrainConfig(max_recoveries=2, skip_window=1)
    pipe, logger = _FakePipe(), MetricsLogger()
    pol = RecoveryPolicy(tc, mgr=None, pipe=pipe, logger=logger)
    state = object()
    got, step = pol.recover(7, state, "nonfinite", float("nan"))
    assert got is state and step == 7
    assert pipe.offset == 2          # 1 (bad batch) + skip_window
    got, step = pol.recover(7, state, "loss_spike", 99.0)
    assert pipe.offset == 4
    assert logger.counters["recoveries"] == 2
    assert logger.counters["nonfinite_steps"] == 1
    assert logger.counters["loss_spikes"] == 1
    assert [e["kind"] for e in logger.events] == ["skip_batch",
                                                  "skip_batch"]
    with pytest.raises(TrainingDiverged, match="max_recoveries"):
        pol.recover(7, state, "nonfinite", float("nan"))
    assert logger.events[-1]["kind"] == "hard_failure"


# ---- MetricsLogger --------------------------------------------------------
def test_metrics_counters_seeded_in_csv_header(tmp_path):
    path = str(tmp_path / "m.csv")
    log = MetricsLogger(path)
    assert set(COUNTER_KEYS) <= set(log.counters)
    log.count("recoveries")
    log.event("rollback", 3, restored_step=2)
    log.log(3, {"loss": 1.5})
    log.close()
    header, row = open(path).read().strip().split("\n")
    cols = header.split(",")
    for k in COUNTER_KEYS:           # counters present from row one
        assert k in cols, (k, cols)
    vals = dict(zip(cols, row.split(",")))
    assert vals["recoveries"] == "1"
    assert log.events == [{"kind": "rollback", "step": 3,
                           "restored_step": 2}]


# ---- PYTHONHASHSEED-stable init ------------------------------------------
_DIGEST_CODE = textwrap.dedent("""
    import sys; sys.path.insert(0, 'src')
    import hashlib, jax, numpy as np
    from repro.config import get_config
    from repro.models.model import build_model
    model = build_model(get_config("llama-60m").smoke())
    params = model.init(jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    h = hashlib.sha256()
    for p, v in sorted(flat, key=lambda kv: jax.tree_util.keystr(kv[0])):
        h.update(np.ascontiguousarray(np.asarray(v)).tobytes())
    print("DIGEST", h.hexdigest())
""")


def _digest(hashseed: str) -> str:
    import os
    env = dict(os.environ, PYTHONHASHSEED=hashseed)
    r = subprocess.run([sys.executable, "-c", _DIGEST_CODE], env=env,
                       capture_output=True, text=True, cwd=".", timeout=560)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout.strip().split()[-1]


def test_param_init_independent_of_pythonhashseed():
    """init_params folds a CRC32 of each param path into the rng, not
    Python's salted hash() — two processes with different PYTHONHASHSEED
    must build bit-identical params from the same seed (multi-host init
    and checkpoint parity both depend on this)."""
    assert _digest("1") == _digest("2")
