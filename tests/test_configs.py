"""Assigned-architecture configs must match the assignment sheet exactly
(layer counts, widths, heads, ffn, vocab, family markers)."""
import pytest

from repro.config import LM_SHAPES, applicable_shapes, get_config

SPEC = {
    # arch: (L, d_model, H, kv, d_ff, vocab)
    "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
    "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
    "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "qwen2-1.5b": (28, 1536, 12, 2, 8960, 151936),
    "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
    "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
    "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
    "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
}


@pytest.mark.parametrize("arch", sorted(SPEC))
def test_config_matches_assignment(arch):
    L, d, h, kv, ff, v = SPEC[arch]
    cfg = get_config(arch)
    assert cfg.num_layers == L
    assert cfg.d_model == d
    assert cfg.num_heads == h
    assert cfg.num_kv_heads == kv
    assert cfg.d_ff == ff
    assert cfg.vocab_size == v
    assert cfg.padded_vocab % 256 == 0 and cfg.padded_vocab >= v


def test_moe_markers():
    jamba = get_config("jamba-v0.1-52b")
    assert jamba.moe.num_experts == 16 and jamba.moe.top_k == 2
    assert jamba.layer_kinds().count("attn") == 4  # 1:7 interleave
    ll4 = get_config("llama4-maverick-400b-a17b")
    assert ll4.moe.num_experts == 128 and ll4.moe.top_k == 1
    phi = get_config("phi3.5-moe-42b-a6.6b")
    assert phi.moe.num_experts == 16 and phi.moe.top_k == 2


def test_shape_cells():
    assert LM_SHAPES["train_4k"].seq_len == 4096
    assert LM_SHAPES["train_4k"].global_batch == 256
    assert LM_SHAPES["prefill_32k"].global_batch == 32
    assert LM_SHAPES["decode_32k"].global_batch == 128
    assert LM_SHAPES["long_500k"].seq_len == 524288
    # long_500k only for sub-quadratic archs
    for arch in SPEC:
        names = [s.name for s in applicable_shapes(get_config(arch))]
        if arch in ("jamba-v0.1-52b", "rwkv6-7b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names


def test_qkv_bias_and_rope_markers():
    assert get_config("qwen2-1.5b").qkv_bias
    assert get_config("qwen2-vl-2b").rope == "mrope"
    assert get_config("minicpm3-4b").attention == "mla"
    assert get_config("whisper-tiny").is_encoder_decoder
    assert get_config("rwkv6-7b").attention == "none"


def test_paper_rank_defaults():
    """Paper Table 5 r/d pairs for the LLaMA family; default r = d/4."""
    for arch, (r, d) in {"llama-60m": (128, 512), "llama-130m": (256, 768),
                         "llama-350m": (256, 1024), "llama-1b": (512, 2048),
                         "llama-7b": (1024, 4096)}.items():
        cfg = get_config(arch)
        assert cfg.rank_attn == r and cfg.d_model == d
    assert get_config("llama3.2-1b").rank_attn == 2048 // 4
