"""Tensor-parallel fused CoLA-AE: the multi-device parity harness.

Proves that ``use_fused`` under a mesh with a 'model' axis no longer falls
back: the Pallas kernels (interpret mode on CPU) run per-shard inside
shard_map with a collective-aware custom VJP (kernels/cola_ae/ops.py), and
their loss/gradients match the unfused sharded reference.

The parity matrix:

* op level    — profile (baseline/megatron/fsdp) × site weight axes
                (column-, row-, and rank-contested sites) × all four σ
                modes, f32 tight + bf16 loose, plus bias-carrying sites
                (two-stage pipeline) and the sequence-parallel entry,
* model level — profile × remat policy (full/cola_m) × σ placement
                (lowrank_only/fullrank_only), fused vs unfused loss+grads,
* dispatch    — the ops.DISPATCH counters assert the fused plans were
                actually taken at every site: no XLA math at megatron
                row-parallel sites (now staged Pallas around the z_pre
                psum), none at bias sites, and none in any bundled config
                (test_no_config_silently_takes_xla_math).

Runs on an 8-virtual-device CPU mesh.  The CI multidevice job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` at the job level and
runs everything here in-process; under plain single-device tier-1 the suite
re-execs itself once in a subprocess with that flag (the forced device
count must not leak into other tests — see conftest.py).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import sharding as sh
from repro.kernels.cola_ae import act as caa
from repro.kernels.cola_ae import kernel as cak
from repro.kernels.cola_ae import ops as cao
from repro.kernels.cola_ae import ref as car

MULTI = jax.device_count() >= 8
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

PROFILES = ("baseline", "megatron", "fsdp")
# (in_ax, out_ax): column-parallel under megatron; row-parallel under
# megatron; rank-vs-in_ax axis contention (MLA uq-style site).
SITE_AXES = (("embed", "ffw"), ("ffw", "embed"), ("rank", "heads"))


@pytest.mark.skipif(MULTI, reason="already inside the multi-device run")
@pytest.mark.skipif(bool(os.environ.get("CI")),
                    reason="CI runs this suite in-process in the "
                           "multidevice job; don't pay it twice")
def test_suite_reexecs_on_8_virtual_devices():
    """Local tier-1 entry point: run this whole file on an 8-device mesh."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        env=env, capture_output=True, text=True, timeout=1500,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-4000:]}\nstderr:\n{r.stderr[-2000:]}"


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32).reshape(got.shape)
    return float(np.abs(got - want).max() / (np.abs(want).max() + 1e-12))


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


def _site_args(dtype, rng_seed=0):
    rng = np.random.RandomState(rng_seed)
    b, s, din, r, dout = 8, 16, 64, 32, 96
    x = jnp.asarray(rng.randn(b, s, din), dtype)
    wa = jnp.asarray(0.05 * rng.randn(din, r), dtype)
    wb = jnp.asarray(0.05 * rng.randn(r, dout), dtype)
    return x, wa, wb


# --------------------------------------------------------------------------
# op level
# --------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
@pytest.mark.parametrize("site", SITE_AXES, ids=lambda s: "->".join(s))
@pytest.mark.parametrize("profile", PROFILES)
def test_sharded_op_grad_parity_f32(profile, site, sigma):
    in_ax, out_ax = site
    x, wa, wb = _site_args(jnp.float32)
    with sh.mesh_env(_mesh24(), profile):
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma=sigma, in_ax=in_ax, out_ax=out_ax) ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    fr = lambda *t: (car.cola_ae(
        t[0].reshape(-1, t[0].shape[-1]), t[1], t[2], sigma=sigma) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, wa, wb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5, (profile, site, sigma, u.shape, _rel(u, v))


@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_sharded_op_grad_parity_bf16(profile):
    x, wa, wb = _site_args(jnp.bfloat16)
    with sh.mesh_env(_mesh24(), profile):
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="embed", out_ax="ffw")
                .astype(jnp.float32) ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    fr = lambda *t: (car.cola_ae(
        t[0].reshape(-1, t[0].shape[-1]), t[1], t[2], sigma="silu")
        .astype(jnp.float32) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, wa, wb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 2e-2, (profile, u.shape, _rel(u, v))


@needs_mesh
def test_sharded_op_dispatch_counts_kernels():
    """The shard_map bodies run the Pallas kernels — not silent XLA — at
    every site where no collective is needed mid-kernel."""
    x, wa, wb = _site_args(jnp.float32)
    with sh.mesh_env(_mesh24(), "baseline"):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="embed", out_ax="ffw") ** 2).sum()
            jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    assert cao.DISPATCH["sharded_call"] > 0
    assert cao.DISPATCH["sharded_fwd_pallas"] > 0
    assert cao.DISPATCH["bwd_pallas"] > 0
    assert cao.DISPATCH["sharded_fwd_ref"] == 0
    assert cao.DISPATCH["bwd_ref"] == 0


@needs_mesh
def test_megatron_row_parallel_is_fully_fused():
    """The PR's headline: the megatron row-parallel forward (o/down: psum
    of z_pre between the A-GEMM and σ) no longer drops to XLA math — the
    two-stage pipeline runs Pallas kernels on both sides of the psum."""
    x, wa, wb = _site_args(jnp.float32)
    with sh.mesh_env(_mesh24(), "megatron"):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="ffw", out_ax="embed") ** 2).sum()
            jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    assert cao.DISPATCH["sharded_fwd_staged"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["sharded_fwd_ref"] == 0
    assert cao.DISPATCH["bwd_ref"] == 0
    # the old fallback counters must be gone, not just zero
    assert "sharded_fwd_rowpar_xla" not in cao.DISPATCH
    # column-parallel bwd (dzl psum) likewise rides the staged kernels
    with sh.mesh_env(_mesh24(), "megatron"):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="embed", out_ax="ffw") ** 2).sum()
            jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    assert cao.DISPATCH["bwd_staged"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["bwd_ref"] == 0
    assert "sharded_bwd_colpar_xla" not in cao.DISPATCH


@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_sharded_bias_site_grad_parity(profile):
    """Bias-carrying AE sites (qwen2 qkv, whisper MLP) stay fused under a
    'model' mesh: bias_a folds into the saved z_pre (monolith body or
    staged seam), bias_b into the output tile (post-psum under rank
    sharding), and all five gradients match the oracle."""
    rng = np.random.RandomState(3)
    x, wa, wb = _site_args(jnp.float32)
    ba = jnp.asarray(0.1 * rng.randn(wa.shape[1]), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(wb.shape[1]), jnp.float32)
    with sh.mesh_env(_mesh24(), profile):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                t[0], t[1], t[2], bias_a=t[3], bias_b=t[4], sigma="gelu",
                in_ax="embed", out_ax="ffw") ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, wa, wb, ba, bb)
    # fwd may be monolith (bias fold) or staged (row-parallel seam); the
    # bwd always stages for the bias grads — never ref either way
    assert cao.DISPATCH["sharded_fwd_pallas"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["bwd_staged"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["sharded_fwd_ref"] == 0
    assert cao.DISPATCH["bwd_ref"] == 0
    fr = lambda *t: (car.cola_ae(
        t[0].reshape(-1, t[0].shape[-1]), t[1], t[2], bias_a=t[3],
        bias_b=t[4], sigma="gelu") ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2, 3, 4))(x, wa, wb, ba, bb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5, (profile, u.shape, _rel(u, v))


@needs_mesh
def test_overvmem_site_stays_fused_under_mesh(monkeypatch):
    """Over-VMEM sites (internlm2 down-proj class): with the per-shard
    local weights still over budget, the shard_map body streams the
    weight grid instead of dropping to XLA — zero ref dispatches, parity
    intact."""
    monkeypatch.setattr(cak, "FWD_VMEM_BUDGET", 16 * 1024)
    monkeypatch.setattr(cak, "DW_VMEM_BUDGET", 12 * 1024)
    x, wa, wb = _site_args(jnp.float32)
    d_in, r = wa.shape
    assert not cak.weights_fit_vmem(d_in, r, wb.shape[1], bytes_el=4)
    with sh.mesh_env(_mesh24(), "megatron"):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="embed", out_ax="ffw") ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    assert cao.DISPATCH["sharded_fwd_staged"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["sharded_fwd_monolith"] == 0
    assert cao.DISPATCH["sharded_fwd_ref"] == 0
    assert cao.DISPATCH["bwd_staged"] > 0
    assert cao.DISPATCH["bwd_ref"] == 0
    fr = lambda *t: (car.cola_ae(
        t[0].reshape(-1, t[0].shape[-1]), t[1], t[2], sigma="silu")
        ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, wa, wb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5


@needs_mesh
def test_sequence_parallel_entry_explicit_gather():
    """Seq-sharded residual streams enter the shard_map seq-sharded and
    are gathered *inside* the body (DISPATCH-owned), not implicitly by
    GSPMD outside; parity is preserved."""
    x, wa, wb = _site_args(jnp.float32)
    with sh.mesh_env(_mesh24(), "baseline") as env:
        part = sh.cola_ae_partition(env, x.shape, wa.shape, wb.shape,
                                    "embed", "ffw")
        assert part.seq_axes == ("model",)
        assert part.x_spec[1] == "model"
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae_sharded(
                *t, sigma="silu", in_ax="embed", out_ax="ffw") ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2))(x, wa, wb)
    # one gather in fwd, one in bwd (plus the inference fwd of jax.vjp is
    # not traced here) — at least both directions fired
    assert cao.DISPATCH["sharded_entry_allgather"] >= 2, dict(cao.DISPATCH)
    fr = lambda *t: (car.cola_ae(
        t[0].reshape(-1, t[0].shape[-1]), t[1], t[2], sigma="silu")
        ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, wa, wb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5
    # row-parallel sites keep 'model' on d_in: seq entry must step aside
    with sh.mesh_env(_mesh24(), "megatron") as env:
        down = sh.cola_ae_partition(env, (8, 16, 128), (128, 16), (16, 64),
                                    "ffw", "embed")
        assert down.seq_axes == ()
        assert down.in_axes == ("model",)


@needs_mesh
def test_zpre_residual_is_rank_sharded_under_baseline():
    """The fused VJP saves only (x, z_pre, a, b), and z_pre's rank dim is
    sharded over 'model' — the saved residual is 1/4 per device."""
    x, wa, wb = _site_args(jnp.float32)
    T, r = x.shape[0] * x.shape[1], wa.shape[1]
    with sh.mesh_env(_mesh24(), "baseline"):
        with cao.force_impl("pallas", True):
            _, vjp_fn = jax.vjp(
                lambda x, a, b: cao.cola_ae_sharded(
                    x, a, b, sigma="silu", in_ax="embed", out_ax="ffw"),
                x, wa, wb)
    leaves = jax.tree_util.tree_leaves(vjp_fn)
    shapes = sorted(tuple(l.shape) for l in leaves)
    assert shapes == sorted([x.shape, (T, r), wa.shape, wb.shape])
    zp = next(l for l in leaves if l.shape == (T, r))
    assert zp.dtype == jnp.float32
    assert zp.sharding.spec[1] == "model", zp.sharding.spec


# --------------------------------------------------------------------------
# model level
# --------------------------------------------------------------------------
def _model_grads(cfg, batch_seed=0):
    from repro.models.model import build_model
    from repro.train.step import build_loss_fn
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(batch_seed)
    batch = {"tokens": jnp.asarray(rng.randint(1, 500, (8, 32)), jnp.int32),
             "labels": jnp.asarray(rng.randint(1, 500, (8, 32)), jnp.int32)}
    loss_fn = build_loss_fn(model)
    (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    return float(loss), g


def _smoke_cfg(remat, sigma_mode, fused, dtype="float32"):
    from repro.config import get_config
    cfg = get_config("llama-60m").smoke().with_overrides(
        remat=remat, dtype=dtype)
    return cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, sigma=sigma_mode, use_fused_kernel=fused))


@needs_mesh
@pytest.mark.parametrize("sigma_mode", ["lowrank_only", "fullrank_only"])
@pytest.mark.parametrize("remat", ["full", "cola_m"])
@pytest.mark.parametrize("profile", PROFILES)
def test_model_fused_vs_unfused_parity(profile, remat, sigma_mode):
    """The PR's acceptance matrix: on an 8-device mesh with a 'model' axis,
    use_fused=True dispatches the sharded fused path at every AE site (no
    silent fallback: counters checked) and its loss/grads match the unfused
    sharded reference within f32 tolerances."""
    with sh.mesh_env(_mesh24(), profile):
        l0, g0 = _model_grads(_smoke_cfg(remat, sigma_mode, fused=False))
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            l1, g1 = _model_grads(_smoke_cfg(remat, sigma_mode, fused=True))
    assert cao.DISPATCH["apply_fused_sharded"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["apply_fused_local"] == 0
    assert cao.DISPATCH["apply_fused_fallback"] == 0
    assert l0 == pytest.approx(l1, rel=1e-5)
    for u, v in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        u = np.asarray(u, np.float32)
        v = np.asarray(v, np.float32)
        rel = np.abs(u - v).max() / (np.abs(u).max() + 1e-12)
        assert rel <= 1e-4, (profile, remat, sigma_mode, u.shape, rel)


@needs_mesh
@pytest.mark.parametrize("arch", [
    # every bundled architecture family: dense llama, bias qkv (qwen2),
    # GQA+deep (internlm2 — the over-VMEM down-proj at full scale), MLA
    # (minicpm3), hybrid ssm+moe (jamba), moe (phi3.5), rwkv6, encdec
    # audio with bias MLPs (whisper), vlm (qwen2-vl), iRoPE moe (llama4)
    "llama3.2-1b", "qwen2-1.5b", "internlm2-20b", "minicpm3-4b",
    "jamba-v0.1-52b", "phi3.5-moe-42b-a6.6b", "rwkv6-7b", "whisper-tiny",
    "qwen2-vl-2b", "llama4-maverick-400b-a17b",
])
def test_no_config_silently_takes_xla_math(arch):
    """Satellite acceptance: under an 8-device 'model' mesh, every CoLA AE
    site in every bundled config dispatches a fused plan — zero unfused
    fallbacks (no apply-level fallback, no ref math inside the shard_map
    bodies), bias sites and row-parallel sites included."""
    import dataclasses as _dc

    from repro.config import get_config
    from repro.models.model import build_model
    from repro.train.step import build_loss_fn
    cfg = get_config(arch).smoke()
    cfg = cfg.with_overrides(cola=_dc.replace(
        cfg.cola, use_fused_kernel=True))
    from test_arch_smoke import _batch_for
    batch = _batch_for(cfg)
    with sh.mesh_env(_mesh24(), "megatron"):
        cao.reset_dispatch()
        with cao.force_impl("pallas", True):
            model = build_model(cfg)
            params = model.init(jax.random.PRNGKey(0))
            loss_fn = build_loss_fn(model)
            (loss, _), _ = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch)
    assert np.isfinite(float(loss))
    assert cao.DISPATCH["apply_fused_sharded"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["apply_fused_fallback"] == 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["apply_fused_local"] == 0
    assert cao.DISPATCH["sharded_fwd_ref"] == 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["bwd_ref"] == 0, dict(cao.DISPATCH)


@needs_mesh
def test_model_fused_parity_bf16_activations():
    """One bf16 point of the matrix: dtype-aware (loose) tolerance — bf16
    GEMM rounding differs between the fused kernels and XLA's reassociated
    einsums, compounding over 2 layers × 7 sites."""
    with sh.mesh_env(_mesh24(), "baseline"):
        l0, g0 = _model_grads(
            _smoke_cfg("cola_m", "lowrank_only", False, dtype="bfloat16"))
        with cao.force_impl("pallas", True):
            l1, g1 = _model_grads(
                _smoke_cfg("cola_m", "lowrank_only", True, dtype="bfloat16"))
    assert l0 == pytest.approx(l1, rel=1e-2)
    for u, v in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        u = np.asarray(u, np.float32)
        v = np.asarray(v, np.float32)
        # norm-based: single-element max-rel is dominated by bf16 rounding
        rel = np.linalg.norm(u - v) / (np.linalg.norm(u) + 1e-12)
        # headroom over the ~3e-2 observed worst case: CPU XLA numerics are
        # mildly run-order dependent (see memory note on test_colam flakes)
        assert rel <= 6e-2, (u.shape, rel)


# --------------------------------------------------------------------------
# partitioning + per-shard VMEM accounting (no mesh needed)
# --------------------------------------------------------------------------
from conftest import FakeMesh as _FakeMesh  # noqa: E402


def _env(profile, **shape):
    return sh.MeshEnv(_FakeMesh(shape or {"data": 2, "model": 4}), profile)


def test_partition_baseline_shards_rank():
    part = sh.cola_ae_partition(_env("baseline"), (8, 16, 64), (64, 16),
                                (16, 128), "embed", "ffw")
    assert part.rank_axes == ("model",)
    assert part.in_axes == () and part.out_axes == ()
    assert part.a_spec == jax.sharding.PartitionSpec(None, "model")
    assert part.zpre_spec == jax.sharding.PartitionSpec("data", "model")
    # bias specs follow the factor dims they attach to
    assert part.bias_a_spec == jax.sharding.PartitionSpec("model")
    assert part.bias_b_spec == jax.sharding.PartitionSpec(None)
    # seq entry: 'model' is free on x's seq dim (rank only shards weights)
    assert part.seq_axes == ("model",)


def test_partition_seq_entry_degrades_on_nondividing_seq():
    # s=10 not divisible by model=4: seq entry degrades to replicated
    part = sh.cola_ae_partition(_env("baseline"), (8, 10, 64), (64, 16),
                                (16, 128), "embed", "ffw")
    assert part.seq_axes == ()
    assert part.x_spec[1] is None


def test_partition_megatron_column_and_row():
    up = sh.cola_ae_partition(_env("megatron"), (8, 16, 64), (64, 16),
                              (16, 128), "embed", "ffw")
    assert up.out_axes == ("model",) and up.in_axes == ()
    assert up.rank_axes == ()
    down = sh.cola_ae_partition(_env("megatron"), (8, 16, 128), (128, 16),
                                (16, 64), "ffw", "embed")
    assert down.in_axes == ("model",) and down.out_axes == ()
    assert down.x_spec == jax.sharding.PartitionSpec("data", None, "model")
    assert up.seq_axes == ("model",)   # column-parallel: seq entry active
    assert down.seq_axes == ()         # row-parallel: d_in owns 'model'
    assert up.bias_b_spec == jax.sharding.PartitionSpec("model")


def test_partition_rank_contention_resolves_consistently():
    """MLA uq-style site (in_ax='rank'): rank wins the 'model' axis so A's
    col dim and B's row dim agree; d_in degrades to replicated."""
    part = sh.cola_ae_partition(_env("baseline"), (8, 16, 32), (32, 16),
                                (16, 128), "rank", "heads")
    assert part.rank_axes == ("model",) and part.in_axes == ()
    assert part.a_spec == jax.sharding.PartitionSpec(None, "model")
    assert part.b_spec == jax.sharding.PartitionSpec("model", None)


def test_partition_fsdp_folds_model_into_batch():
    part = sh.cola_ae_partition(_env("fsdp"), (8, 16, 64), (64, 16),
                                (16, 128), "embed", "ffw")
    assert part.in_axes == part.rank_axes == part.out_axes == ()
    assert set(part.batch_axes) == {"data", "model"}


def test_partition_nondividing_degrades_to_replicated():
    # r=6 not divisible by model=4: rank replicated, no collective emitted
    part = sh.cola_ae_partition(_env("baseline"), (8, 16, 64), (64, 6),
                                (6, 128), "embed", "ffw")
    assert part.rank_axes == ()
    assert part.zpre_spec == jax.sharding.PartitionSpec("data", None)


def test_vmem_guards_admit_per_shard_sites():
    """The guards run inside the shard_map body on *local* shapes: a site
    whose whole weights overflow the budget fits once its rank (baseline)
    or output (megatron) dim is sharded 16-way."""
    # (2048, 2048, 2048) bf16: A+B whole = 16.8 MB > FWD_VMEM_BUDGET
    assert not cak.weights_fit_vmem(2048, 2048, 2048)
    assert cak.weights_fit_vmem(2048, 2048 // 16, 2048)   # rank shard
    assert not cak.dw_fits_vmem(4096, 1024, 4096)
    assert cak.dw_fits_vmem(4096, 1024 // 16, 4096 // 16)


def test_collective_bytes_profile_ordering():
    """megatron moves r-dim f32 psums; baseline moves d-dim ones: for the
    paper regime r = d/4 megatron's modeled wire bytes are strictly lower,
    and fsdp is zero."""
    T, din, r, dout = 4096, 1024, 256, 1024
    got = {}
    for profile in PROFILES:
        env = _env(profile, data=2, model=8)
        part = sh.cola_ae_partition(env, (8, T // 8, din), (din, r),
                                    (r, dout), "embed", "ffw")
        got[profile] = sh.cola_ae_collective_bytes(env, part, T, din, r,
                                                   dout)
    assert got["fsdp"] == 0
    assert 0 < got["megatron"] < got["baseline"]
