import os
import sys

# Tests run on the default single CPU device (the dry-run sets its own
# XLA_FLAGS in a subprocess).  Distribution tests that need a small mesh
# re-exec themselves with xla_force_host_platform_device_count=8.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite (slow; run with REPRO_CHAOS=1 or "
        "-m chaos — skipped in the tier-1 pass)")


def pytest_collection_modifyitems(config, items):
    # chaos tests run in their own CI job; keep tier-1 fast unless the
    # user opts in via the env var or selects the marker explicitly
    if os.environ.get("REPRO_CHAOS") == "1":
        return
    if "chaos" in (config.getoption("-m") or ""):
        return
    skip = pytest.mark.skip(
        reason="chaos suite: set REPRO_CHAOS=1 or run with -m chaos")
    for item in items:
        if "chaos" in item.keywords:
            item.add_marker(skip)


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


class FakeMesh:
    """Stands in for jax.sharding.Mesh in resolution-only sharding tests:
    MeshEnv reads nothing but ``mesh.shape``, so arbitrary mesh geometries
    can be tested without allocating devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
