import os
import sys

# Tests run on the default single CPU device (the dry-run sets its own
# XLA_FLAGS in a subprocess).  Distribution tests that need a small mesh
# re-exec themselves with xla_force_host_platform_device_count=8.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.RandomState(0)


class FakeMesh:
    """Stands in for jax.sharding.Mesh in resolution-only sharding tests:
    MeshEnv reads nothing but ``mesh.shape``, so arbitrary mesh geometries
    can be tested without allocating devices."""

    def __init__(self, shape):
        self.shape = dict(shape)
