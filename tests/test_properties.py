"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import flops
from repro.config import get_config
from repro.data.synthetic import MarkovZipf
from repro.optim import adamw, compression, schedule
from repro.config import TrainConfig


@settings(max_examples=25, deadline=None)
@given(n=st.integers(64, 4096), d=st.sampled_from([256, 512, 1024, 2048]),
       ff_mult=st.floats(2.0, 4.0), r_frac=st.floats(0.05, 0.5))
def test_cola_flops_below_full_rank_under_crossover(n, d, ff_mult, r_frac):
    """Paper §3.3: CoLA < full-rank whenever r < crossover(d, d_ff)."""
    dff = int(ff_mult * d)
    r = max(1, int(r_frac * d))
    dims = flops.LayerDims(n=n, d=d, d_ff=dff, r=r)
    cross = (24 * d + 18 * dff) * d / (48 * d + 18 * (d + dff))
    if r < cross:
        assert flops.cola(dims) < flops.full_rank(dims)
    # LoRA is always lower-bounded by CoLA at equal rank (paper App. B)
    assert flops.lora(dims) > flops.cola(dims)


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 999), total=st.integers(10, 1000))
def test_cosine_schedule_bounds(step, total):
    lr = float(schedule.cosine_schedule(step, base_lr=1e-3,
                                        total_steps=total))
    assert 0.0 <= lr <= 1e-3 + 1e-9


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), step=st.integers(0, 1000))
def test_synthetic_data_deterministic(seed, step):
    src = MarkovZipf(512, seed=seed)
    a = src.batch(step, 2, 32)
    b = src.batch(step, 2, 32)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].min() >= 0 and a["tokens"].max() < 512
    # labels are next tokens
    c = src.batch(step + 1, 2, 32)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50))
def test_int8_quantization_error_bound(seed):
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(64, 32) * rng.uniform(0.1, 10), jnp.float32)
    q, s = compression.quantize(x)
    deq = compression.dequantize(q, s)
    assert float(jnp.abs(deq - x).max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_mean_preserving():
    """With error feedback, the long-run sum of transmitted grads tracks
    the true sum (compression bias is bounded, not accumulating)."""
    rng = np.random.RandomState(0)
    err = {"w": jnp.zeros((16, 16), jnp.float32)}
    true_sum = np.zeros((16, 16), np.float32)
    sent_sum = np.zeros((16, 16), np.float32)
    for t in range(50):
        g = {"w": jnp.asarray(rng.randn(16, 16) * 0.1, jnp.float32)}
        sent, err = compression.compress_with_feedback(g, err)
        true_sum += np.asarray(g["w"])
        sent_sum += np.asarray(sent["w"])
    resid = np.abs(true_sum - sent_sum).max()
    assert resid < 0.05  # bounded by one quantization step, not O(T)


def test_adamw_matches_numpy_oracle():
    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(8, 8), jnp.float32)}
    tc = TrainConfig(beta1=0.9, beta2=0.999, eps=1e-8, weight_decay=0.01)
    state = adamw.adamw_init(p)
    m = np.zeros((8, 8)); v = np.zeros((8, 8))
    pw = np.asarray(p["w"]).copy()
    lr = 1e-2
    for t in range(1, 6):
        g = rng.randn(8, 8).astype(np.float32)
        p, state = adamw.adamw_update(tc, p, {"w": jnp.asarray(g)}, state,
                                      jnp.float32(lr))
        m = 0.9 * m + 0.1 * g
        v = 0.999 * v + 0.001 * g * g
        mh = m / (1 - 0.9**t)
        vh = v / (1 - 0.999**t)
        pw = pw - lr * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * pw)
    np.testing.assert_allclose(np.asarray(p["w"]), pw, rtol=1e-5, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(t=st.integers(8, 64), e=st.sampled_from([4, 8]),
       k=st.sampled_from([1, 2]), seed=st.integers(0, 20))
def test_moe_mass_conservation(t, e, k, seed):
    """Combine weights of kept tokens sum to ≤ 1 per token; no expert
    receives more than capacity tokens."""
    import dataclasses
    from repro.models import moe
    from repro.config import MoEConfig
    cfg = get_config("phi3.5-moe-42b-a6.6b").smoke()
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=e, top_k=k))
    model_d = cfg.d_model
    rng = np.random.RandomState(seed)
    x = jnp.asarray(rng.randn(1, t, model_d), jnp.float32)
    defs = moe.moe_defs(cfg)
    from repro.models.common import init_params
    params = init_params(defs, jax.random.PRNGKey(seed))
    y, aux = moe.moe_apply(cfg, params, x)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(y, np.float32)).all()
    assert 0.0 <= float(aux["moe_drop_frac"]) <= 1.0
    assert float(aux["moe_aux"]) >= 0.0


def test_effective_rank_invariants():
    from repro.core.rank_analysis import effective_rank
    rng = np.random.RandomState(0)
    # rank-r matrix has effective rank exactly r at alpha→1
    u = rng.randn(64, 4); v = rng.randn(4, 32)
    assert effective_rank(jnp.asarray(u @ v), 0.999) <= 4
    full = rng.randn(64, 32)
    assert effective_rank(jnp.asarray(full), 0.95) > 10


# --------------------------------------------------------------------------
# sharding resolution invariants (distributed/sharding.py)
#
# Pure-resolution properties need no devices: MeshEnv only reads
# ``mesh.shape``, so conftest.FakeMesh stands in for arbitrary geometries.
# --------------------------------------------------------------------------
from conftest import FakeMesh as _FakeMesh  # noqa: E402
from repro.distributed.sharding import (  # noqa: E402
    MeshEnv, _entry_axes, cola_ae_partition, logical_to_pspec, param_pspec)

_SHARD_PROFILES = ("baseline", "megatron", "fsdp")
_LOGICAL = ("batch", "seq", "embed", "heads", "kv_heads", "ffw", "rank",
            "vocab", "expert", "w_fsdp", "act_rank", "act_ffw", "head_dim",
            "null", None)
_SIZES = (1, 2, 3, 4, 6, 8, 16, 32, 96, 100, 128, 256, 1024)


@st.composite
def _sharding_case(draw):
    profile = draw(st.sampled_from(_SHARD_PROFILES))
    mesh = {"pod": draw(st.sampled_from([1, 2])),
            "data": draw(st.sampled_from([1, 2, 4])),
            "model": draw(st.sampled_from([1, 2, 4, 8, 16]))}
    n = draw(st.integers(1, 4))
    names = tuple(draw(st.sampled_from(_LOGICAL)) for _ in range(n))
    shape = tuple(draw(st.sampled_from(_SIZES)) for _ in range(n))
    return profile, mesh, names, shape


def _check_entries(spec, shape, mesh_shape):
    """Every resolved entry divides its dim; no mesh axis appears twice.
    Returns the total shard factor (so callers can check element counts)."""
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    seen = set()
    factor = 1
    for entry, size in zip(entries, shape):
        axes = _entry_axes(entry)
        prod = int(np.prod([mesh_shape[a] for a in axes])) if axes else 1
        assert size % prod == 0, (spec, shape)
        assert not (set(axes) & seen), (spec, shape)
        seen |= set(axes)
        factor *= prod
    return factor


@settings(max_examples=80, deadline=None)
@given(case=_sharding_case())
def test_resolve_dim_divides_and_never_reuses_axes(case):
    profile, mesh_shape, names, shape = case
    env = MeshEnv(_FakeMesh(mesh_shape), profile)
    spec = logical_to_pspec(names, shape, env)
    _check_entries(spec, shape, mesh_shape)


@settings(max_examples=80, deadline=None)
@given(case=_sharding_case())
def test_param_pspec_fsdp_fill_preserves_element_count(case):
    """The ZeRO-3 fill may only place axes on dims they divide: the global
    element count must equal local elements × total shard factor."""
    profile, mesh_shape, names, shape = case
    env = MeshEnv(_FakeMesh(mesh_shape), profile)
    spec = param_pspec(names, shape, env)
    factor = _check_entries(spec, shape, mesh_shape)
    total = int(np.prod(shape))
    assert total % factor == 0
    assert (total // factor) * factor == total


@st.composite
def _ae_site_case(draw):
    profile = draw(st.sampled_from(_SHARD_PROFILES))
    mesh = {"data": draw(st.sampled_from([1, 2, 4])),
            "model": draw(st.sampled_from([1, 2, 4, 8, 16]))}
    b = draw(st.sampled_from([1, 2, 4, 8, 16]))
    d_in = draw(st.sampled_from([16, 32, 64, 96, 128, 1024]))
    r = draw(st.sampled_from([4, 6, 16, 32, 96, 128]))
    d_out = draw(st.sampled_from([16, 32, 64, 96, 100, 128, 1024]))
    in_ax = draw(st.sampled_from(["embed", "ffw", "heads", "rank"]))
    out_ax = draw(st.sampled_from(["embed", "ffw", "heads", "kv_heads",
                                   "vocab"]))
    return profile, mesh, b, d_in, r, d_out, in_ax, out_ax


@settings(max_examples=80, deadline=None)
@given(case=_ae_site_case())
def test_cola_ae_partition_invariants(case):
    """The shard_map partitioning the fused TP path relies on: psum axis
    groups divide their dims; rank axes never collide with the in/out axes
    of the same factor; batch axes are disjoint from all weight axes; and
    the specs agree with the axis groups (A's col dim == B's row dim ==
    z_pre's rank dim)."""
    profile, mesh_shape, b, d_in, r, d_out, in_ax, out_ax = case
    env = MeshEnv(_FakeMesh(mesh_shape), profile)
    part = cola_ae_partition(env, (b, 16, d_in), (d_in, r), (r, d_out),
                             in_ax, out_ax)
    prod = lambda axes: int(np.prod([mesh_shape[a] for a in axes])) \
        if axes else 1
    assert d_in % prod(part.in_axes) == 0
    assert r % prod(part.rank_axes) == 0
    assert d_out % prod(part.out_axes) == 0
    assert b % prod(part.batch_axes) == 0
    assert not (set(part.rank_axes) & set(part.in_axes))
    assert not (set(part.rank_axes) & set(part.out_axes))
    assert not (set(part.batch_axes)
                & (set(part.in_axes) | set(part.rank_axes)
                   | set(part.out_axes)))
    assert _entry_axes(part.a_spec[0]) == part.in_axes
    assert _entry_axes(part.a_spec[1]) == part.rank_axes
    assert _entry_axes(part.b_spec[0]) == part.rank_axes
    assert _entry_axes(part.b_spec[1]) == part.out_axes
    assert _entry_axes(part.x_spec[2]) == part.in_axes
    assert _entry_axes(part.zpre_spec[1]) == part.rank_axes


# --------------------------------------------------------------------------
# Paged-KV allocator (serve/paging.py)
# --------------------------------------------------------------------------
from repro.serve.paging import PageAllocator  # noqa: E402


@st.composite
def _pool_trace(draw):
    """A pool shape plus a random admit/release trace over its slots."""
    page_size = draw(st.integers(1, 8))
    max_batch = draw(st.integers(1, 4))
    max_seq = draw(st.integers(4, 40))
    n_pages = draw(st.integers(2, 24))
    n_ops = draw(st.integers(1, 30))
    ops = [(draw(st.sampled_from(["admit", "release"])),
            draw(st.integers(0, max_batch - 1)),
            draw(st.integers(1, max_seq - 1)))
           for _ in range(n_ops)]
    return page_size, max_batch, max_seq, n_pages, ops


@settings(max_examples=120, deadline=None)
@given(case=_pool_trace())
def test_page_allocator_invariants_under_random_traces(case):
    """Any admit/release interleaving preserves the pool invariants: no
    double-allocation, conservation (free + live == n_pages - 1 with the
    sacrificial page never circulating), and every live slot's map row
    reconstructing exactly the dense layout's positions.  Failed admits
    (slot busy / pool exhausted) must not corrupt state either."""
    page_size, max_batch, max_seq, n_pages, ops = case
    alloc = PageAllocator(n_pages, page_size, max_batch, max_seq)
    for op, slot, span in ops:
        if op == "admit":
            if alloc.pages[slot] or not alloc.can_allocate(span):
                with pytest.raises(RuntimeError):
                    alloc.allocate(slot, span)
            else:
                rows = alloc.allocate(slot, span)
                # page-granular ownership covers the token span
                assert len(rows) == alloc.pages_needed(span) * page_size
                assert PageAllocator.SACRIFICIAL not in rows
        else:
            alloc.release(slot)
            # released rows are entirely sacrificial
            assert (alloc.page_map[slot] ==
                    PageAllocator.SACRIFICIAL).all()
        alloc.check_invariants()
        assert alloc.peak_pages <= alloc.capacity_pages


@settings(max_examples=60, deadline=None)
@given(case=_pool_trace(), seed=st.integers(0, 999))
def test_page_map_is_dense_equivalent(case, seed):
    """Writing token vectors through the page map and gathering them back
    reproduces a dense (B, max_seq) cache exactly, for every live span —
    including after slots are released and their pages recycled by other
    slots (recycled rows are re-zeroed, as the engine does at admit)."""
    page_size, max_batch, max_seq, n_pages, ops = case
    rng = np.random.RandomState(seed)
    alloc = PageAllocator(n_pages, page_size, max_batch, max_seq)
    pool = np.zeros((n_pages * page_size,), np.float64)
    dense = np.zeros((max_batch, max_seq), np.float64)
    spans = {i: 0 for i in range(max_batch)}
    for op, slot, span in ops:
        if op == "admit" and not alloc.pages[slot] \
                and alloc.can_allocate(span):
            rows = alloc.allocate(slot, span)
            pool[rows] = 0.0  # the engine's fresh-row wipe
            dense[slot] = 0.0
            vals = rng.randn(span)
            cols = np.arange(span)
            write = cols < max_seq - 1  # last col is the parking slot
            pool[alloc.page_map[slot, cols[write]]] = vals[write]
            dense[slot, cols[write]] = vals[write]
            spans[slot] = span
        elif op == "release":
            alloc.release(slot)
            spans[slot] = 0
        # every live slot gathers back its dense row (positions below the
        # parking column — the last column is sacrificial by design)
        for i in range(max_batch):
            if spans[i]:
                n = min(spans[i], max_seq - 1)
                got = pool[alloc.page_map[i, :n]]
                np.testing.assert_array_equal(got, dense[i, :n])
