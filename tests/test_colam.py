"""CoLA-M correctness: gradients under the save-only-low-rank remat policy
must be identical to no-remat (the paper's memory recipe is exact), and the
policy must actually save only r-dim tensors per block."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.models.model import build_model
from repro.train.step import build_loss_fn


def _grads(cfg, batch_seed=0):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(batch_seed)
    batch = {"tokens": jnp.asarray(rng.randint(1, 500, (2, 64)), jnp.int32),
             "labels": jnp.asarray(rng.randint(1, 500, (2, 64)), jnp.int32)}
    loss_fn = build_loss_fn(model)
    (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
    return float(loss), g


@pytest.mark.parametrize("policy", ["full", "cola_m", "dots"])
def test_remat_grads_identical(policy):
    """Remat must not change the math.  Tolerances are dtype-aware: the
    smoke model computes in bf16 over f32 master params, and CPU XLA may
    reassociate reductions between the remat and no-remat programs, so the
    float comparison gets an f32-appropriate bound here; the bitwise claim
    moved to the x64-only variant below (see memory note: the old
    atol=1e-6 assertion was flaky at seed)."""
    cfg0 = get_config("llama-60m").smoke().with_overrides(remat="none")
    cfg1 = cfg0.with_overrides(remat=policy)
    l0, g0 = _grads(cfg0)
    l1, g1 = _grads(cfg1)
    assert l0 == pytest.approx(l1, rel=1e-5)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


@pytest.mark.skipif(not jax.config.jax_enable_x64,
                    reason="bitwise remat-identity only claimed under x64 "
                           "(run with JAX_ENABLE_X64=1)")
@pytest.mark.parametrize("policy", ["full", "cola_m", "dots"])
def test_remat_grads_bitwise_x64(policy):
    """The strict form of the claim: with f64 accumulation the remat
    program replays the identical arithmetic, so gradients match bitwise."""
    cfg0 = get_config("llama-60m").smoke().with_overrides(remat="none")
    cfg1 = cfg0.with_overrides(remat=policy)
    l0, g0 = _grads(cfg0)
    l1, g1 = _grads(cfg1)
    assert l0 == l1
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_colam_saves_only_rank_dim():
    """Under cola_m, per-scan-step saved residuals must be the r-dim names
    plus the bf16 carry — nothing (b, s, d_ff)- or (s, s)-shaped."""
    import io, contextlib
    cfg = get_config("llama-60m").smoke().with_overrides(remat="cola_m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 64), jnp.int32),
             "labels": jnp.ones((2, 64), jnp.int32)}
    loss_fn = build_loss_fn(model)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        jax.ad_checkpoint.print_saved_residuals(loss_fn, params, batch)
    rank = cfg.rank_attn
    d_ff = cfg.d_ff
    per_layer_saves = [ln for ln in buf.getvalue().splitlines()
                       if "output of scan" in ln]
    # every per-layer named save is r-dim (…,rank]); the carry is (…,d]
    for ln in per_layer_saves:
        assert (f",{rank}]" in ln) or (f",{cfg.d_model}]" in ln), ln
        assert f",{d_ff}]" not in ln, f"d_ff-sized save leaked: {ln}"


def test_cola_m_memory_model():
    """Paper Table 4 arithmetic: M_CoLA-M << M_CoLA; recompute 4.6x less
    than GCP at LLaMA-1B scale with the paper's token batch n=256
    (Fig. 7; the ratio is n-dependent through the 4n²d SDP term)."""
    from repro.core import memory
    cfg = get_config("llama-1b")
    t = memory.model_totals(cfg, 4096)
    assert t["cola_m"] < 0.2 * t["cola"]
    assert t["vanilla_gcp"] < t["cola_m"]
    red = memory.recompute_reduction_vs_gcp(cfg, 256)
    assert 4.0 < red < 5.2  # paper reports 4.6x


def test_flops_model_paper_claims():
    """Paper §3.3: r=d/4 ⇒ CoLA ≈ 0.4-0.55× full-rank; crossover ≈ 0.62d;
    baselines lower-bounded by full-rank."""
    from repro.core import flops
    cfg = get_config("llama-1b")
    dims = flops.LayerDims.from_config(cfg, n=1024)
    c_full = flops.full_rank(dims)
    c_cola = flops.cola(dims)
    assert 0.3 < c_cola / c_full < 0.6
    assert flops.sltrain(dims) > c_full
    assert flops.galore(dims) > c_full
    assert flops.lora(dims) > c_cola
    assert 0.55 < flops.crossover_rank(cfg) / cfg.d_model < 0.7
