"""Quantized weight streaming tests: the int8/int4 decode kernels must be
*bit-identical* to the bf16 kernels run on dequantized factors (4 σ ×
int8/int4 × B ∈ {1, 8} ± biases, forced-tiny-budget streaming), the
`_plan_infer` weight_dtype routing + no-silent-fallback DISPATCH
allowlist, sharded (8-virtual-device) quant parity for all three TP site
shapes, spec-decode's draft-over-quantized paged-pool byte-identity, the
shared quant utilities (round-trip bound, nibble-packing bit-exactness,
PYTHONHASHSEED-independence of the scale layout, the lifted
optim/compression delegation), the `decode_hbm_traffic` weight_bits byte
model, and measured top-1 greedy agreement vs bf16 on a trained 12-layer
smoke model."""
import dataclasses
import hashlib
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.cola_ae import act as caa
from repro.kernels.cola_ae import kernel as cak
from repro.kernels.cola_ae import ops as cao
from repro.kernels.cola_ae import quant as q
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request

MULTI = jax.device_count() >= 8
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


# --------------------------------------------------------------------------
# quant utilities: round-trip bound, packing bit-exactness, shared core
# --------------------------------------------------------------------------
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("kind", ["in", "out"])
def test_quantize_factor_roundtrip_bound(kind, bits, rng):
    """Symmetric per-row/-column quantization: reconstruction error is
    bounded by half a quantization step everywhere (the rounding bound),
    and the scale layout matches the kind's streaming axis."""
    w = jnp.asarray(0.05 * rng.randn(64, 48), jnp.float32)
    qf = q.quantize_factor(w, kind, bits)
    assert qf.shape == w.shape and qf.ndim == 2  # logical, unpacked
    assert qf.scale.shape == ((64, 1) if kind == "in" else (1, 48))
    if bits == 4:
        packed = (32, 48) if kind == "in" else (64, 24)
        assert qf.q.shape == packed
    deq = np.asarray(q.dequantize(qf))
    step = np.asarray(qf.scale)
    assert np.all(np.abs(deq - np.asarray(w)) <= step / 2 + 1e-7)


def test_nibble_packing_bit_exact(rng):
    """pack → unpack is the identity on the full signed int4 grid, along
    either axis — including the extremes ±7 (sign-extension paths)."""
    vals = rng.randint(-7, 8, (6, 10)).astype(np.int8)
    vals[0, :2] = [-7, 7]
    for axis in (0, 1, -1, -2):
        packed = q.pack_nibbles(jnp.asarray(vals), axis=axis)
        assert packed.dtype == jnp.int8
        assert packed.shape[axis % 2] == vals.shape[axis % 2] // 2
        back = np.asarray(q.unpack_nibbles(packed, axis=axis))
        np.testing.assert_array_equal(back, vals)
    with pytest.raises(ValueError, match="even"):
        q.pack_nibbles(jnp.asarray(vals[:5]), axis=0)


def test_compression_quantize_lifted_onto_shared_core(rng):
    """optim/compression's quantize keeps its historic per-tensor scalar
    int8 behaviour bit-for-bit at the defaults, and its new axis/bits
    kwargs are the same implementation quant.py streams through."""
    from repro.optim import compression as comp
    x = jnp.asarray(rng.randn(13, 7), jnp.float32)
    qq, s = comp.quantize(x)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0  # the old math
    np.testing.assert_array_equal(np.asarray(s), np.asarray(scale))
    np.testing.assert_array_equal(
        np.asarray(qq),
        np.asarray(jnp.clip(jnp.round(x / scale), -127, 127), np.int8))
    q4, s4 = comp.quantize(x, bits=4, axis=-1)
    q4b, s4b = q.quantize_array(x, bits=4, axis=-1)
    np.testing.assert_array_equal(np.asarray(q4), np.asarray(q4b))
    np.testing.assert_array_equal(np.asarray(s4), np.asarray(s4b))
    assert s4.shape == (13, 1) and int(jnp.max(jnp.abs(q4))) <= 7


_SCALE_DIGEST_CODE = textwrap.dedent("""
    import sys; sys.path.insert(0, 'src')
    import hashlib
    import jax
    import numpy as np
    from repro.config import get_config
    from repro.kernels.cola_ae import quant as q
    from repro.models.model import build_model

    model = build_model(get_config("llama-60m").smoke())
    params = q.quantize_params(model.init(jax.random.PRNGKey(0)), bits=4)
    h = hashlib.sha256()
    leaves, treedef = jax.tree_util.tree_flatten_with_path(params)
    for path, leaf in leaves:
        h.update(str(path).encode())
        h.update(str(np.asarray(leaf).shape).encode())
        h.update(np.ascontiguousarray(np.asarray(leaf)).tobytes())
    print("DIGEST", h.hexdigest())
""")


def _scale_digest(hashseed):
    env = dict(os.environ, PYTHONHASHSEED=hashseed, JAX_PLATFORMS="cpu")
    r = subprocess.run([sys.executable, "-c", _SCALE_DIGEST_CODE], env=env,
                       capture_output=True, text=True, timeout=560,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout.strip().split()[-1]


def test_scale_layout_hashseed_independent():
    """quantize_params walks dicts in sorted order and the scale layout is
    a pure function of the weight values: two processes with different
    PYTHONHASHSEED must produce bit-identical q/scale trees (a TP fleet
    quantizes per-host; divergent layouts would shear the shards)."""
    assert _scale_digest("1") == _scale_digest("2")


# --------------------------------------------------------------------------
# quant kernels ≡ bf16 kernels on dequantized factors, bit for bit
# --------------------------------------------------------------------------
def _qsite(rng, dt, T, bits, din=192, r=48, dout=160):
    x = jnp.asarray(rng.randn(T, din), dt)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    qa = q.quantize_factor(a, "in", bits)
    qb = q.quantize_factor(b, "out", bits)
    da = q.dequantize(qa).astype(dt)
    db = q.dequantize(qb).astype(dt)
    return x, qa, qb, da, db


@pytest.mark.parametrize("B", [1, 8])
@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_decode_quant_bit_identical(sigma, bits, B, rng):
    """The quantized fused decode launch streams q-blocks + scales through
    the *same* weight grid as the bf16 kernel (block planning keys on the
    compute dtype) and dequantizes with the same elementwise expression —
    so its output is bit-identical to the bf16 kernel on dequantize(q)."""
    x, qa, qb, da, db = _qsite(rng, jnp.float32, B, bits)
    got = cak.cola_ae_decode_quant(x, qa, qb, sigma=sigma, interpret=True)
    want = cak.cola_ae_decode(x, da, db, sigma=sigma, interpret=True)
    assert got.shape == want.shape and got.dtype == want.dtype
    assert np.array_equal(np.asarray(got), np.asarray(want)), (sigma, bits, B)


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_decode_quant_bias_bit_identical(sigma, bits, rng):
    """Both biases fold into the quantized launch exactly as in the bf16
    twin (bias_a pre-σ, bias_b on the output tile)."""
    x, qa, qb, da, db = _qsite(rng, jnp.float32, 8, bits)
    ba = jnp.asarray(0.1 * rng.randn(48), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(160), jnp.float32)
    got = cak.cola_ae_decode_quant(x, qa, qb, ba, bb, sigma=sigma,
                                   interpret=True)
    want = cak.cola_ae_decode(x, da, db, ba, bb, sigma=sigma, interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want)), (sigma, bits)


@pytest.mark.parametrize("bits", [8, 4])
def test_decode_quant_stages_bit_identical(bits, rng):
    """The two-stage quant pipeline (the decode-split seam for megatron
    row-parallel sites): stage A emits the identical f32 z_pre, stage B
    the identical output tile."""
    x, qa, qb, da, db = _qsite(rng, jnp.float32, 8, bits)
    zp = cak.cola_ae_decode_stage_a_quant(x, qa, interpret=True)
    zp_want = cak.cola_ae_decode_stage_a(x, da, interpret=True)
    assert np.array_equal(np.asarray(zp), np.asarray(zp_want)), bits
    bb = jnp.asarray(0.1 * rng.randn(160), jnp.float32)
    out = cak.cola_ae_decode_stage_b_quant(zp, qb, bb, sigma="silu",
                                           out_dtype=x.dtype, interpret=True)
    out_want = cak.cola_ae_decode_stage_b(zp_want, db, bb, sigma="silu",
                                          out_dtype=x.dtype, interpret=True)
    assert np.array_equal(np.asarray(out), np.asarray(out_want)), bits


@pytest.mark.parametrize("bits", [8, 4])
def test_decode_quant_streams_weight_grid(bits, rng, monkeypatch):
    """Forced-tiny budget: the weight grid genuinely tiles (blocks below
    the dims on both phases) and bit-identity still holds — streaming
    never required whole-factor residency."""
    monkeypatch.setattr(cak, "FWD_VMEM_BUDGET", 48 * 1024)
    x, qa, qb, da, db = _qsite(rng, jnp.float32, 4, bits,
                               din=1024, r=96, dout=384)
    e = 4
    bi = cak._fit_block(1024, e * (8 + 96), 4 * 8 * 96,
                        cak.FWD_VMEM_BUDGET, cap=1024)
    assert bi < 1024 and 1024 % bi == 0  # it actually tiles
    got = cak.cola_ae_decode_quant(x, qa, qb, sigma="silu", interpret=True)
    want = cak.cola_ae_decode(x, da, db, sigma="silu", interpret=True)
    assert np.array_equal(np.asarray(got), np.asarray(want)), bits


# --------------------------------------------------------------------------
# ops routing: the weight_dtype axis, counters, and hard errors
# --------------------------------------------------------------------------
def test_quant_routing_decode_and_prefill(rng):
    """mode='infer' on QuantFactors: decode T dispatches the quant decode
    launch; prefill-grain T dequantizes whole factors once and rides the
    bf16 monolith (counted as its own plan, not as a bare bf16 decode)."""
    x, qa, qb, _, _ = _qsite(rng, jnp.float32, 1, 8)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        out = cao.cola_ae(x, qa, qb, mode="infer")
    assert cao.DISPATCH["quant_infer_decode"] == 1, dict(cao.DISPATCH)
    want = cao.cola_ae(x, q.dequantize(qa).astype(x.dtype),
                       q.dequantize(qb).astype(x.dtype), mode="infer",
                       impl="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    xT = jnp.asarray(rng.randn(cao.DECODE_T_MAX + 64, 192), jnp.float32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        cao.cola_ae(xT, qa, qb, mode="infer")
    d = dict(cao.DISPATCH)
    assert d["quant_infer_dequant_monolith"] == 1, d
    assert d.get("quant_infer_decode", 0) == 0, d


def test_quant_unroutable_is_an_error(rng):
    """No silent fallback: a quantized request that cannot reach the
    Pallas kernels (ref/XLA impl, or training) raises instead of quietly
    dequantizing into slower math."""
    x, qa, qb, _, _ = _qsite(rng, jnp.float32, 1, 8)
    with pytest.raises(ValueError, match="Pallas-only"):
        cao.cola_ae(x, qa, qb, mode="infer", impl="ref")
    with pytest.raises(ValueError, match="inference-only"):
        with cao.force_impl("pallas", True):
            cao.cola_ae(x, qa, qb, mode="train")


def _cfg():
    # f32 keeps greedy argmax robust to path-dependent rounding
    cfg = get_config("qwen2-1.5b").smoke().with_overrides(dtype="float32")
    return cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, use_fused_kernel=True))


def _deq_params(params):
    return jax.tree.map(
        lambda n: q.dequantize(n) if isinstance(n, q.QuantFactor) else n,
        params, is_leaf=lambda n: isinstance(n, q.QuantFactor))


def test_engine_quant_stream_and_dispatch_allowlist(rng):
    """Engine grain: an int8 engine's greedy stream is bit-identical to a
    bf16 engine built on the dequantized factors, every decode dispatch is
    a quant_ counter, and there are zero bare bf16 decode dispatches (the
    allowlist this PR's CI leg greps for)."""
    prompts = rng.randint(1, 512, (2, 9)).astype(np.int32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        qeng = make_engine(_cfg(), max_batch=2, max_seq=48, decode_block=4,
                           weight_dtype="int8")
        got, _ = qeng.generate(prompts, 6)
    d = dict(cao.DISPATCH)
    assert d.get("quant_infer_decode", 0) > 0, d
    for key, n in d.items():
        if "infer_decode" in key and n:
            assert "quant" in key, (key, d)  # no bare bf16 decode
        assert not key.endswith("_ref"), (key, d)
        assert not key.startswith(("fwd_", "bwd_")), (key, d)
    assert qeng.weight_dtype == "int8"
    with cao.force_impl("pallas", True):
        ref = make_engine(_cfg(), _deq_params(qeng.params), max_batch=2,
                          max_seq=48, decode_block=4)
        want, _ = ref.generate(prompts, 6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_quant_requires_factorized_sites():
    cfg = get_config("qwen2-1.5b").smoke().with_overrides(
        parameterization="dense")
    with pytest.raises(ValueError, match="factorized"):
        make_engine(cfg, max_batch=2, max_seq=48, weight_dtype="int8")


# --------------------------------------------------------------------------
# sharded parity: global-quantize-then-shard on an 8-virtual-device mesh
# --------------------------------------------------------------------------
@pytest.mark.skipif(MULTI, reason="already inside the multi-device run")
@pytest.mark.skipif(bool(os.environ.get("CI")),
                    reason="CI runs this in-process in the multidevice job")
def test_sharded_quant_reexecs_on_8_virtual_devices():
    """Local tier-1 entry point: run the sharded parity test on a mesh."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__,
         "-k", "sharded_quant_parity"],
        env=env, capture_output=True, text=True, timeout=1500,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-4000:]}\nstderr:\n{r.stderr[-2000:]}"


@needs_mesh
@pytest.mark.parametrize("bits", [8, 4])
def test_sharded_quant_parity(bits, rng):
    """Factors are quantized once globally, then the q/scale *arrays* are
    sharded.  For every TP site shape (baseline rank-sharded, megatron
    column- and row-parallel) the sharded quant output must be
    bit-identical to the sharded bf16 kernels on the dequantized factors
    under the same mesh (same collectives, same accumulation order) and
    match the single-device quant engine to f32 tolerance (psum reorders
    the rank reduction, so bitwise is not the right bar there)."""
    from repro.distributed import sharding as sh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    B, din, r, dout = 8, 256, 64, 192
    x = jnp.asarray(rng.randn(B, 1, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    ba = jnp.asarray(0.1 * rng.randn(r), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(dout), jnp.float32)
    qa = q.quantize_factor(a, "in", bits)
    qb = q.quantize_factor(b, "out", bits)
    da, db = q.dequantize(qa).astype(x.dtype), q.dequantize(qb).astype(x.dtype)
    with cao.force_impl("pallas", True):
        single = cao.cola_ae(x, qa, qb, bias_a=ba, bias_b=bb, mode="infer")
    # (profile, in_ax, out_ax, the split-seam counter expected?)
    shapes = [("baseline", "embed", "ffw", False),
              ("megatron", "embed", "ffw", False),   # column-parallel
              ("megatron", "ffw", "embed", True)]    # row-parallel
    for profile, in_ax, out_ax, splits in shapes:
        with sh.mesh_env(mesh, profile) as env:
            cao.reset_dispatch()
            with cao.force_impl("pallas", True):
                got = cao.cola_ae_sharded(
                    x, qa, qb, bias_a=ba, bias_b=bb, env=env,
                    in_ax=in_ax, out_ax=out_ax, mode="infer")
                want = cao.cola_ae_sharded(
                    x, da, db, bias_a=ba, bias_b=bb, env=env,
                    in_ax=in_ax, out_ax=out_ax, mode="infer")
        d = dict(cao.DISPATCH)
        key = ("quant_sharded_infer_decode_split" if splits
               else "quant_sharded_infer_decode")
        assert d.get(key, 0) > 0, (profile, in_ax, out_ax, d)
        assert np.array_equal(np.asarray(got), np.asarray(want)), \
            (profile, in_ax, out_ax, bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(single),
                                   rtol=1e-5, atol=1e-5)


@needs_mesh
def test_sharded_int4_odd_local_extent_is_an_error(rng):
    """int4 packs pairs along d_in/d_out: a shard whose local extent
    would be odd must be rejected at dispatch, not mis-unpacked."""
    from repro.distributed import sharding as sh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    din, r, dout = 36, 16, 64  # 36/4 = 9 local rows: odd
    x = jnp.asarray(rng.randn(4, 1, din), jnp.float32)
    qa = q.quantize_factor(
        jnp.asarray(0.05 * rng.randn(din, r), jnp.float32), "in", 4)
    qb = q.quantize_factor(
        jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32), "out", 4)
    with sh.mesh_env(mesh, "megatron") as env:
        with pytest.raises(ValueError, match="int4"):
            with cao.force_impl("pallas", True):
                cao.cola_ae_sharded(x, qa, qb, env=env, in_ax="ffw",
                                    out_ax="embed", mode="infer")


# --------------------------------------------------------------------------
# speculative decoding over quantized factors
# --------------------------------------------------------------------------
def _pool(eng):
    """Cache pool bytes minus the sacrificial page (page 0 absorbs
    unowned-position writes — scatter-order noise, not state)."""
    return [np.asarray(l)[:, eng.page_size:]
            for l in jax.tree.leaves(eng._caches)]


def test_spec_draft_over_quant_pool_byte_identity(rng):
    """The rank-truncated draft gathers q codes and shares scales (views,
    zero persistent HBM): a speculatively-served int8 engine must emit the
    plain int8 engine's exact stream and leave the paged KV pool
    byte-identical to the never-drafted run."""
    prompt = rng.randint(1, 512, (7,)).astype(np.int32)
    mk = lambda: [Request(uid=0, prompt=prompt, max_new_tokens=6)]
    # same seed → identical init → identical globally-quantized factors
    with cao.force_impl("pallas", True):
        plain = make_engine(_cfg(), max_batch=2, max_seq=48, decode_block=4,
                            weight_dtype="int8", seed=0)
        want = plain.serve(mk())
        cao.reset_dispatch()
        spec = make_engine(_cfg(), max_batch=2, max_seq=48, decode_block=4,
                           weight_dtype="int8", seed=0,
                           speculate=True, draft_alpha=0.95, spec_window=3)
        got = spec.serve(mk())
    for w, g in zip(want, got):
        assert g.finish_reason == w.finish_reason
        np.testing.assert_array_equal(g.tokens, w.tokens)
    s = spec.stats()
    assert s["spec_rounds"] > 0 and s["spec_drafted"] > 0
    for ls, lp in zip(_pool(spec), _pool(plain)):
        np.testing.assert_array_equal(ls, lp)
    d = dict(cao.DISPATCH)
    assert any(k.startswith("draft_quant_") and v for k, v in d.items()), d
    assert any(k.startswith("verify_quant_") and v for k, v in d.items()), d


# --------------------------------------------------------------------------
# the byte model: weight_bits charges packing + scale bytes honestly
# --------------------------------------------------------------------------
def test_decode_hbm_traffic_weight_bits():
    """At the llama-1b o-proj-class site the *weight-stream* term (total
    minus the activation bytes) drops ≥1.8x at int8 and ≥3.2x at int4 —
    less than the raw 2x/4x because the f32 per-row/-column scales are
    charged, exactly one per streamed d_in row + d_out column."""
    T, din, r, dout = 1, 2048, 512, 2048
    act = 2 * (T * din + T * dout)  # bf16 activation bytes, both paths
    base = cak.decode_hbm_traffic(T, din, r, dout)
    w = r * (din + dout)
    assert base - act == 2 * w  # bf16: weight stream is pure bf16 bytes
    for bits, floor in ((8, 1.8), (4, 3.2)):
        qt = cak.decode_hbm_traffic(T, din, r, dout, weight_bits=bits)
        assert qt - act == (w * bits + 7) // 8 + 4 * (din + dout)
        ratio = (base - act) / (qt - act)
        assert ratio >= floor, (bits, ratio)
    # split accounting carries the same scale terms per stage
    sa = cak.decode_hbm_traffic(T, din, r, dout, split=True, weight_bits=4)
    assert sa < cak.decode_hbm_traffic(T, din, r, dout, split=True)


def test_draft_byte_model_weight_bits():
    """Rank truncation shrinks the q-code bytes but NOT the scale bytes
    (one scale per d_in row / d_out column survives any rank cut) — the
    draft byte model must say so."""
    from repro.serve import draft as dm
    full = dm._site_stream_bytes(64, 256, 192, 2, 8)
    half = dm._site_stream_bytes(32, 256, 192, 2, 8)
    scales = 4 * (256 + 192)
    assert full - scales == 2 * (half - scales)  # q codes halve
    assert half > scales  # scales never truncate
    bf16 = dm._site_stream_bytes(64, 256, 192, 2, None)
    assert bf16 == 2 * 64 * (256 + 192)


# --------------------------------------------------------------------------
# measured quality: top-1 greedy agreement vs bf16 on a trained model
# --------------------------------------------------------------------------
def top1_agreement(got, want):
    """Per-step top-1 agreement between two greedy streams: a position
    counts only while its row's prefixes still match (identical context
    → the comparison really is argmax-vs-argmax; after a divergence the
    contexts differ and neither token is 'wrong')."""
    same = np.asarray(got) == np.asarray(want)
    ctx_ok = np.cumprod(
        np.concatenate([np.ones((same.shape[0], 1), bool), same[:, :-1]],
                       axis=1), axis=1).astype(bool)
    return float(same[ctx_ok].mean())


def test_top1_agreement_int8_trained_12l():
    """int8 quantization must not change what the model says: on a
    12-layer smoke model trained to low loss on a high-determinism corpus,
    the int8 engine's greedy argmax agrees with the bf16 engine's on
    ≥95% of same-context decode steps."""
    from repro.config import TrainConfig
    from repro.data.synthetic import MarkovZipf
    from repro.train.loop import train
    mc = get_config("llama-60m").smoke().with_overrides(num_layers=12)
    tc = TrainConfig(steps=120, global_batch=8, seq_len=128,
                     data="markov:0.95", log_every=100)
    params = train(mc, tc)["state"].params
    prompts = MarkovZipf(mc.vocab_size, seed=0,
                         markov_p=0.95).batch(999, 8, 16)["tokens"]
    prompts = np.asarray(prompts, np.int32)
    base = make_engine(mc, params, max_batch=8, max_seq=64, decode_block=8)
    want, _ = base.generate(prompts, 16)
    with cao.force_impl("pallas", True):
        qeng = make_engine(mc, params, max_batch=8, max_seq=64,
                           decode_block=8, weight_dtype="int8")
        got, _ = qeng.generate(prompts, 16)
    agree = top1_agreement(got, want)
    assert agree >= 0.95, agree
