"""End-to-end behaviour tests: train loop learns, baselines train,
checkpoint/resume is exact, serve engine generates."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.models.model import build_model
from repro.serve.engine import make_engine
from repro.train.loop import train


def test_train_loss_decreases(tmp_path):
    cfg = get_config("llama-60m").smoke()
    tc = TrainConfig(steps=30, global_batch=4, seq_len=64,
                     learning_rate=3e-3, log_every=0)
    out = train(cfg, tc)
    assert out["final_step"] == 30
    assert out["ce_loss"] < 6.0  # ln(512) ≈ 6.24 at init


def test_full_rank_baseline_trains(tmp_path):
    cfg = get_config("llama-60m").smoke().with_overrides(
        parameterization="dense")
    tc = TrainConfig(steps=10, global_batch=4, seq_len=64, log_every=0)
    out = train(cfg, tc)
    assert np.isfinite(out["ce_loss"])


@pytest.mark.parametrize("param", ["lora", "sltrain"])
def test_baseline_parameterizations_train(param):
    cfg = get_config("llama-60m").smoke().with_overrides(
        parameterization=param)
    tc = TrainConfig(steps=6, global_batch=2, seq_len=64, log_every=0)
    out = train(cfg, tc)
    assert np.isfinite(out["ce_loss"])


def test_galore_trains():
    cfg = get_config("llama-60m").smoke().with_overrides(
        parameterization="dense")
    tc = TrainConfig(steps=6, global_batch=2, seq_len=64, log_every=0,
                     galore_rank=8, galore_update_every=4)
    out = train(cfg, tc)
    assert np.isfinite(out["ce_loss"])


def test_checkpoint_resume_exact(tmp_path):
    """10 straight steps == 5 steps + preemption + resume for 5 more
    (same LR-schedule horizon; deterministic data)."""
    cfg = get_config("llama-60m").smoke()
    kw = dict(global_batch=2, seq_len=32, log_every=0,
              checkpoint_dir=str(tmp_path / "ckpt"), checkpoint_every=100,
              async_checkpoint=False)
    out_a = train(cfg, TrainConfig(steps=10, **kw))
    import shutil
    shutil.rmtree(tmp_path / "ckpt")
    train(cfg, TrainConfig(steps=10, stop_after=5, **kw))  # "preempted"
    out_b = train(cfg, TrainConfig(steps=10, **kw))        # auto-resumes
    a = jax.tree.leaves(out_a["state"].params)
    b = jax.tree.leaves(out_b["state"].params)
    for x, y in zip(a, b):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=2e-5, atol=2e-5)


def test_serve_generates():
    cfg = get_config("qwen2-1.5b").smoke()
    eng = make_engine(cfg, max_batch=2, max_seq=64)
    prompts = np.ones((2, 8), np.int32)
    toks, stats = eng.generate(prompts, max_new_tokens=6)
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < cfg.padded_vocab).all()
    assert stats["decode_tok_per_s"] > 0


def test_microbatch_accumulation_matches():
    cfg = get_config("llama-60m").smoke()
    tc1 = TrainConfig(steps=3, global_batch=4, seq_len=32, log_every=0)
    tc2 = TrainConfig(steps=3, global_batch=4, seq_len=32, log_every=0,
                      microbatch=2)
    o1 = train(cfg, tc1)
    o2 = train(cfg, tc2)
    assert abs(o1["ce_loss"] - o2["ce_loss"]) < 0.05


def test_grad_compression_trains():
    cfg = get_config("llama-60m").smoke()
    tc = TrainConfig(steps=6, global_batch=2, seq_len=32, log_every=0,
                     grad_compression="int8")
    out = train(cfg, tc)
    assert np.isfinite(out["ce_loss"])


def test_relora_merge_restart():
    import dataclasses
    cfg = get_config("llama-60m").smoke().with_overrides(
        parameterization="lora")
    cfg = dataclasses.replace(cfg, lora=dataclasses.replace(
        cfg.lora, relora_every=3))
    tc = TrainConfig(steps=7, global_batch=2, seq_len=32, log_every=0)
    out = train(cfg, tc)
    assert np.isfinite(out["ce_loss"])
