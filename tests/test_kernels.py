"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp ref
oracles, swept over shapes and dtypes (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cola_ae import kernel as cak, ops as cao, ref as car
from repro.kernels.flash_attn import kernel as fak, ref as far
from repro.kernels.mamba_scan import kernel as msk, ref as msr
from repro.kernels.rwkv6_scan import kernel as rwk, ref as rwr


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------- cola_ae
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(128, 256, 64, 256), (256, 512, 128, 512),
                                   (192, 1024, 128, 384), (130, 256, 96, 512)])
def test_cola_ae_pallas_matches_ref(shape, dtype, rng):
    T, din, r, dout = shape
    x = jnp.asarray(rng.randn(T, din), dtype)
    a = jnp.asarray(0.05 * rng.randn(din, r), dtype)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dtype)
    for sigma in (True, False):
        got = cak.cola_ae_fwd(x, a, b, sigma=sigma, interpret=True)
        want = car.cola_ae(x, a, b, sigma=sigma)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), **_tol(dtype))


def test_cola_ae_custom_vjp_matches_autodiff(rng):
    x = jnp.asarray(rng.randn(64, 128), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(128, 32), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(32, 96), jnp.float32)
    f_op = lambda *t: (cao.cola_ae(*t, impl="ref") ** 2).sum()
    f_rf = lambda *t: (car.cola_ae(*t) ** 2).sum()
    g_op = jax.grad(f_op, argnums=(0, 1, 2))(x, a, b)
    g_rf = jax.grad(f_rf, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(g_op, g_rf):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=1e-5, atol=1e-5)


def test_cola_ae_3d_and_bias(rng):
    x = jnp.asarray(rng.randn(2, 32, 64), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(64, 16), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(16, 48), jnp.float32)
    ba = jnp.asarray(0.01 * rng.randn(16), jnp.float32)
    bb = jnp.asarray(0.01 * rng.randn(48), jnp.float32)
    out = cao.cola_ae(x, a, b, bias_a=ba, bias_b=bb, impl="ref")
    z = jnp.einsum("bsd,dr->bsr", x, a) + ba
    z = z * jax.nn.sigmoid(z)
    want = jnp.einsum("bsr,ro->bso", z, b) + bb
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- flash_attn
def _dense_attn(q, k, v, qpos):
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    s = s / np.sqrt(hd)
    ok = jnp.arange(skv)[None, None, :] <= qpos[:, :, None]
    s = jnp.where(ok[:, None, None, :, :], s, -1e30)
    w = jax.nn.softmax(s, -1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(q.dtype), v)
    return o.reshape(b, sq, h, hd)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("dims", [(2, 128, 128, 4, 2, 32),
                                  (1, 64, 320, 8, 4, 64),
                                  (2, 96, 96, 4, 4, 16)])
def test_flash_ref_and_pallas_match_dense(dims, dtype, rng):
    b, sq, skv, h, kvh, hd = dims
    q = jnp.asarray(rng.randn(b, sq, h, hd), dtype)
    k = jnp.asarray(rng.randn(b, skv, kvh, hd), dtype)
    v = jnp.asarray(rng.randn(b, skv, kvh, hd), dtype)
    qpos = jnp.asarray(rng.randint(0, skv, (b, sq)), jnp.int32)
    want = _dense_attn(q, k, v, qpos)
    got_ref = far.flash_attention(q, k, v, True, qpos, (32, 64))
    got_pal = fak.flash_attention(q, k, v, q_positions=qpos, interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    np.testing.assert_allclose(np.asarray(got_pal, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_ref_grads_match_dense(rng):
    b, sq, skv, h, kvh, hd = 1, 64, 64, 4, 2, 16
    q = jnp.asarray(rng.randn(b, sq, h, hd), jnp.float32)
    k = jnp.asarray(rng.randn(b, skv, kvh, hd), jnp.float32)
    v = jnp.asarray(rng.randn(b, skv, kvh, hd), jnp.float32)
    qpos = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
    f1 = lambda q, k, v: (far.flash_attention(q, k, v, True, None, (16, 32))
                          ** 2).sum()
    f2 = lambda q, k, v: (_dense_attn(q, k, v, qpos) ** 2).sum()
    g1 = jax.grad(f1, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f2, argnums=(0, 1, 2))(q, k, v)
    for u, v_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(u), np.asarray(v_),
                                   rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------- rwkv6/mamba
@pytest.mark.parametrize("dims", [(2, 64, 2, 16), (1, 96, 4, 32),
                                  (2, 40, 2, 64)])
def test_wkv6_pallas_matches_ref(dims, rng):
    b, s, h, dh = dims
    r = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    k = jnp.asarray(0.3 * rng.randn(b, s, h, dh), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, dh), jnp.float32)
    w = jnp.asarray(rng.uniform(0.3, 0.99, (b, s, h, dh)), jnp.float32)
    u = jnp.asarray(0.1 * rng.randn(h, dh), jnp.float32)
    s0 = jnp.asarray(0.1 * rng.randn(b, h, dh, dh), jnp.float32)
    y1, S1 = rwk.wkv6(r, k, v, w, u, s0, seq_chunk=32, interpret=True)
    y2, S2 = rwr.wkv6(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(S1), np.asarray(S2),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_chunked_equals_unchunked(rng):
    """State handoff across sequence chunks is exact."""
    b, s, h, dh = 1, 64, 2, 16
    args = [jnp.asarray(rng.randn(b, s, h, dh), jnp.float32) for _ in range(3)]
    w = jnp.asarray(rng.uniform(0.5, 0.99, (b, s, h, dh)), jnp.float32)
    u = jnp.asarray(0.1 * rng.randn(h, dh), jnp.float32)
    y1, S1 = rwk.wkv6(args[0], args[1], args[2], w, u, seq_chunk=16,
                      interpret=True)
    y2, S2 = rwk.wkv6(args[0], args[1], args[2], w, u, seq_chunk=64,
                      interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("dims", [(2, 64, 128, 8), (1, 96, 256, 16)])
def test_mamba_pallas_matches_ref(dims, rng):
    b, s, di, N = dims
    x = jnp.asarray(rng.randn(b, s, di), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (b, s, di)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (di, N)), jnp.float32)
    B = jnp.asarray(rng.randn(b, s, N), jnp.float32)
    C = jnp.asarray(rng.randn(b, s, N), jnp.float32)
    D = jnp.asarray(rng.randn(di), jnp.float32)
    h0 = jnp.asarray(0.1 * rng.randn(b, di, N), jnp.float32)
    y1, h1 = msk.selective_scan(x, dt, A, B, C, D, h0, seq_chunk=32,
                                d_block=64, interpret=True)
    y2, h2 = msr.selective_scan(x, dt, A, B, C, D, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                               rtol=1e-4, atol=1e-4)
