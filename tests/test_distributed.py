"""Distribution tests on an 8-device CPU mesh (subprocess so the device
count doesn't leak into other tests): sharding rules, sharded train step,
elastic restore across mesh shapes, flash-decode collective, pipeline
stage loop, compressed psum, straggler watchdog."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest


def _run(code: str):
    full = ("import os\n"
            "os.environ['XLA_FLAGS'] = "
            "'--xla_force_host_platform_device_count=8'\n"
            "import sys; sys.path.insert(0, 'src')\n" + textwrap.dedent(code))
    r = subprocess.run([sys.executable, "-c", full], capture_output=True,
                       text=True, cwd=".", timeout=560)
    assert r.returncode == 0 and "OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"


def test_sharding_rules_divisibility_fallback():
    _run("""
        import jax
        from repro.distributed.sharding import mesh_env, logical_to_pspec, param_pspec
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_env(mesh, "megatron"):
            # heads=6 not divisible by model=4 -> replicated
            ps = logical_to_pspec(("batch", "seq", "heads", "head_dim"),
                                  (8, 16, 6, 64))
            assert ps == jax.sharding.PartitionSpec("data"), ps
            # divisible heads shard
            ps2 = logical_to_pspec(("batch", "seq", "heads", "head_dim"),
                                   (8, 16, 8, 64))
            assert ps2[2] == "model", ps2
            # FSDP fill puts 'data' on the largest unsharded weight dim
            ps3 = param_pspec(("embed", "rank"), (1024, 96))
            assert ps3[0] == "data", ps3
        print("OK")
    """)


def test_sharded_train_step_runs_and_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.config import TrainConfig, get_config
        from repro.distributed.sharding import mesh_env
        from repro.train.loop import train
        cfg = get_config("llama-60m").smoke()
        tc = TrainConfig(steps=5, global_batch=8, seq_len=64, log_every=0)
        out_single = train(cfg, tc)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_env(mesh, "megatron"):
            out_mesh = train(cfg, tc)
        a, b = out_single["ce_loss"], out_mesh["ce_loss"]
        assert abs(a - b) < 0.05, (a, b)
        print("OK", a, b)
    """)


def test_elastic_restore_across_meshes():
    _run("""
        import tempfile, jax, numpy as np
        from repro.config import TrainConfig, get_config
        from repro.distributed.sharding import mesh_env, MeshEnv
        from repro.distributed.elastic import resume_on_mesh
        from repro.train.loop import train
        d = tempfile.mkdtemp()
        cfg = get_config("llama-60m").smoke()
        tc = TrainConfig(steps=4, global_batch=8, seq_len=32, log_every=0,
                         checkpoint_dir=d, checkpoint_every=4,
                         async_checkpoint=False)
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_env(mesh8, "megatron"):
            out = train(cfg, tc)
        # resume the 8-device checkpoint on a 4-device mesh
        mesh4 = jax.make_mesh((4,), ("data",))
        env4 = MeshEnv(mesh4, "fsdp")
        with mesh_env(mesh4, "fsdp") as env:
            state, step = resume_on_mesh(d, cfg, tc, env)
        assert step == 4
        ref = jax.tree.leaves(out["state"].params)
        got = jax.tree.leaves(state.params)
        for x, y in zip(ref, got):
            np.testing.assert_allclose(np.asarray(x, np.float32),
                                       np.asarray(y, np.float32), rtol=1e-6)
        print("OK")
    """)


def test_flash_decode_collective():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.collectives import flash_decode_attention
        mesh = jax.make_mesh((8,), ("model",))
        b, S, h, kv, hd = 2, 64, 4, 2, 16
        rng = np.random.RandomState(0)
        q = jnp.asarray(rng.randn(b, 1, h, hd), jnp.float32)
        k = jnp.asarray(rng.randn(b, S, kv, hd), jnp.float32)
        v = jnp.asarray(rng.randn(b, S, kv, hd), jnp.float32)
        lengths = jnp.asarray([40, 64], jnp.int32)
        out = flash_decode_attention(mesh, q, k, v, lengths)
        # dense reference
        g = h // kv
        qg = q.reshape(b, 1, kv, g, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k) / np.sqrt(hd)
        msk = jnp.arange(S)[None, :] < lengths[:, None]
        s = jnp.where(msk[:, None, None, None, :], s, -1e30)
        w = jax.nn.softmax(s, -1)
        ref = jnp.einsum("bkgqs,bskh->bqkgh", w, v).reshape(b, 1, h, hd)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_pipeline_stage_loop():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.pipeline import pipeline_forward
        mesh = jax.make_mesh((4,), ("stage",))
        n_stage, num_micro, mb, d = 4, 8, 4, 16
        rng = np.random.RandomState(0)
        ws = jnp.asarray(rng.randn(n_stage, d, d) * 0.1, jnp.float32)
        def stage_fn(p, x):
            return jnp.tanh(x @ p["w"])
        x = jnp.asarray(rng.randn(num_micro * mb, d), jnp.float32)
        got = pipeline_forward(mesh, "stage", stage_fn, {"w": ws}, x,
                               num_micro)
        ref = x
        for i in range(n_stage):
            ref = jnp.tanh(ref @ ws[i])
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
        print("OK")
    """)


def test_compressed_psum_int8():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.optim.compression import compressed_psum
        mesh = jax.make_mesh((8,), ("d",))
        rng = np.random.RandomState(0)
        x = jnp.asarray(rng.randn(32, 16), jnp.float32)
        # replicated input: psum over 8 ranks = 8x
        out = compressed_psum(mesh, "d", {"g": x})
        ref = 8 * np.asarray(x)
        err = np.abs(np.asarray(out["g"]) - ref).max()
        scale = np.abs(ref).max()
        assert err < 0.02 * scale, (err, scale)
        print("OK")
    """)


def test_straggler_watchdog():
    from repro.distributed.straggler import StepWatchdog
    events = []
    wd = StepWatchdog(threshold=2.0, warmup_steps=2,
                      on_straggler=lambda s, dt, avg: events.append(s))
    for i in range(10):
        wd.observe(i, 0.1)
    assert wd.observe(10, 0.5)  # 5x the average -> flagged
    assert events == [10]
    assert not wd.observe(11, 0.11)  # EWMA not poisoned by the outlier


def test_pipeline_stage_fn_matches_pp_off():
    """pipeline_forward(1 stage) == plain apply (degenerate case)."""
    pass
