"""Decode-subsystem engine tests: scan-engine vs python-loop greedy parity
(bit-identical token streams), one device dispatch per k decoded tokens,
ragged-prompt continuous batching with per-request isolation, EOS early
exit + slot recycling, fused-vs-unfused engine parity, and the dispatch
assertion that decode never silently takes a training-shaped kernel."""
import math

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.cola_ae import ops as cao
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request


def _cfg(**over):
    # f32 keeps greedy argmax robust to path-dependent rounding
    return get_config("qwen2-1.5b").smoke().with_overrides(
        dtype="float32", **over)


@pytest.fixture(scope="module")
def engine():
    return make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4)


def _prompts(rng, b, p, vocab=512):
    return rng.randint(1, vocab, (b, p)).astype(np.int32)


def test_scan_engine_matches_python_loop(engine, rng):
    """Greedy decode through the jitted lax.scan engine is token-for-token
    identical to the old one-dispatch-per-token Python loop."""
    prompts = _prompts(rng, 2, 8)
    toks, stats = engine.generate(prompts, 10)
    ref, _ = engine.generate_python_loop(prompts, 10)
    assert toks.shape == (2, 10)
    np.testing.assert_array_equal(toks, ref)


def test_one_dispatch_per_k_tokens(engine, rng):
    """The engine issues exactly ceil((n-1)/k) decode dispatches (the
    first token comes out of the admission prefill) — counted at the
    jitted-call boundary — and, with variable-k chunks, scans exactly
    n-1 decode steps for an equal-budget batch: finished slots never
    burn dead steps."""
    for n in (5, 9, 12):
        toks, stats = engine.generate(_prompts(rng, 2, 6), n)
        k = engine.decode_block
        assert stats["decode_dispatches"] == math.ceil((n - 1) / k), n
        assert stats["prefill_dispatches"] == 1
        assert stats["decode_steps"] == n - 1, n
        assert toks.shape == (2, n)


def test_ragged_prompts_isolated_and_recycled(engine, rng):
    """Continuous batching over ragged left-padded prompts: more requests
    than slots, every request's stream is bit-identical to its solo run
    (slot recycling leaks nothing across tenants)."""
    reqs = [Request(uid=i, prompt=_prompts(rng, 1, L)[0], max_new_tokens=6)
            for i, L in enumerate([5, 9, 3, 12])]
    resps = engine.serve(reqs)
    assert [r.uid for r in resps] == [0, 1, 2, 3]
    for r, q in zip(resps, reqs):
        assert r.finish_reason == "length" and len(r.tokens) == 6
        solo, _ = engine.generate(q.prompt[None, :], 6)
        np.testing.assert_array_equal(solo[0], r.tokens), r.uid


def test_eos_early_exit_and_slot_reuse(engine, rng):
    """An EOS mid-stream truncates the request (EOS token included),
    frees the slot, and the freed slot serves a queued request whose
    stream is unperturbed."""
    p = _prompts(rng, 1, 7)[0]
    base = engine.serve([Request(uid=0, prompt=p, max_new_tokens=8)])[0]
    eos = int(base.tokens[3])
    first = base.tokens.tolist().index(eos)
    follower = _prompts(rng, 1, 4)[0]
    want_follower, _ = engine.generate(follower[None, :], 8)
    resps = engine.serve([
        Request(uid=0, prompt=p, max_new_tokens=8, eos_id=eos),
        Request(uid=1, prompt=p, max_new_tokens=8, eos_id=eos),
        Request(uid=2, prompt=follower, max_new_tokens=8),
    ])
    for r in resps[:2]:
        assert r.finish_reason == "eos"
        assert len(r.tokens) == first + 1 and r.tokens[-1] == eos
    assert resps[2].finish_reason == "length"
    np.testing.assert_array_equal(resps[2].tokens, want_follower[0])


def test_scheduler_rejects_oversize_and_ragged_recurrent(rng):
    eng = make_engine(_cfg(), max_batch=2, max_seq=32, decode_block=4)
    with pytest.raises(ValueError, match="max_seq"):
        eng.serve([Request(uid=0, prompt=_prompts(rng, 1, 20)[0],
                           max_new_tokens=16)])
    rcfg = get_config("rwkv6-7b").smoke().with_overrides(dtype="float32")
    reng = make_engine(rcfg, max_batch=2, max_seq=64, decode_block=4)
    with pytest.raises(ValueError, match="equal-length"):
        reng.serve([Request(uid=0, prompt=_prompts(rng, 1, 5)[0],
                            max_new_tokens=4),
                    Request(uid=1, prompt=_prompts(rng, 1, 9)[0],
                            max_new_tokens=4)])
    # equal-length recurrent serving still works (pad is zero)
    resps = reng.serve([Request(uid=i, prompt=_prompts(rng, 1, 6)[0],
                                max_new_tokens=4) for i in range(2)])
    assert all(len(r.tokens) == 4 for r in resps)


def test_engine_fused_vs_unfused_identical_tokens(rng):
    """Engine-level greedy parity: the fused infer path (decode kernel +
    no-residual prefill, interpret-mode Pallas on CPU) emits the exact
    token stream of the unfused einsum path."""
    prompts = _prompts(rng, 2, 8)

    def run(fused):
        import dataclasses
        cfg = _cfg()
        cfg = cfg.with_overrides(cola=dataclasses.replace(
            cfg.cola, use_fused_kernel=fused))
        eng = make_engine(cfg, max_batch=2, max_seq=64, decode_block=4)
        toks, _ = eng.generate(prompts, 6)
        return toks

    want = run(fused=False)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        got = run(fused=True)
    assert cao.DISPATCH["infer_decode"] > 0, dict(cao.DISPATCH)
    np.testing.assert_array_equal(got, want)


def test_paged_matches_dense_and_releases_pages(rng):
    """Paged KV (the default for attn-only archs) emits streams
    bit-identical to the dense (B, max_seq) slot layout, and every page
    returns to the pool once serving drains."""
    reqs = lambda: [Request(uid=i, prompt=_prompts(rng, 1, L)[0],
                            max_new_tokens=6)
                    for i, L in enumerate([5, 9, 3, 12])]
    rng_state = rng.get_state()
    dense_eng = make_engine(_cfg(), max_batch=2, max_seq=64,
                            decode_block=4, paged=False)
    want = {r.uid: r.tokens.tolist() for r in dense_eng.serve(reqs())}
    rng.set_state(rng_state)
    eng = make_engine(_cfg(), max_batch=2, max_seq=64, decode_block=4)
    assert eng.paged and not dense_eng.paged
    got = {r.uid: r.tokens.tolist() for r in eng.serve(reqs())}
    assert got == want
    stats = eng.stats()
    assert stats["pages_in_use"] == 0      # all released at finish
    assert stats["peak_pages"] > 0
    hbm = eng.cache_hbm_bytes()
    assert 0 < hbm["paged_bytes"] < hbm["dense_bytes"]
    eng.alloc.check_invariants()


def test_small_pool_admission_waits_for_compaction(rng):
    """A pool too small for all requests at once still serves everything:
    admission waits for live slots to release pages instead of failing,
    and a request that could never fit is rejected at submit."""
    # page_size 4, 6 usable pages: one (prompt 8 + new 6 = 14-token)
    # request needs 4 pages, so two can't be resident together
    eng = make_engine(_cfg(), max_batch=2, max_seq=32, decode_block=4,
                      page_size=4, n_pages=7)
    prompts = [_prompts(rng, 1, 8)[0] for _ in range(3)]
    want = []
    for p in prompts:
        solo = make_engine(_cfg(), max_batch=2, max_seq=32,
                           decode_block=4, page_size=4, n_pages=7)
        want.append(solo.serve([Request(uid=0, prompt=p,
                                        max_new_tokens=6)])[0]
                    .tokens.tolist())
    resps = eng.serve([Request(uid=i, prompt=p, max_new_tokens=6)
                       for i, p in enumerate(prompts)])
    assert [r.tokens.tolist() for r in resps] == want
    assert eng.stats()["peak_pages"] <= 6
    with pytest.raises(ValueError, match="pool"):
        eng.serve([Request(uid=0, prompt=_prompts(rng, 1, 20)[0],
                           max_new_tokens=9)])  # 29 tokens > 24-row pool


def test_decode_never_takes_training_kernel(rng):
    """Dispatch assertion for the whole serving stack: with the fused
    path forced onto Pallas, every AE execution is an infer-mode plan —
    zero training-shaped kernel dispatches (fwd_*/bwd_* counters), zero
    silent ref fallbacks, and the decode steps specifically dispatch
    `cola_ae_decode` (T = B×1 ≤ DECODE_T_MAX)."""
    import dataclasses
    cfg = _cfg()
    cfg = cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, use_fused_kernel=True))
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        eng = make_engine(cfg, max_batch=2, max_seq=64, decode_block=4)
        eng.serve([Request(uid=0, prompt=_prompts(rng, 1, 5)[0],
                           max_new_tokens=6),
                   Request(uid=1, prompt=_prompts(rng, 1, 9)[0],
                           max_new_tokens=6)])
    d = dict(cao.DISPATCH)
    assert d.get("infer_decode", 0) > 0, d          # the decode kernel ran
    assert d.get("infer_ref", 0) == 0, d            # no silent XLA math
    # training-shaped kernels never dispatched anywhere in the serve path
    for key in ("fwd_pallas", "fwd_monolith", "fwd_staged", "bwd_pallas",
                "bwd_monolith", "bwd_staged", "fwd_ref", "bwd_ref"):
        assert d.get(key, 0) == 0, (key, d)
