"""Fused CoLA auto-encoder backward: interpret-mode gradient parity vs
jax.grad of the jnp oracle (all four σ modes, bf16 + f32, non-multiple-of-
tile T), residual residency (only (x, z_pre) saved — no full-rank tensor),
and GEMM/kernel counts (exactly one A-GEMM in forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cola_ae import act as caa
from repro.kernels.cola_ae import kernel as cak
from repro.kernels.cola_ae import ops as cao
from repro.kernels.cola_ae import ref as car


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
@pytest.mark.parametrize("shape", [(130, 256, 64, 384),   # T % bt != 0
                                   (128, 128, 32, 256)])
def test_fused_bwd_matches_ref_grads(shape, sigma, dtype, rng):
    T, din, r, dout = shape
    x = jnp.asarray(rng.randn(T, din), dtype)
    a = jnp.asarray(0.05 * rng.randn(din, r), dtype)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dtype)
    f = lambda *t: (cao.cola_ae(*t, sigma=sigma, impl="pallas",
                                interpret=True) ** 2).sum()
    fr = lambda *t: (car.cola_ae(*t, sigma=sigma) ** 2).sum()
    got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    for u, v in zip(got, want):
        assert _rel(u, v) <= tol, (sigma, dtype, u.shape, _rel(u, v))


def test_non_128_multiple_dims_fully_covered(rng):
    """d_in/d_out not multiples of 128 must shrink the tile, not silently
    truncate the grid and leave output columns unwritten."""
    T, din, r, dout = 70, 192, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    f = lambda *t: (cao.cola_ae(*t, impl="pallas", interpret=True) ** 2).sum()
    fr = lambda *t: (car.cola_ae(*t) ** 2).sum()
    got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5


def test_fwd_kernel_emits_zpre(rng):
    T, din, r, dout = 130, 256, 64, 384
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    out, z_pre = cak.cola_ae_fwd(x, a, b, sigma="silu", interpret=True,
                                 return_zpre=True)
    assert z_pre.shape == (T, r) and z_pre.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(z_pre), np.asarray(jnp.dot(x, a)),
                               rtol=1e-5, atol=1e-5)
    # plain fwd (inference) stays available and identical
    out2 = cak.cola_ae_fwd(x, a, b, sigma="silu", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


def test_fused_vjp_saves_only_lowrank_residuals(rng):
    """The fused VJP saves (x, z_pre, a, b) — nothing (T, d_out)-shaped."""
    T, din, r, dout = 64, 128, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    f = lambda x, a, b: cao.cola_ae(x, a, b, impl="pallas", interpret=True)
    _, vjp_fn = jax.vjp(f, x, a, b)
    shapes = sorted(tuple(l.shape) for l in jax.tree_util.tree_leaves(vjp_fn))
    assert shapes == sorted([(T, din), (T, r), (din, r), (r, dout)])
    assert (T, dout) not in shapes  # no full-rank activation residual


def _count_prims(jaxpr, name, *, skip_inside=("pallas_call",)):
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        if eqn.primitive.name in skip_inside:
            continue
        for v in eqn.params.values():
            is_jx = lambda s: isinstance(
                s, (jax.extend.core.Jaxpr, jax.extend.core.ClosedJaxpr))
            for sub in jax.tree_util.tree_leaves(v, is_leaf=is_jx):
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    n += _count_prims(sub.jaxpr, name,
                                      skip_inside=skip_inside)
                elif isinstance(sub, jax.extend.core.Jaxpr):
                    n += _count_prims(sub, name, skip_inside=skip_inside)
    return n


def _args(rng):
    x = jnp.asarray(rng.randn(64, 128), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(128, 32), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(32, 192), jnp.float32)
    return x, a, b


def test_single_a_gemm_ref_path(rng):
    """fwd 2 GEMMs (x·A, z·B) + bwd 4 — no z_pre recompute under grad."""
    loss = lambda x, a, b: (cao.cola_ae(x, a, b, impl="ref") ** 2).sum()
    jx = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(*_args(rng))
    assert _count_prims(jx.jaxpr, "dot_general", skip_inside=()) == 6


def test_fused_path_is_three_kernels(rng):
    """grad(fused) = 1 fwd kernel + dx kernel + dA/dB kernel, 0 XLA GEMMs."""
    loss = lambda x, a, b: (cao.cola_ae(x, a, b, impl="pallas",
                                        interpret=True) ** 2).sum()
    jx = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(*_args(rng))
    assert _count_prims(jx.jaxpr, "pallas_call") == 3
    assert _count_prims(jx.jaxpr, "dot_general") == 0


def test_bwd_kernels_direct_parity(rng):
    """Drive the two backward kernels directly against the unfused math."""
    T, din, r, dout = 96, 128, 32, 256
    dt = jnp.float32
    x = jnp.asarray(rng.randn(T, din), dt)
    a = jnp.asarray(0.05 * rng.randn(din, r), dt)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dt)
    g = jnp.asarray(rng.randn(T, dout), dt)
    z_pre = jnp.dot(x, a).astype(jnp.float32)
    for sigma in caa.SIGMA_MODES:
        dsig = caa.act_grad(z_pre, sigma)
        dz = (jnp.dot(g, b.T).astype(jnp.float32) * dsig).astype(dt)
        dx = cak.cola_ae_bwd_dx(g, z_pre, a, b, sigma=sigma, interpret=True)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(jnp.dot(dz, a.T)),
                                   rtol=1e-5, atol=1e-5)
        da, db = cak.cola_ae_bwd_dw(x, g, z_pre, b, sigma=sigma,
                                    interpret=True)
        z = caa.apply_act(z_pre, sigma).astype(dt)
        np.testing.assert_allclose(np.asarray(da), np.asarray(jnp.dot(x.T, dz)),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(db), np.asarray(jnp.dot(z.T, g)),
                                   rtol=1e-5, atol=1e-5)


def test_dw_vmem_fallback_guard():
    assert cak.dw_fits_vmem(128, 32, 256)
    # internlm2 down-proj: f32 grad blocks are ~138 MB — monolith dA/dB out
    assert not cak.dw_fits_vmem(16384, 1536, 6144)
    # grad blocks exactly at budget but tiles/B push residency over
    assert not cak.dw_fits_vmem(8192, 128, 8192)


def test_planner_routes_by_shape_and_structure():
    """Over-VMEM sites (internlm2 down-proj) now plan 'staged' — never
    'ref'; small sites keep the monolith *including bias* (the fold);
    mid-pipeline collectives and bias grads force the staged pipeline.
    Infer mode adds the decode plan below the T threshold."""
    from repro.kernels.cola_ae.ops import (DECODE_T_MAX, _plan_bwd,
                                           _plan_fwd, _plan_infer)
    big_a = jax.ShapeDtypeStruct((16384, 1536), jnp.bfloat16)
    big_b = jax.ShapeDtypeStruct((1536, 6144), jnp.bfloat16)
    assert not cak.weights_fit_vmem(16384, 1536, 6144)
    assert _plan_fwd("pallas", big_a, big_b) == "staged"
    assert _plan_bwd("pallas", big_a, big_b) == "staged"
    small_a = jax.ShapeDtypeStruct((256, 64), jnp.bfloat16)
    small_b = jax.ShapeDtypeStruct((64, 384), jnp.bfloat16)
    assert _plan_fwd("pallas", small_a, small_b) == "monolith"
    # monolith bias fold: bias no longer forces the split in forward —
    # only the *backward* needs the dzl seam for the bias grads
    assert _plan_fwd("pallas", small_a, small_b, has_bias=True) == "monolith"
    assert _plan_fwd("pallas", small_a, small_b, mid_psum=True) == "staged"
    assert _plan_bwd("pallas", small_a, small_b, want_dbias=True) == "staged"
    assert _plan_bwd("pallas", small_a, small_b, mid_psum=True) == "staged"
    assert _plan_fwd("ref", small_a, small_b) == "ref"
    # infer: T at/below the threshold takes the GEMV decode launch (even
    # for over-VMEM sites — it streams weights); above it, the same
    # monolith/staged routing as the training forward
    assert _plan_infer("pallas", small_a, small_b, 1) == "decode"
    assert _plan_infer("pallas", small_a, small_b, DECODE_T_MAX) == "decode"
    assert _plan_infer("pallas", big_a, big_b, 8) == "decode"
    assert _plan_infer("pallas", small_a, small_b,
                       DECODE_T_MAX + 1) == "monolith"
    assert _plan_infer("pallas", big_a, big_b, 4096) == "staged"
    # row-parallel serving: the mid-pipeline z_pre psum takes the decode
    # kernel cut at the z seam below the T threshold, the training stage
    # pipeline above it; forcing the GEMV grain resolves to the split
    assert _plan_infer("pallas", small_a, small_b, 1,
                       mid_psum=True) == "decode_split"
    assert _plan_infer("pallas", small_a, small_b, DECODE_T_MAX,
                       mid_psum=True) == "decode_split"
    assert _plan_infer("pallas", small_a, small_b, DECODE_T_MAX + 1,
                       mid_psum=True) == "staged"
    assert _plan_infer("pallas:decode", small_a, small_b, 4096,
                       mid_psum=True) == "decode_split"
    assert _plan_infer("pallas:staged", small_a, small_b, 1,
                       mid_psum=True) == "staged"
    assert _plan_infer("ref", small_a, small_b, 1) == "ref"


# --------------------------------------------------------------------------
# two-stage pipeline: weight-grid tiling coverage
# --------------------------------------------------------------------------
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_staged_grad_parity_non_128_multiple_dims(sigma, rng):
    """Forced staged plan over d_in/d_out that are not 128-multiples: the
    weight-grid tiles must shrink to divide, never truncate."""
    T, din, r, dout = 70, 192, 48, 160
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    with cao.force_impl("pallas", True, plan="staged"):
        f = lambda *t: (cao.cola_ae(*t, sigma=sigma) ** 2).sum()
        got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    fr = lambda *t: (car.cola_ae(*t, sigma=sigma) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5, (sigma, u.shape, _rel(u, v))


def test_tiny_budget_streams_weight_grid(rng, monkeypatch):
    """Forced-tiny VMEM budgets: the planner must route the internlm2
    down-proj *shape class* (over-budget at every tile) through the
    streamed path, the weight-grid blocks must shrink below the dims, and
    gradients stay exact."""
    monkeypatch.setattr(cak, "FWD_VMEM_BUDGET", 64 * 1024)
    monkeypatch.setattr(cak, "DW_VMEM_BUDGET", 48 * 1024)
    T, din, r, dout = 48, 1024, 96, 384  # internlm2 down-proj, scaled
    assert not cak.weights_fit_vmem(din, r, dout, bytes_el=4)
    # the weight grid actually tiles: more than one block per weight dim
    bt = cak._pick_bt(T)
    bi = cak._fit_block(din, 4 * (bt + r), 4 * bt * r, cak.FWD_VMEM_BUDGET)
    assert bi < din and din % bi == 0
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        f = lambda *t: (cao.cola_ae(*t) ** 2).sum()
        got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    assert cao.DISPATCH["fwd_staged"] == 1
    assert cao.DISPATCH["bwd_staged"] == 1
    assert cao.DISPATCH["fwd_ref"] == 0 and cao.DISPATCH["bwd_ref"] == 0
    fr = lambda *t: (car.cola_ae(*t) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5


def test_monolith_dw_overflow_streams_not_xla(rng, monkeypatch):
    """dw over DW_VMEM_BUDGET while weights still fit FWD: the backward
    keeps the monolith dx kernel and streams dA/dB through the weight-grid
    kernels (old behavior: XLA GEMM fallback)."""
    monkeypatch.setattr(cak, "DW_VMEM_BUDGET", 32 * 1024)
    T, din, r, dout = 96, 256, 32, 192
    assert cak.weights_fit_vmem(din, r, dout, bytes_el=4)
    assert not cak.dw_fits_vmem(din, r, dout, bytes_el=4)
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        f = lambda *t: (cao.cola_ae(*t) ** 2).sum()
        got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    assert cao.DISPATCH["bwd_monolith"] == 1
    assert cao.DISPATCH["bwd_dw_streamed"] == 1
    fr = lambda *t: (car.cola_ae(*t) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5


def test_staged_bias_grad_parity(rng):
    """Bias sites ride the staged pipeline end to end: grads for x, A, B,
    bias_a (pre-σ) and bias_b (output) all match the oracle."""
    T, din, r, dout = 64, 128, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    ba = jnp.asarray(0.1 * rng.randn(r), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(dout), jnp.float32)
    for sigma in caa.SIGMA_MODES:
        with cao.force_impl("pallas", True):
            f = lambda *t: (cao.cola_ae(t[0], t[1], t[2], bias_a=t[3],
                                        bias_b=t[4], sigma=sigma) ** 2).sum()
            got = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, a, b, ba, bb)
        fr = lambda *t: (car.cola_ae(t[0], t[1], t[2], bias_a=t[3],
                                     bias_b=t[4], sigma=sigma) ** 2).sum()
        want = jax.grad(fr, argnums=(0, 1, 2, 3, 4))(x, a, b, ba, bb)
        for u, v in zip(got, want):
            assert _rel(u, v) <= 1e-5, (sigma, u.shape, _rel(u, v))


def test_staged_path_is_seven_kernels_zero_gemms(rng):
    """grad(staged) = stage_a + stage_b fwd, dzl + dz + dx + dA + dB bwd —
    seven Pallas launches (dz materialized once for the dA weight passes),
    zero XLA GEMMs (the bias-less case)."""
    with cao.force_impl(plan="staged"):
        loss = lambda x, a, b: (cao.cola_ae(x, a, b, impl="pallas",
                                            interpret=True) ** 2).sum()
        jx = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(*_args(rng))
    assert _count_prims(jx.jaxpr, "pallas_call") == 7
    assert _count_prims(jx.jaxpr, "dot_general") == 0


def test_staged_vjp_saves_only_lowrank_residuals(rng):
    """The staged VJP saves the same (x, z_pre, a, b) residual set as the
    monolith — the remat story is plan-independent."""
    T, din, r, dout = 64, 128, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    with cao.force_impl(plan="staged"):
        f = lambda x, a, b: cao.cola_ae(x, a, b, impl="pallas",
                                        interpret=True)
        _, vjp_fn = jax.vjp(f, x, a, b)
    shapes = sorted(tuple(l.shape) for l in jax.tree_util.tree_leaves(vjp_fn))
    assert shapes == sorted([(T, din), (T, r), (din, r), (r, dout)])
    assert (T, dout) not in shapes  # no full-rank activation residual


def test_local_model_bias_sites_stay_fused():
    """No mesh: a bias-carrying config (qwen2 qkv_bias) with use_fused
    routes every AE site through the fused planner — bias sites now take
    the monolith *forward* (bias folded into the kernel body) with the
    staged backward supplying the bias grads — and loss/grads match the
    unfused reference."""
    import dataclasses

    from repro.config import get_config
    from repro.models.model import build_model
    from repro.train.step import build_loss_fn

    def grads(fused):
        cfg = get_config("qwen2-1.5b").smoke().with_overrides(
            dtype="float32")
        cfg = cfg.with_overrides(cola=dataclasses.replace(
            cfg.cola, use_fused_kernel=fused))
        assert cfg.qkv_bias
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        batch = {"tokens": jnp.asarray(rng.randint(1, 500, (2, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.randint(1, 500, (2, 32)),
                                       jnp.int32)}
        loss_fn = build_loss_fn(model)
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                 batch)
        return float(loss), g

    l0, g0 = grads(fused=False)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        l1, g1 = grads(fused=True)
    assert cao.DISPATCH["apply_fused_local"] > 0
    # bias sites fold into the monolith fwd; their bwd rides the staged
    # kernels (the dzl seam yields dbias)
    assert cao.DISPATCH["fwd_monolith"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["bwd_staged"] > 0, dict(cao.DISPATCH)
    assert cao.DISPATCH["fwd_ref"] == 0 and cao.DISPATCH["bwd_ref"] == 0
    assert l0 == pytest.approx(l1, rel=1e-5)
    for u, v in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        assert _rel(u, v) <= 1e-4


def test_traffic_model_fused_below_unfused():
    for shape in [(4096, 1024, 256, 1024), (2048, 2048, 512, 5632)]:
        f = cak.hbm_traffic(*shape, fused=True)
        u = cak.hbm_traffic(*shape, fused=False)
        assert f < u, shape


def test_traffic_model_staged_pays_for_its_seams():
    """The split strictly pays vs the monolith (z_pre round-trips + weight
    re-streams) — that's the price of the collective/bias seams and of
    unbounded sites; the model must show it, not hide it.  The re-stream
    terms must also respond to the tile pickers: a shape with more token
    tiles models more weight traffic."""
    for shape in [(4096, 1024, 256, 1024), (2048, 2048, 512, 5632),
                  (4096, 16384, 1536, 6144)]:  # incl. internlm2 down-proj
        m = cak.hbm_traffic(*shape, path="monolith")
        s = cak.hbm_traffic(*shape, path="staged")
        assert m < s, (shape, m, s)
    # legacy bool alias still routes
    assert cak.hbm_traffic(2048, 512, 128, 512, fused=True) == \
        cak.hbm_traffic(2048, 512, 128, 512, path="monolith")
