"""Fused CoLA auto-encoder backward: interpret-mode gradient parity vs
jax.grad of the jnp oracle (all four σ modes, bf16 + f32, non-multiple-of-
tile T), residual residency (only (x, z_pre) saved — no full-rank tensor),
and GEMM/kernel counts (exactly one A-GEMM in forward)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cola_ae import act as caa
from repro.kernels.cola_ae import kernel as cak
from repro.kernels.cola_ae import ops as cao
from repro.kernels.cola_ae import ref as car


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-12)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
@pytest.mark.parametrize("shape", [(130, 256, 64, 384),   # T % bt != 0
                                   (128, 128, 32, 256)])
def test_fused_bwd_matches_ref_grads(shape, sigma, dtype, rng):
    T, din, r, dout = shape
    x = jnp.asarray(rng.randn(T, din), dtype)
    a = jnp.asarray(0.05 * rng.randn(din, r), dtype)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dtype)
    f = lambda *t: (cao.cola_ae(*t, sigma=sigma, impl="pallas",
                                interpret=True) ** 2).sum()
    fr = lambda *t: (car.cola_ae(*t, sigma=sigma) ** 2).sum()
    got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    tol = 1e-2 if dtype == jnp.bfloat16 else 1e-5
    for u, v in zip(got, want):
        assert _rel(u, v) <= tol, (sigma, dtype, u.shape, _rel(u, v))


def test_non_128_multiple_dims_fully_covered(rng):
    """d_in/d_out not multiples of 128 must shrink the tile, not silently
    truncate the grid and leave output columns unwritten."""
    T, din, r, dout = 70, 192, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    f = lambda *t: (cao.cola_ae(*t, impl="pallas", interpret=True) ** 2).sum()
    fr = lambda *t: (car.cola_ae(*t) ** 2).sum()
    got = jax.grad(f, argnums=(0, 1, 2))(x, a, b)
    want = jax.grad(fr, argnums=(0, 1, 2))(x, a, b)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5


def test_fwd_kernel_emits_zpre(rng):
    T, din, r, dout = 130, 256, 64, 384
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    out, z_pre = cak.cola_ae_fwd(x, a, b, sigma="silu", interpret=True,
                                 return_zpre=True)
    assert z_pre.shape == (T, r) and z_pre.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(z_pre), np.asarray(jnp.dot(x, a)),
                               rtol=1e-5, atol=1e-5)
    # plain fwd (inference) stays available and identical
    out2 = cak.cola_ae_fwd(x, a, b, sigma="silu", interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2))


def test_fused_vjp_saves_only_lowrank_residuals(rng):
    """The fused VJP saves (x, z_pre, a, b) — nothing (T, d_out)-shaped."""
    T, din, r, dout = 64, 128, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    f = lambda x, a, b: cao.cola_ae(x, a, b, impl="pallas", interpret=True)
    _, vjp_fn = jax.vjp(f, x, a, b)
    shapes = sorted(tuple(l.shape) for l in jax.tree_util.tree_leaves(vjp_fn))
    assert shapes == sorted([(T, din), (T, r), (din, r), (r, dout)])
    assert (T, dout) not in shapes  # no full-rank activation residual


def _count_prims(jaxpr, name, *, skip_inside=("pallas_call",)):
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        if eqn.primitive.name in skip_inside:
            continue
        for v in eqn.params.values():
            is_jx = lambda s: isinstance(
                s, (jax.extend.core.Jaxpr, jax.extend.core.ClosedJaxpr))
            for sub in jax.tree_util.tree_leaves(v, is_leaf=is_jx):
                if isinstance(sub, jax.extend.core.ClosedJaxpr):
                    n += _count_prims(sub.jaxpr, name,
                                      skip_inside=skip_inside)
                elif isinstance(sub, jax.extend.core.Jaxpr):
                    n += _count_prims(sub, name, skip_inside=skip_inside)
    return n


def _args(rng):
    x = jnp.asarray(rng.randn(64, 128), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(128, 32), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(32, 192), jnp.float32)
    return x, a, b


def test_single_a_gemm_ref_path(rng):
    """fwd 2 GEMMs (x·A, z·B) + bwd 4 — no z_pre recompute under grad."""
    loss = lambda x, a, b: (cao.cola_ae(x, a, b, impl="ref") ** 2).sum()
    jx = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(*_args(rng))
    assert _count_prims(jx.jaxpr, "dot_general", skip_inside=()) == 6


def test_fused_path_is_three_kernels(rng):
    """grad(fused) = 1 fwd kernel + dx kernel + dA/dB kernel, 0 XLA GEMMs."""
    loss = lambda x, a, b: (cao.cola_ae(x, a, b, impl="pallas",
                                        interpret=True) ** 2).sum()
    jx = jax.make_jaxpr(jax.grad(loss, argnums=(0, 1, 2)))(*_args(rng))
    assert _count_prims(jx.jaxpr, "pallas_call") == 3
    assert _count_prims(jx.jaxpr, "dot_general") == 0


def test_bwd_kernels_direct_parity(rng):
    """Drive the two backward kernels directly against the unfused math."""
    T, din, r, dout = 96, 128, 32, 256
    dt = jnp.float32
    x = jnp.asarray(rng.randn(T, din), dt)
    a = jnp.asarray(0.05 * rng.randn(din, r), dt)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dt)
    g = jnp.asarray(rng.randn(T, dout), dt)
    z_pre = jnp.dot(x, a).astype(jnp.float32)
    for sigma in caa.SIGMA_MODES:
        dsig = caa.act_grad(z_pre, sigma)
        dz = (jnp.dot(g, b.T).astype(jnp.float32) * dsig).astype(dt)
        dx = cak.cola_ae_bwd_dx(g, z_pre, a, b, sigma=sigma, interpret=True)
        np.testing.assert_allclose(np.asarray(dx), np.asarray(jnp.dot(dz, a.T)),
                                   rtol=1e-5, atol=1e-5)
        da, db = cak.cola_ae_bwd_dw(x, g, z_pre, b, sigma=sigma,
                                    interpret=True)
        z = caa.apply_act(z_pre, sigma).astype(dt)
        np.testing.assert_allclose(np.asarray(da), np.asarray(jnp.dot(x.T, dz)),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(db), np.asarray(jnp.dot(z.T, g)),
                                   rtol=1e-5, atol=1e-5)


def test_dw_vmem_fallback_guard():
    assert cak.dw_fits_vmem(128, 32, 256)
    # internlm2 down-proj: f32 grad blocks are ~138 MB — must fall back
    assert not cak.dw_fits_vmem(16384, 1536, 6144)
    # grad blocks exactly at budget but tiles/B push residency over
    assert not cak.dw_fits_vmem(8192, 128, 8192)


def test_weights_vmem_guard_routes_to_unfused(rng):
    assert cak.weights_fit_vmem(256, 64, 384)
    # internlm2 down-proj: A alone is 50 MB bf16 — whole-weight staging
    # cannot fit; ops must dispatch the unfused path for fwd AND bwd
    assert not cak.weights_fit_vmem(16384, 1536, 6144)
    from repro.kernels.cola_ae.ops import _resolve_impl
    big_a = jax.ShapeDtypeStruct((16384, 1536), jnp.bfloat16)
    big_b = jax.ShapeDtypeStruct((1536, 6144), jnp.bfloat16)
    assert _resolve_impl("pallas", big_a, big_b) == "ref"
    small_a = jax.ShapeDtypeStruct((256, 64), jnp.bfloat16)
    small_b = jax.ShapeDtypeStruct((64, 384), jnp.bfloat16)
    assert _resolve_impl("pallas", small_a, small_b) == "pallas"


def test_traffic_model_fused_below_unfused():
    for shape in [(4096, 1024, 256, 1024), (2048, 2048, 512, 5632)]:
        f = cak.hbm_traffic(*shape, fused=True)
        u = cak.hbm_traffic(*shape, fused=False)
        assert f < u, shape
