"""Distributed serving: the multi-device serve parity harness.

Proves the TP-sharded decode path end to end: a ServeEngine constructed
with ``mesh=``/``profile=`` traces every jitted dispatch under the
sharding MeshEnv, so each CoLA site routes through
``cola_ae_sharded(mode='infer')`` and the shard_map bodies run the
per-shard decode kernels (interpret-mode Pallas on CPU) with the
profile's collectives:

* **baseline** — rank-sharded A/B, `cola_ae_decode` per shard, exit psum
  over the rank axis (``sharded_infer_decode``),
* **megatron** — column-parallel B at qkv/up (``sharded_infer_decode``),
  row-parallel at o/down with the z_pre psum at the decode-split seam
  (``sharded_infer_decode_split``).

The parity bar is **bit-identical greedy token streams** against a
single-device engine — across profiles × ragged continuous batching ×
EOS early-exit — plus no-silent-fallback DISPATCH assertions: only
``sharded_infer_*`` counters, zero training-shaped kernels, zero ref
math.  Paged KV is on for both sides, so the page-table gather is under
the same parity bar.

Runs on an 8-virtual-device CPU mesh.  The CI multidevice job sets
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` at the job level
and runs everything here in-process; under plain single-device tier-1
the suite re-execs itself once in a subprocess with that flag.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.cola_ae import ops as cao
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request

MULTI = jax.device_count() >= 8
needs_mesh = pytest.mark.skipif(
    not MULTI, reason="needs 8 host devices "
    "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")

PROFILES = ("baseline", "megatron")


@pytest.mark.skipif(MULTI, reason="already inside the multi-device run")
@pytest.mark.skipif(bool(os.environ.get("CI")),
                    reason="CI runs this suite in-process in the "
                           "multidevice job; don't pay it twice")
def test_suite_reexecs_on_8_virtual_devices():
    """Local tier-1 entry point: run this whole file on an 8-device mesh."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", __file__],
        env=env, capture_output=True, text=True, timeout=1500,
        cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert r.returncode == 0, \
        f"stdout:\n{r.stdout[-4000:]}\nstderr:\n{r.stderr[-2000:]}"


def _cfg():
    # f32 keeps greedy argmax robust to path-dependent rounding, which is
    # what makes "bit-identical across collectives" a fair bar
    cfg = get_config("qwen2-1.5b").smoke().with_overrides(dtype="float32")
    return cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, use_fused_kernel=True))


def _mesh24():
    return jax.make_mesh((2, 4), ("data", "model"))


def _prompts(rng, lens, vocab=512):
    return [rng.randint(1, vocab, (L,)).astype(np.int32) for L in lens]


def _serve(mesh, profile, reqs, **eng_kw):
    eng = make_engine(_cfg(), max_batch=2, max_seq=48, decode_block=4,
                      mesh=mesh, profile=profile, **eng_kw)
    with cao.force_impl("pallas", True):
        resps = eng.serve(reqs)
    return eng, {r.uid: (r.tokens.tolist(), r.finish_reason)
                 for r in resps}


# --------------------------------------------------------------------------
# parity: sharded vs single-device greedy streams, bit for bit
# --------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_ragged_batched_parity(profile, rng):
    """4 ragged requests over 2 slots (continuous batching + slot
    recycling + page churn on both sides): every stream matches the
    single-device oracle token for token."""
    prompts = _prompts(rng, [5, 11, 3, 8])
    mk = lambda: [Request(uid=i, prompt=p, max_new_tokens=6)
                  for i, p in enumerate(prompts)]
    _, want = _serve(None, "baseline", mk())
    _, got = _serve(_mesh24(), profile, mk())
    assert got == want


@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_eos_early_exit_parity(profile, rng):
    """EOS mid-stream under the sharded engine: the request truncates at
    the same token, the recycled slot's follower stream is unperturbed,
    and everything matches the single-device run."""
    p, follower = _prompts(rng, [7, 4])
    base = _serve(None, "baseline",
                  [Request(uid=0, prompt=p, max_new_tokens=8)])[1]
    eos = base[0][0][3]
    mk = lambda: [Request(uid=0, prompt=p, max_new_tokens=8, eos_id=eos),
                  Request(uid=1, prompt=p, max_new_tokens=8, eos_id=eos),
                  Request(uid=2, prompt=follower, max_new_tokens=8)]
    _, want = _serve(None, "baseline", mk())
    _, got = _serve(_mesh24(), profile, mk())
    assert got == want
    assert got[0][1] == "eos" and got[0][0][-1] == eos


@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_paged_matches_dense_under_mesh(profile, rng):
    """The page-table gather and the dense (B, max_seq) layout are the
    same computation: identical streams under the same TP mesh."""
    prompts = _prompts(rng, [6, 9, 4])
    mk = lambda: [Request(uid=i, prompt=p, max_new_tokens=5)
                  for i, p in enumerate(prompts)]
    _, want = _serve(_mesh24(), profile, mk(), paged=False)
    eng, got = _serve(_mesh24(), profile, mk(), paged=True)
    assert eng.paged
    assert got == want


# --------------------------------------------------------------------------
# no silent fallback: only sharded infer kernels may run
# --------------------------------------------------------------------------
@needs_mesh
@pytest.mark.parametrize("profile", PROFILES)
def test_no_silent_fallback(profile, rng):
    """Under the TP mesh every AE execution in the serve path is a
    sharded infer plan: the per-shard decode kernel ran, megatron's
    row-parallel sites took the decode-split seam, and there are zero
    ref dispatches, zero training-shaped kernels, and zero *local*
    (unsharded) infer dispatches that would mean the mesh was ignored."""
    reqs = [Request(uid=i, prompt=p, max_new_tokens=6)
            for i, p in enumerate(_prompts(rng, [5, 9]))]
    cao.reset_dispatch()
    _serve(_mesh24(), profile, reqs)
    d = dict(cao.DISPATCH)
    assert d.get("sharded_infer_decode", 0) > 0, d
    if profile == "megatron":
        # o/down are row-parallel: the z_pre psum sits at the split seam
        assert d.get("sharded_infer_decode_split", 0) > 0, d
    else:
        # baseline shards rank only — no mid-kernel collective anywhere
        assert d.get("sharded_infer_decode_split", 0) == 0, d
    assert d.get("apply_fused_sharded", 0) > 0, d
    allowed = {"sharded_call", "apply_fused_sharded",
               "sharded_entry_allgather", "sharded_infer_pallas",
               "sharded_infer_decode", "sharded_infer_decode_split",
               "sharded_infer_monolith", "sharded_infer_staged"}
    for key, n in d.items():
        assert key in allowed and n >= 0, (key, d)
    # spelled out: the failure modes this test exists to catch
    for key in ("infer_decode", "infer_ref", "sharded_infer_ref",
                "apply_fused_local", "apply_fused_fallback",
                "fwd_pallas", "bwd_pallas", "fwd_ref", "bwd_ref"):
        assert d.get(key, 0) == 0, (key, d)


@needs_mesh
def test_variable_k_chunks_under_mesh(rng):
    """The variable-k chunk policy composes with TP: equal-budget batches
    decode exactly max_new - 1 steps (no finished-slot burn), each k a
    separately jitted scan."""
    eng, _ = _serve(_mesh24(), "baseline",
                    [Request(uid=i, prompt=p, max_new_tokens=6)
                     for i, p in enumerate(_prompts(rng, [5, 7]))])
    assert eng.stats()["decode_steps"] == 5
