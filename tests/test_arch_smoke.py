"""Per-architecture smoke tests (assignment requirement): a REDUCED config
of each family runs one forward/train step on CPU, asserting output shapes
and no NaNs; plus prefill + one decode step through the serve path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, get_config
from repro.configs import ASSIGNED
from repro.models.model import build_model
from repro.train.step import build_train_step, make_train_state


def _batch_for(cfg, b=2, s=16):
    i32 = jnp.int32
    if cfg.family == "vlm":
        return {"inputs_embeds": jnp.zeros((b, s, cfg.d_model), jnp.bfloat16),
                "position_ids": jnp.zeros((3, b, s), i32),
                "labels": jnp.ones((b, s), i32)}
    if cfg.family == "audio":
        return {"frames": jnp.zeros((b, cfg.encoder_seq_len, cfg.d_model),
                                    jnp.bfloat16),
                "tokens": jnp.ones((b, s), i32),
                "labels": jnp.ones((b, s), i32)}
    return {"tokens": jnp.ones((b, s), i32), "labels": jnp.ones((b, s), i32)}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_finite(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg)
    logits, aux = jax.jit(
        lambda p, b: model.apply(p, b, training=True))(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_one_train_step(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    tc = TrainConfig(steps=10, global_batch=2, seq_len=16)
    state = make_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, tc))
    state, metrics = step(state, _batch_for(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert int(state.step) == 1
    # params actually changed
    before = model.init(jax.random.PRNGKey(0))
    diffs = [float(jnp.abs(a.astype(jnp.float32) -
                           b.astype(jnp.float32)).max())
             for a, b in zip(jax.tree.leaves(before),
                             jax.tree.leaves(state.params))]
    assert max(diffs) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_decode(arch):
    cfg = get_config(arch).smoke()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, P, MAX = 2, 8, 32
    caches = model.init_caches(B, MAX)
    batch = _batch_for(cfg, B, P)
    batch.pop("labels")
    logits, caches = jax.jit(model.prefill)(params, batch, caches)
    assert logits.shape == (B, 1, cfg.padded_vocab)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    pos = jnp.full((B, 1), P, jnp.int32)
    logits2, _ = jax.jit(model.decode_step)(params, tok, caches, pos)
    assert bool(jnp.isfinite(logits2.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_full_forward(arch):
    """Teacher-forced decode over the cache must match the parallel
    forward logits (cache-correctness invariant)."""
    if arch == "qwen2-vl-2b":
        pytest.skip("vlm decode consumes tokens; parallel fwd uses embeds")
    cfg = get_config(arch).smoke()
    if cfg.moe.enabled:
        # capacity-based token dropping depends on the routing batch: a
        # token routed within T=8 (full fwd) vs T=1 (decode) sees different
        # capacity pressure.  Lift capacity so routing is drop-free and the
        # invariant is exact.
        import dataclasses
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=float(cfg.moe.num_experts)))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 1, 8
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(1, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            0.1 * rng.randn(B, cfg.encoder_seq_len, cfg.d_model),
            jnp.bfloat16)
    full_logits, _ = model.apply(params, batch, training=False)

    caches = model.init_caches(B, S)
    step_logits = []
    # feed tokens one at a time through decode_step
    if cfg.family == "audio":
        pre = {"frames": batch["frames"], "tokens": toks[:, :1]}
        lg, caches = model.prefill(params, pre, caches)
        step_logits.append(lg[:, 0])
        start = 1
    else:
        lg, caches = model.prefill(params, {"tokens": toks[:, :1]}, caches)
        step_logits.append(lg[:, 0])
        start = 1
    for t in range(start, S):
        pos = jnp.full((B, 1), t, jnp.int32)
        lg, caches = model.decode_step(params, toks[:, t:t + 1], caches, pos)
        step_logits.append(lg[:, 0])
    step_logits = jnp.stack(step_logits, axis=1)
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32), rtol=0.15, atol=0.15)
