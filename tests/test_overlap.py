"""Chunked prefill / prefill-decode overlap: the mixed-dispatch engine.

The overlap engine (the default for attn-only archs) dissolves the
admit-then-decode round structure: each admitted prompt is prefilled in
``prefill_chunk``-token slices fused into the decode dispatch, so
decoding slots keep streaming while a neighbour's prompt is consumed.
The correctness bar is **bit-identical greedy streams** against the
non-overlapped (``overlap=False``) engine — chunked prefill writes the
same cache bytes as a monolithic one, and batch rows are independent —
plus byte-identical final paged pools under churn, prompt deadline
enforcement *between* chunks, quarantine compatibility, and
no-silent-fallback DISPATCH assertions (a B×c chunk stays on the decode
kernel plan: keep B·c ≤ ops.DECODE_T_MAX).

Also home of the deadline-sweep regression tests (deadlines are checked
after every dispatch, not once per round) and the hypothesis-gated
random churn traces through PageAllocator.check_invariants.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.config import get_config
from repro.kernels.cola_ae import ops as cao
from repro.serve.engine import make_engine
from repro.serve.scheduler import Request


def _cfg(**over):
    # f32 keeps greedy argmax robust to path-dependent rounding
    return get_config("qwen2-1.5b").smoke().with_overrides(
        dtype="float32", **over)


def _prompt(rng, n, vocab=512):
    return rng.randint(1, vocab, (n,)).astype(np.int32)


def _mk(max_batch=2, max_seq=48, **kw):
    kw.setdefault("decode_block", 4)
    return make_engine(_cfg(), max_batch=max_batch, max_seq=max_seq, **kw)


def _pool(eng):
    """The paged KV pool minus the sacrificial page 0 (it absorbs parked
    writes whose content is mode-dependent by design)."""
    return [np.asarray(l)[:, eng.page_size:]
            for l in jax.tree.leaves(eng._caches)]


def _serve(eng, reqs):
    resps = eng.serve(reqs)
    return {r.uid: (r.tokens.tolist(), r.finish_reason) for r in resps}


# churn trace: more requests than slots, ragged lengths spanning several
# chunks, staggered arrivals, equal budgets (equal budgets keep the
# finish order identical across modes, which the pool byte-identity
# check needs — streams are mode-independent regardless)
def _churn(rng, budget=6):
    lens = [9, 5, 14, 3, 11, 7]
    return [Request(uid=i, prompt=_prompt(rng, L), max_new_tokens=budget,
                    arrival_s=0.02 * i) for i, L in enumerate(lens)]


# module-scope engine pair: small page pool (10 usable pages of 4 rows —
# the full churn trace cannot be resident at once) and a 4-token chunk so
# every prompt above spans multiple chunks
_GEOM = dict(page_size=4, n_pages=11, prefill_chunk=4)


@pytest.fixture(scope="module")
def ov():
    eng = _mk(**_GEOM)
    assert eng.overlap
    return eng


@pytest.fixture(scope="module")
def nov():
    eng = _mk(overlap=False, **_GEOM)
    assert not eng.overlap
    return eng


# --------------------------------------------------------------------------
# bit-identity + pool byte-identity under churn
# --------------------------------------------------------------------------
def test_churn_streams_identical(ov, nov, rng):
    """Seeded high-churn trace (staggered arrivals × ragged prompts ×
    page-pool contention): every stream is bit-identical with overlap on
    vs off."""
    st = rng.get_state()
    want = _serve(nov, _churn(rng))
    rng.set_state(st)
    got = _serve(ov, _churn(rng))
    assert got == want
    s = ov.stats()
    assert s["mixed_dispatches"] > 0
    # 4-token chunks: prompt L consumes ceil(L/4) chunks
    assert s["prefill_chunks"] == sum(-(-L // 4) for L in [9, 5, 14, 3, 11, 7])
    assert s["pages_in_use"] == 0
    ov.alloc.check_invariants()
    # latency accounting rode along: per-request TTFT + inter-token gaps
    for p in (50, 95, 99):
        assert s[f"ttft_p{p}_s"] >= 0.0
        assert s[f"itl_p{p}_s"] >= 0.0


def test_churn_pool_byte_identical(rng):
    """Final paged pools match byte for byte across modes.  The pool's
    stale bytes encode the full allocation history, so this needs a
    finish-order-preserving trace: equal prompt lengths + equal budgets
    keep FIFO admission order == finish order in both modes (a later
    admission can never overtake under overlap either — its prefill
    starts at least one dispatch behind).  Ragged traces can legitimately
    reorder finishes (a short prompt admitted later finishes its chunked
    prefill first), which permutes page claims without affecting any
    stream — streams are covered by the ragged churn test above."""
    st = rng.get_state()
    mk_reqs = lambda r: [Request(uid=i, prompt=_prompt(r, 8),
                                 max_new_tokens=6, arrival_s=0.02 * i)
                         for i in range(6)]
    a = _mk(**_GEOM)
    got_a = _serve(a, mk_reqs(rng))
    rng.set_state(st)
    b = _mk(overlap=False, **_GEOM)
    got_b = _serve(b, mk_reqs(rng))
    assert got_a == got_b
    assert a.stats()["mixed_dispatches"] > 0
    for x, y in zip(_pool(a), _pool(b)):
        np.testing.assert_array_equal(x, y)


def test_chunk_width_does_not_change_streams(ov, rng):
    """prefill_chunk is a latency knob, not a semantics knob: a 16-token
    chunk (every churn prompt fits in one chunk) yields the same streams
    as the module fixture's 4-token chunks."""
    st = rng.get_state()
    want = _serve(ov, _churn(rng))
    rng.set_state(st)
    wide = _mk(page_size=4, n_pages=11, prefill_chunk=16)
    got = _serve(wide, _churn(rng))
    assert got == want
    assert wide.stats()["prefill_chunks"] == 6  # one chunk per prompt


def test_eos_inside_chunk_matches_no_overlap(ov, nov, rng):
    """EOS landing mid-stream while a neighbour is still prefilling:
    the request truncates at the same token in both modes and the
    follower's stream is unperturbed."""
    p, follower = _prompt(rng, 9), _prompt(rng, 6)
    base = ov.serve([Request(uid=0, prompt=p, max_new_tokens=8)])[0]
    eos = int(base.tokens[3])
    mk = lambda: [Request(uid=0, prompt=p, max_new_tokens=8, eos_id=eos),
                  Request(uid=1, prompt=p, max_new_tokens=8, eos_id=eos),
                  Request(uid=2, prompt=follower, max_new_tokens=8,
                          arrival_s=0.01)]
    want = _serve(nov, mk())
    got = _serve(ov, mk())
    assert got == want
    assert got[0][1] == "eos" and got[0][0][-1] == eos


# --------------------------------------------------------------------------
# deadline enforcement between chunks (the sweep regression tests)
# --------------------------------------------------------------------------
def test_deadline_fires_mid_prefill(rng):
    """A tight deadline on a long prompt times out *between* prefill
    chunks: the request is finalized with zero tokens after consuming
    only part of its prompt — admission of a long prompt can no longer
    run to completion past its deadline."""
    hook = lambda kind, idx: ({"delay_s": 0.03} if kind == "prefill"
                              else None)
    eng = _mk(max_seq=64, prefill_chunk=4)
    eng.fault_hook = hook
    long_req = Request(uid=0, prompt=_prompt(rng, 24), max_new_tokens=4,
                       deadline_s=0.05)
    ok_req = Request(uid=1, prompt=_prompt(rng, 5), max_new_tokens=4)
    resps = eng.serve([long_req, ok_req])
    assert resps[0].finish_reason == "timeout"
    assert resps[0].tokens.size == 0 and resps[0].ttft_s is None
    s = eng.stats()
    assert s["timeouts"] == 1
    # the 24-token prompt needed 6 chunks; the deadline cut it short
    assert 0 < s["prefill_chunks"] - 2 < 6  # (2 chunks were uid 1's)
    assert resps[1].finish_reason == "length" and len(resps[1].tokens) == 4


def test_queued_deadline_swept_after_every_dispatch(rng):
    """Regression: deadlines used to be evaluated only at round
    boundaries, so a queued request whose deadline passed during a long
    dispatch was finalized one full round late.  The sweep now runs after
    every dispatch and emits a ``queue_timeout`` event — only the sweep
    path emits it, so its presence proves the request was reaped while
    the slot holder was still mid-generation."""
    armed = [False]

    def hook(kind, idx):
        if armed[0] and kind == "decode":
            return {"delay_s": 0.05}
        return None

    eng = _mk(max_batch=1, max_seq=64)
    eng.fault_hook = hook
    # warm every jit shape first so the timed trace sees millisecond
    # dispatches plus exactly the injected delays
    eng.serve([Request(uid=0, prompt=_prompt(rng, 5), max_new_tokens=13)])
    eng.reset_stats()
    armed[0] = True
    resps = eng.serve([
        Request(uid=0, prompt=_prompt(rng, 5), max_new_tokens=13),
        Request(uid=1, prompt=_prompt(rng, 5), max_new_tokens=4,
                deadline_s=0.02),
    ])
    assert resps[0].finish_reason == "length"
    assert resps[1].finish_reason == "timeout" and resps[1].tokens.size == 0
    assert {"kind": "queue_timeout", "uid": 1} in eng.events
    assert resps[1].latency_s < resps[0].latency_s


# --------------------------------------------------------------------------
# quarantine composes with chunked prefill
# --------------------------------------------------------------------------
def test_poisoned_prefill_chunk_quarantined_and_retried(ov, rng):
    """A NaN-poisoned prefill *chunk* quarantines only its slot: the
    request is re-queued and its retry restarts the prompt from scratch,
    the neighbour's stream is untouched, and both final streams match
    the unpoisoned engine's."""
    st = rng.get_state()
    reqs = [Request(uid=0, prompt=_prompt(rng, 9), max_new_tokens=5),
            Request(uid=1, prompt=_prompt(rng, 6), max_new_tokens=5)]
    want = _serve(ov, reqs)
    fired = [False]

    def hook(kind, idx):
        # one shot: poison slot 1 (the first admission pops slot 1 off
        # the free list) in the very first prefill-tagged dispatch
        if kind == "prefill" and not fired[0]:
            fired[0] = True
            return {"poison": np.array([False, True])}
        return None

    rng.set_state(st)
    eng = _mk(**_GEOM)
    eng.fault_hook = hook
    got = _serve(eng, [Request(uid=0, prompt=_prompt(rng, 9),
                               max_new_tokens=5),
                       Request(uid=1, prompt=_prompt(rng, 6),
                               max_new_tokens=5)])
    assert got == want
    s = eng.stats()
    assert s["quarantines"] == 1 and s["requeues"] == 1
    assert s["nonfinite_chunks"] >= 1
    eng.alloc.check_invariants()


# --------------------------------------------------------------------------
# overlap composes with speculative decoding and quantized streaming
# --------------------------------------------------------------------------
def test_spec_overlap_matches_spec_no_overlap(rng):
    """Speculative decoding under overlap: draft KV prefills chunk by
    chunk alongside the full model's, spec rounds are masked to decoding
    rows, and greedy streams match the non-overlapped spec engine.  On a
    finish-order-preserving trace (equal lengths/budgets) the final
    pools — full model's AND the draft's — also match byte for byte,
    proving rejected-draft rollback zeroed exactly the same rows."""
    mk = lambda **kw: _mk(max_seq=64, prefill_chunk=4, speculate=True,
                          spec_window=3, **kw)
    st = rng.get_state()
    even = lambda r: [Request(uid=i, prompt=_prompt(r, 9),
                              max_new_tokens=6, arrival_s=0.01 * i)
                      for i in range(4)]
    ragged = lambda r: [Request(uid=i, prompt=_prompt(r, L),
                                max_new_tokens=6, arrival_s=0.01 * i)
                        for i, L in enumerate([9, 5, 11, 7])]
    spec_off = mk(overlap=False)
    want_even = _serve(spec_off, even(rng))
    rng.set_state(st)
    spec_on = mk()
    assert spec_on.overlap and spec_on.speculating
    got_even = _serve(spec_on, even(rng))
    assert got_even == want_even
    assert spec_on.stats()["mixed_dispatches"] > 0
    # pool bytes compared while histories are still finish-order
    # preserving (the even trace) — BEFORE the ragged trace below, whose
    # legitimate finish reordering would desync the stale bytes
    for a, b in zip(_pool(spec_on), _pool(spec_off)):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(jax.tree.leaves(spec_on._draft_caches),
                    jax.tree.leaves(spec_off._draft_caches)):
        np.testing.assert_array_equal(
            np.asarray(a)[:, spec_on.page_size:],
            np.asarray(b)[:, spec_off.page_size:])
    st2 = rng.get_state()
    want_ragged = _serve(spec_off, ragged(rng))
    rng.set_state(st2)
    assert _serve(spec_on, ragged(rng)) == want_ragged
    spec_on.alloc.check_invariants()


def test_int8_overlap_matches_no_overlap(rng):
    """Quantized weight streaming under overlap: the int8 factors are
    dequantized in-VMEM identically for prefill chunks and decode steps,
    so overlap on/off streams stay bit-identical."""
    st = rng.get_state()
    reqs = lambda r: [Request(uid=i, prompt=_prompt(r, L),
                              max_new_tokens=4)
                      for i, L in enumerate([7, 5])]
    with cao.force_impl("pallas", True):
        off = _mk(prefill_chunk=4, overlap=False, weight_dtype="int8")
        want = _serve(off, reqs(rng))
        rng.set_state(st)
        on = _mk(prefill_chunk=4, weight_dtype="int8")
        got = _serve(on, reqs(rng))
    assert got == want
    assert on.stats()["mixed_dispatches"] > 0


# --------------------------------------------------------------------------
# no silent fallback: mixed dispatches stay on the decode kernel plan
# --------------------------------------------------------------------------
def test_mixed_dispatch_never_takes_training_kernel(rng):
    """With the fused path forced onto Pallas, every AE execution under
    overlap is an infer-mode plan: the B×c prefill chunk (B·c = 8 ≤
    DECODE_T_MAX) rides the same decode-kernel plan as the decode steps —
    zero training-shaped kernels, zero ref fallbacks."""
    cfg = _cfg()
    cfg = cfg.with_overrides(cola=dataclasses.replace(
        cfg.cola, use_fused_kernel=True))
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        eng = make_engine(cfg, max_batch=2, max_seq=48, decode_block=4,
                          prefill_chunk=4)
        eng.serve([Request(uid=0, prompt=_prompt(rng, 9),
                           max_new_tokens=6),
                   Request(uid=1, prompt=_prompt(rng, 5),
                           max_new_tokens=6, arrival_s=0.01)])
    assert eng.stats()["mixed_dispatches"] > 0
    d = dict(cao.DISPATCH)
    assert d.get("infer_decode", 0) > 0, d
    assert d.get("infer_ref", 0) == 0, d
    for key in ("fwd_pallas", "fwd_monolith", "fwd_staged", "bwd_pallas",
                "bwd_monolith", "bwd_staged", "fwd_ref", "bwd_ref"):
        assert d.get(key, 0) == 0, (key, d)


# --------------------------------------------------------------------------
# randomized churn traces keep the page pool coherent (hypothesis-driven
# when available; a fixed seed sweep otherwise)
# --------------------------------------------------------------------------
def _check_random_trace(ov, nov, seed, n_reqs, budget):
    """Random arrival trace (lengths, budgets, stagger) through the
    small-pool engine pair: streams bit-identical across modes, every
    page released at drain, allocator invariants intact."""
    r = np.random.RandomState(seed)
    lens = r.randint(2, 15, n_reqs)
    arr = r.uniform(0.0, 0.04, n_reqs)
    prompts = [r.randint(1, 512, L).astype(np.int32) for L in lens]
    mk = lambda: [Request(uid=i, prompt=p, max_new_tokens=budget,
                          arrival_s=float(a))
                  for i, (p, a) in enumerate(zip(prompts, arr))]
    want = _serve(nov, mk())
    got = _serve(ov, mk())
    assert got == want
    ov.alloc.check_invariants()
    nov.alloc.check_invariants()
    assert ov.stats()["pages_in_use"] == 0


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    st = None

if st is not None:
    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 1000), n_reqs=st.integers(3, 6),
           budget=st.integers(2, 8))
    def test_random_traces_streams_match_and_pool_coherent(ov, nov, seed,
                                                           n_reqs, budget):
        _check_random_trace(ov, nov, seed, n_reqs, budget)
else:
    @pytest.mark.parametrize("seed,n_reqs,budget",
                             [(0, 4, 5), (7, 3, 2), (23, 6, 8)])
    def test_random_traces_streams_match_and_pool_coherent(ov, nov, seed,
                                                           n_reqs, budget):
        _check_random_trace(ov, nov, seed, n_reqs, budget)
