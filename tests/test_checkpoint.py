"""Checkpoint-manager unit tests: round-trip, retention GC, atomic-rename
crash safety (fault hooks), manifest integrity verification + corrupt-
checkpoint fallback, async-writer error propagation, extra.json ride-along,
and elastic resume onto a different mesh (subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.checkpoint.manager import (CheckpointCorruptError,
                                      CheckpointManager,
                                      CheckpointWriteError)
from repro.testing.faults import (SimulatedCrash, corrupt_checkpoint,
                                  kill_mid_write, truncate_checkpoint)


def _tree(seed: int):
    r = np.random.RandomState(seed)
    return {"w": r.randn(4, 8).astype(np.float32),
            "inner": {"b": r.randn(8).astype(np.float32),
                      "step": np.asarray(seed, np.int32)}}


def _mgr(tmp_path, **kw):
    kw.setdefault("async_save", False)
    return CheckpointManager(str(tmp_path / "ckpt"), **kw)


def _leaves(t):
    import jax
    return jax.tree_util.tree_leaves(t)


def test_round_trip_and_extra(tmp_path):
    mgr = _mgr(tmp_path)
    tree = _tree(3)
    mgr.save(5, tree, extra={"offset": 7, "shard": 0})
    got = mgr.restore(5, _tree(0))
    for a, b in zip(_leaves(got), _leaves(tree)):
        np.testing.assert_array_equal(a, b)
    extra = mgr.restore_extra(5)
    assert extra["step"] == 5 and extra["offset"] == 7
    assert mgr.latest_step() == 5 and mgr.latest_good_step() == 5


def test_manifest_contents(tmp_path):
    mgr = _mgr(tmp_path)
    mgr.save(1, _tree(1))
    with open(os.path.join(mgr.dir, "step_1", "manifest.json")) as f:
        man = json.load(f)
    assert man["step"] == 1
    # one manifest entry per leaf, each with crc/shape/dtype
    assert set(man["leaves"]) == {"['w']", "['inner']['b']",
                                  "['inner']['step']"}
    for info in man["leaves"].values():
        assert set(info) == {"crc32", "shape", "dtype"}
    st = man["files"]["state.npz"]
    assert st["size"] == os.path.getsize(
        os.path.join(mgr.dir, "step_1", "state.npz"))


def test_retention_gc_keeps_newest(tmp_path):
    mgr = _mgr(tmp_path, keep=2)
    for s in (1, 2, 3):
        mgr.save(s, _tree(s))
    assert mgr.all_steps() == [2, 3]


@pytest.mark.parametrize("stage", ["post_state", "pre_rename"])
def test_crash_mid_write_is_atomic(tmp_path, stage):
    """A writer death mid-write (at either fault stage) never shadows the
    previous checkpoint: the partial write stays in a .tmp dir,
    latest_good_step falls back, and the next save GCs the leftovers."""
    mgr = _mgr(tmp_path)
    mgr.save(1, _tree(1))
    kill_mid_write(mgr, at_step=2, stage=stage)
    with pytest.raises(SimulatedCrash):
        mgr.save(2, _tree(2))
    assert mgr.all_steps() == [1]          # no renamed partial checkpoint
    assert os.path.exists(os.path.join(mgr.dir, "step_2.tmp"))
    assert mgr.latest_good_step() == 1
    got = mgr.restore(1, _tree(0))
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])
    mgr.save(3, _tree(3))                  # next save GCs the stray tmp
    assert not os.path.exists(os.path.join(mgr.dir, "step_2.tmp"))


def test_async_writer_failure_reraises_from_wait(tmp_path):
    """A failure on the background writer thread must surface on the train
    loop (as CheckpointWriteError), never die silently with the daemon."""
    mgr = _mgr(tmp_path, async_save=True)
    kill_mid_write(mgr, at_step=1)
    mgr.save(1, _tree(1))                  # returns; writer dies async
    with pytest.raises(CheckpointWriteError, match="injected writer death"):
        mgr.wait()
    mgr.save(2, _tree(2))                  # manager recovers after re-raise
    mgr.wait()
    assert mgr.latest_good_step() == 2


@pytest.mark.parametrize("damage", [corrupt_checkpoint, truncate_checkpoint])
def test_corrupt_checkpoint_detected_and_skipped(tmp_path, damage):
    mgr = _mgr(tmp_path)
    mgr.save(1, _tree(1))
    mgr.save(2, _tree(2))
    damage(mgr.dir, 2)
    assert not mgr.verify(2) and mgr.verify(1)
    assert mgr.latest_good_step() == 1     # corrupt newest is skipped
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(2, _tree(0))
    got = mgr.restore(1, _tree(0))
    np.testing.assert_array_equal(got["w"], _tree(1)["w"])


def test_corrupt_manifest_detected(tmp_path):
    mgr = _mgr(tmp_path)
    mgr.save(1, _tree(1))
    with open(os.path.join(mgr.dir, "step_1", "manifest.json"), "w") as f:
        f.write("{not json")
    assert not mgr.verify(1)
    assert mgr.latest_good_step() is None


def test_missing_file_detected(tmp_path):
    mgr = _mgr(tmp_path)
    mgr.save(1, _tree(1))
    os.remove(os.path.join(mgr.dir, "step_1", "extra.json"))
    assert not mgr.verify(1)


def test_elastic_resume_on_different_mesh(tmp_path):
    """Checkpoint written on one virtual mesh restores through
    resume_on_mesh onto a differently-shaped mesh (subprocess so the
    forced device count doesn't leak into this process)."""
    code = textwrap.dedent("""
        import os
        os.environ['XLA_FLAGS'] = \
            '--xla_force_host_platform_device_count=8'
        import sys; sys.path.insert(0, 'src')
        import jax, numpy as np
        from repro.config import TrainConfig, get_config
        from repro.distributed.sharding import mesh_env, MeshEnv
        from repro.distributed.elastic import resume_on_mesh
        from repro.train.loop import train
        d = %r
        cfg = get_config("llama-60m").smoke()
        tc = TrainConfig(steps=2, global_batch=4, seq_len=32, log_every=0,
                         checkpoint_dir=d, checkpoint_every=2,
                         async_checkpoint=False)
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        with mesh_env(mesh8, "megatron"):
            out = train(cfg, tc)
        mesh2 = jax.make_mesh((2,), ("data",))
        env2 = MeshEnv(mesh2, "fsdp")
        state, step = resume_on_mesh(d, cfg, tc, env2)
        assert step == 2, step
        a = jax.tree.leaves(out["state"].params)
        b = jax.tree.leaves(state.params)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print("OK")
    """) % str(tmp_path / "ckpt")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=".", timeout=560)
    assert r.returncode == 0 and "OK" in r.stdout, \
        f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
