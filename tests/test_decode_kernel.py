"""Decode-path kernel tests: `cola_ae_decode` parity against the oracle
(4 σ × bf16/f32 × decode batches B ∈ {1, 8}, with and without biases),
the monolith bias fold, the materialized-dz streamed dA backward, the
infer-mode planner, and the decode traffic model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.cola_ae import act as caa
from repro.kernels.cola_ae import kernel as cak
from repro.kernels.cola_ae import ops as cao
from repro.kernels.cola_ae import ref as car


def _rel(got, want):
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    return np.abs(got - want).max() / (np.abs(want).max() + 1e-12)


def _site(rng, dt, T, din=192, r=48, dout=160):
    x = jnp.asarray(rng.randn(T, din), dt)
    a = jnp.asarray(0.05 * rng.randn(din, r), dt)
    b = jnp.asarray(0.05 * rng.randn(r, dout), dt)
    return x, a, b


@pytest.mark.parametrize("B", [1, 8])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_decode_kernel_parity(sigma, dtype, B, rng):
    """The GEMV-shaped single launch matches the oracle at decode batches
    — including B=1, where the training kernels' token tiles are
    degenerate (the whole reason this kernel exists)."""
    x, a, b = _site(rng, dtype, B)
    got = cak.cola_ae_decode(x, a, b, sigma=sigma, interpret=True)
    want = car.cola_ae(x, a, b, sigma=sigma)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    assert got.shape == want.shape and got.dtype == x.dtype
    assert _rel(got, want) <= tol, (sigma, dtype, B, _rel(got, want))


@pytest.mark.parametrize("B", [1, 8])
@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_decode_kernel_bias_parity(sigma, B, rng):
    """Both biases fold into the single launch: bias_a pre-σ, bias_b on
    the output tile."""
    x, a, b = _site(rng, jnp.float32, B)
    ba = jnp.asarray(0.1 * rng.randn(a.shape[1]), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(b.shape[1]), jnp.float32)
    got = cak.cola_ae_decode(x, a, b, ba, bb, sigma=sigma, interpret=True)
    want = car.cola_ae(x, a, b, sigma=sigma, bias_a=ba, bias_b=bb)
    assert _rel(got, want) <= 1e-5, (sigma, B, _rel(got, want))


def test_decode_kernel_streams_weight_grid(rng, monkeypatch):
    """Forced-tiny budget: the weight-grid blocks shrink below the dims
    (the kernel never needs whole-weight residency) and parity holds."""
    monkeypatch.setattr(cak, "FWD_VMEM_BUDGET", 48 * 1024)
    x, a, b = _site(rng, jnp.float32, 4, din=1024, r=96, dout=384)
    e = 4
    bi = cak._fit_block(1024, e * (8 + 96), 4 * 8 * 96,
                        cak.FWD_VMEM_BUDGET, cap=1024)
    assert bi < 1024 and 1024 % bi == 0  # it actually tiles
    got = cak.cola_ae_decode(x, a, b, sigma="silu", interpret=True)
    want = car.cola_ae(x, a, b, sigma="silu")
    assert _rel(got, want) <= 1e-5


def test_decode_is_single_launch_no_gemms(rng):
    """One pallas_call, zero XLA dot_generals, and no (T, r) output —
    decode emits nothing but the output tile."""
    from tests.test_cola_ae_bwd import _count_prims
    x, a, b = _site(rng, jnp.float32, 1)
    f = lambda *t: cao.cola_ae(*t, mode="infer", impl="pallas",
                               interpret=True)
    jx = jax.make_jaxpr(f)(x, a, b)
    assert _count_prims(jx.jaxpr, "pallas_call") == 1
    assert _count_prims(jx.jaxpr, "dot_general") == 0
    r = a.shape[1]
    for eqn in jx.jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            for var in eqn.outvars:
                assert var.aval.shape[-1] != r  # no z_pre emitted


def test_monolith_bias_fold_fwd(rng):
    """The monolithic fwd kernel folds both biases; the emitted z_pre is
    post-bias_a (the true σ input the backward recomputes from)."""
    T, din, r, dout = 130, 256, 64, 384
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    ba = jnp.asarray(0.1 * rng.randn(r), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(dout), jnp.float32)
    out, zp = cak.cola_ae_fwd(x, a, b, ba, bb, sigma="gelu",
                              interpret=True, return_zpre=True)
    np.testing.assert_allclose(np.asarray(zp), np.asarray(jnp.dot(x, a) + ba),
                               rtol=1e-5, atol=1e-5)
    want = car.cola_ae(x, a, b, sigma="gelu", bias_a=ba, bias_b=bb)
    assert _rel(out, want) <= 1e-5


@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_monolith_bias_grad_parity(sigma, rng):
    """Bias sites on the default plan: monolith fwd (bias folded) +
    staged bwd (dbias from the dzl seam) — all five grads match."""
    T, din, r, dout = 96, 128, 32, 192
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.float32)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.float32)
    ba = jnp.asarray(0.1 * rng.randn(r), jnp.float32)
    bb = jnp.asarray(0.1 * rng.randn(dout), jnp.float32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        f = lambda *t: (cao.cola_ae(t[0], t[1], t[2], bias_a=t[3],
                                    bias_b=t[4], sigma=sigma) ** 2).sum()
        got = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, a, b, ba, bb)
    assert cao.DISPATCH["fwd_monolith"] == 1, dict(cao.DISPATCH)
    assert cao.DISPATCH["bwd_staged"] == 1, dict(cao.DISPATCH)
    fr = lambda *t: (car.cola_ae(t[0], t[1], t[2], bias_a=t[3],
                                 bias_b=t[4], sigma=sigma) ** 2).sum()
    want = jax.grad(fr, argnums=(0, 1, 2, 3, 4))(x, a, b, ba, bb)
    for u, v in zip(got, want):
        assert _rel(u, v) <= 1e-5, (sigma, u.shape, _rel(u, v))


@pytest.mark.parametrize("sigma", list(caa.SIGMA_MODES))
def test_dz_materialization_and_streamed_da(sigma, rng):
    """cola_ae_dz materializes dz = dzl ⊙ σ′(z_pre) exactly once; the
    streamed dA kernel consumes it and matches xᵀ·dz."""
    T, din, r = 130, 192, 48
    x = jnp.asarray(rng.randn(T, din), jnp.float32)
    z_pre = jnp.asarray(rng.randn(T, r), jnp.float32)
    dzl = jnp.asarray(rng.randn(T, r), jnp.float32)
    dz = cak.cola_ae_dz(dzl, z_pre, sigma=sigma, interpret=True)
    want_dz = dzl * caa.act_grad(z_pre, sigma)
    np.testing.assert_allclose(np.asarray(dz), np.asarray(want_dz),
                               rtol=1e-6, atol=1e-6)
    da = cak.cola_ae_bwd_da(x, dz, interpret=True)
    np.testing.assert_allclose(
        np.asarray(da), np.asarray(jnp.dot(x.T, dz.astype(x.dtype))),
        rtol=1e-5, atol=1e-5)


def test_infer_mode_dispatches_by_t(rng):
    """mode='infer': T=1 dispatches the decode launch, T above the
    threshold rides the monolith — and the forced-plan override can pin
    'decode' for harnesses."""
    x1, a, b = _site(rng, jnp.float32, 1)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        out = cao.cola_ae(x1, a, b, mode="infer")
    assert cao.DISPATCH["infer_decode"] == 1, dict(cao.DISPATCH)
    assert _rel(out, car.cola_ae(x1, a, b)) <= 1e-5
    xT = jnp.asarray(rng.randn(cao.DECODE_T_MAX + 64, a.shape[0]),
                     jnp.float32)
    cao.reset_dispatch()
    with cao.force_impl("pallas", True):
        out = cao.cola_ae(xT, a, b, mode="infer")
    assert cao.DISPATCH["infer_monolith"] == 1, dict(cao.DISPATCH)
    assert cao.DISPATCH["infer_decode"] == 0
    assert _rel(out, car.cola_ae(xT, a, b)) <= 1e-5
    cao.reset_dispatch()
    with cao.force_impl("pallas", True, plan="decode"):
        cao.cola_ae(xT, a, b, mode="infer")
    assert cao.DISPATCH["infer_decode"] == 1, dict(cao.DISPATCH)


def test_decode_traffic_model():
    """Fused decode strictly beats the XLA GEMV pair (the z round-trips),
    and the CoLA site moves ~half the dense site's weight bytes at r=d/4
    (the paper's Table-11 story)."""
    for (T, din, r, dout) in [(1, 2048, 512, 2048), (8, 4096, 1024, 4096)]:
        f = cak.decode_hbm_traffic(T, din, r, dout, fused=True)
        u = cak.decode_hbm_traffic(T, din, r, dout, fused=False)
        assert f < u
        dense = 2 * (T * din + din * dout + T * dout)
        assert 1.8 <= dense / f <= 2.2


def test_staged_traffic_model_charges_dz_once(monkeypatch):
    """The staged model pays the dz materialization (3 f32 (T, r) moves)
    and in exchange re-reads ONE r-dim tensor per dA weight pass; at a
    many-pass site (internlm2 down-proj) that nets out strictly below the
    old recompute-from-(dzl, z_pre) accounting — and the model's re-read
    term genuinely responds to the pass count (shrinking the DW budget
    forces more passes and must model more bytes)."""
    T, din, r, dout = 4096, 16384, 1536, 6144
    e, zp32 = 2, 4 * T * r
    loose = cak.hbm_traffic(T, din, r, dout, path="staged")
    # the old model: per-pass cost 2·zp32 (dzl + z_pre), bigger fixed VMEM
    # footprint per token tile (8·r), no dz round-trip
    _, bi_old = cak._pick_dw_tiles(T, din, r, e, 8 * r, cak.DW_VMEM_BUDGET)
    _, bi_new = cak._pick_dw_tiles(T, din, r, e, 4 * r, cak.DW_VMEM_BUDGET)
    n_old, n_new = -(-din // bi_old), -(-din // bi_new)
    assert n_new >= 1 and n_old >= 3  # a genuinely multi-pass site
    old_da_reads = n_old * 2 * zp32
    new_da_reads = 3 * zp32 + n_new * zp32
    assert new_da_reads < old_da_reads
    # the per-pass dz re-read is a live term, not a constant: a tighter
    # budget → smaller weight blocks → more passes → more modeled bytes
    monkeypatch.setattr(cak, "DW_VMEM_BUDGET", cak.DW_VMEM_BUDGET // 8)
    tight = cak.hbm_traffic(T, din, r, dout, path="staged")
    assert tight > loose
