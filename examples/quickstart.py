"""Quickstart: pre-train a tiny CoLA LLaMA on the synthetic corpus, then
generate from it — the whole public API in ~30 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.config import TrainConfig, get_config
from repro.serve.engine import ServeEngine
from repro.models.model import build_model
from repro.train.loop import train

# 1. pick an architecture and shrink it to laptop scale
cfg = get_config("llama-60m").smoke()          # CoLA parameterization, r=16
print(f"arch={cfg.name} parameterization={cfg.parameterization} "
      f"remat={cfg.remat}")

# 2. train for a few hundred steps (CoLA-M checkpointing on by default)
tc = TrainConfig(steps=60, global_batch=8, seq_len=128,
                 learning_rate=3e-3, log_every=20)
out = train(cfg, tc)
print(f"final loss: {out['ce_loss']:.3f} (ppl {np.exp(out['ce_loss']):.1f})")

# 3. serve it
model = build_model(cfg)
eng = ServeEngine(model, out["state"].params, max_batch=2, max_seq=160)
prompts = np.ones((2, 8), np.int32)
tokens, stats = eng.generate(prompts, max_new_tokens=24)
print(f"generated: {tokens[0].tolist()}")
print(f"decode throughput: {stats['decode_tok_per_s']:.0f} tok/s")
