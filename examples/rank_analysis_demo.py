"""Reproduce the paper's motivating observation (Fig. 2): activations of a
*trained* transformer have effective rank far below their dimension, and a
CoLA model enforces this by construction.

    PYTHONPATH=src python examples/rank_analysis_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import TrainConfig, get_config
from repro.core.rank_analysis import collect_activation_spectra
from repro.models.model import build_model
from repro.train.loop import train

cfg = get_config("llama-60m").smoke().with_overrides(
    parameterization="dense", num_layers=4)
tc = TrainConfig(steps=80, global_batch=8, seq_len=128, log_every=40)
print("training a small full-rank model to get non-random activations...")
out = train(cfg, tc)

model = build_model(cfg)
rng = np.random.RandomState(0)
batch = {"tokens": jnp.asarray(rng.randint(1, cfg.vocab_size, (8, 128)),
                               jnp.int32)}
alpha = 0.95
rows = collect_activation_spectra(model, out["state"].params, batch, alpha)
print(f"\neffective rank r({alpha}) of the residual stream (dim = "
      f"{cfg.d_model}) — paper Fig. 2b shape:")
for r in rows:
    bar = "#" * int(40 * r["effective_rank"] / r["dim"])
    print(f"  layer {r['layer']:2d}: r={r['effective_rank']:3d}/{r['dim']} "
          f"{bar}")

# the same spectra drive the speculative-decoding self-draft: the rank
# holding alpha of each layer's activation energy is the draft rank that
# layer gets (serve/draft.py builds the truncated parameter views)
from repro.core.rank_analysis import pick_draft_ranks

print("\nper-layer draft ranks for speculative decoding "
      "(pick_draft_ranks):")
for a in (0.8, 0.9, 0.95):
    ranks = pick_draft_ranks(rows, a)
    print(f"  alpha={a:.2f}: " +
          " ".join(f"L{l}:{r}" for l, r in sorted(ranks.items())))
