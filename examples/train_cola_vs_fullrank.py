"""End-to-end training driver: CoLA vs full-rank vs Control at equal token
budget (paper Table 5/7 shape), with checkpointing + resume.

Default runs a ~3M-param model for 300 steps on CPU in a few minutes; on a
TPU fleet pass --full for the real llama-60m at the paper's batch.

    PYTHONPATH=src python examples/train_cola_vs_fullrank.py [--steps N]
"""
import argparse
import dataclasses

import numpy as np

from repro.config import TrainConfig, get_config
from repro.train.loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="full llama-60m config (TPU-scale)")
    args = ap.parse_args()

    base = get_config("llama-60m")
    if not args.full:
        base = base.smoke().with_overrides(num_layers=4, d_model=128,
                                           head_dim=32)
        base = dataclasses.replace(
            base, cola=dataclasses.replace(base.cola, rank_attn=32,
                                           rank_mlp=32))
    tc = TrainConfig(steps=args.steps, global_batch=8, seq_len=128,
                     learning_rate=3e-3, log_every=max(args.steps // 6, 1),
                     eval_every=args.steps // 2, eval_batches=4,
                     checkpoint_dir="/tmp/cola_example_ckpt",
                     checkpoint_every=args.steps // 2)

    results = {}
    for name, cfg in {
        "cola": base.with_overrides(parameterization="cola"),
        "full_rank": base.with_overrides(parameterization="dense"),
        "control(0.5x width)": dataclasses.replace(
            base.with_overrides(parameterization="dense"),
            d_ff=base.d_ff // 2, d_model=base.d_model // 2,
            head_dim=base.resolved_head_dim // 2),
    }.items():
        import shutil
        shutil.rmtree("/tmp/cola_example_ckpt", ignore_errors=True)
        print(f"=== {name} ===")
        out = train(cfg, tc)
        results[name] = out["ce_loss"]

    print("\nfinal losses (paper Table 5/7 shape: CoLA ≈ full-rank, "
          "Control worse):")
    for k, v in results.items():
        print(f"  {k:22s} {v:.4f}  (ppl {np.exp(v):.1f})")


if __name__ == "__main__":
    main()
