"""Batched serving demo across architecture families: GQA (qwen2), MLA
(minicpm3), attention-free (rwkv6) — one engine API, per-family caches.

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np

from repro.config import get_config
from repro.serve.engine import make_engine

for arch in ("qwen2-1.5b", "minicpm3-4b", "rwkv6-7b"):
    cfg = get_config(arch).smoke()
    eng = make_engine(cfg, max_batch=4, max_seq=96)
    prompts = np.random.RandomState(0).randint(
        1, cfg.vocab_size, (4, 16)).astype(np.int32)
    tokens, stats = eng.generate(prompts, max_new_tokens=32)
    print(f"{arch:15s} cache={'state' if cfg.sub_quadratic() else 'kv'} "
          f"prefill={stats['prefill_s']*1e3:7.1f}ms "
          f"decode={stats['decode_tok_per_s']:7.1f} tok/s "
          f"sample={tokens[0][:8].tolist()}")
