"""Paper Table 9 / Fig. 8 analogue: measured train-step wall time for
full-rank vs vanilla-GCP vs CoLA vs CoLA-M (CPU-relative; the paper's A100
numbers translate through the FLOPs ratios validated in flops_table)."""
import time

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.models.model import build_model
from repro.train.step import build_train_step, make_train_state


def _step_time(cfg, iters=4):
    model = build_model(cfg)
    tc = TrainConfig(steps=10, global_batch=4, seq_len=256)
    state = make_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, tc), donate_argnums=0)
    batch = {"tokens": jnp.ones((4, 256), jnp.int32),
             "labels": jnp.ones((4, 256), jnp.int32)}
    state, m = step(state, batch)  # compile + warmup
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def run(emit):
    variants = {
        "full_rank": dict(parameterization="dense", remat="none"),
        "vanilla_gcp": dict(parameterization="dense", remat="full"),
        "cola": dict(parameterization="cola", remat="none"),
        "cola_m": dict(parameterization="cola", remat="cola_m"),
    }
    tokens = 4 * 256
    times = {}
    for name, over in variants.items():
        cfg = get_config("llama-60m").with_overrides(**over)
        dt = _step_time(cfg)
        times[name] = dt
        emit(f"table9_step_s/{name}", dt, f"tok_per_s={tokens/dt:.0f}")
    emit("fig8/cola_speedup_vs_full", times["full_rank"] / times["cola"],
         "paper: 1.86x on A100")
    emit("fig8/colam_speedup_vs_gcp", times["vanilla_gcp"] / times["cola_m"],
         "paper: CoLA-M > GCP")
