"""Paper Table 9 / Fig. 8 analogue: measured train-step wall time for
full-rank vs vanilla-GCP vs CoLA vs CoLA-M (CPU-relative; the paper's A100
numbers translate through the FLOPs ratios validated in flops_table), plus
a fwd+bwd microbench of one CoLA-AE site: fused custom-VJP path (saves only
the r-dim z_pre; Pallas kernels on TPU) vs plain autodiff of the unfused
reference, with the modeled HBM traffic from kernels/cola_ae/kernel.py."""
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.config import TrainConfig, get_config
from repro.models.model import build_model
from repro.train.step import build_train_step, make_train_state


def _step_time(cfg, iters=4):
    model = build_model(cfg)
    tc = TrainConfig(steps=10, global_batch=4, seq_len=256)
    state = make_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(build_train_step(model, tc), donate_argnums=0)
    batch = {"tokens": jnp.ones((4, 256), jnp.int32),
             "labels": jnp.ones((4, 256), jnp.int32)}
    state, m = step(state, batch)  # compile + warmup
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        state, m = step(state, batch)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def _time_grad(fn, args, iters=8):
    g = jax.jit(jax.grad(fn, argnums=(0, 1, 2)))
    out = g(*args)  # compile + warmup
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = g(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _time_fwd(fn, args, iters=32):
    f = jax.jit(fn)
    out = f(*args)  # compile + warmup
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def _cola_ae_bwd_bench(emit):
    from repro.kernels.cola_ae import kernel as cak
    from repro.kernels.cola_ae import ops as cao
    from repro.kernels.cola_ae import ref as car

    T, din, r, dout = 2048, 512, 128, 512
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(T, din), jnp.bfloat16)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.bfloat16)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.bfloat16)

    # fused = the structured custom-VJP path: Pallas kernels on TPU, the
    # same (x, z_pre)-residual math off-TPU (impl='auto').
    fused = lambda *t: cao.cola_ae(*t, impl="auto").astype(jnp.float32).sum()
    # unfused = plain autodiff of the oracle: full-rank z saved, r-dim dz
    # round-trips HBM as separate XLA ops.
    unfused = lambda *t: car.cola_ae(*t).astype(jnp.float32).sum()
    t_f = _time_grad(fused, (x, a, b))
    t_u = _time_grad(unfused, (x, a, b))
    emit("cola_ae_bwd/fused_fwdbwd_s", t_f,
         f"T={T} d_in={din} r={r} d_out={dout} bf16")
    emit("cola_ae_bwd/unfused_fwdbwd_s", t_u, f"speedup={t_u / t_f:.2f}x")
    hbm_f = cak.hbm_traffic(T, din, r, dout, fused=True)
    hbm_u = cak.hbm_traffic(T, din, r, dout, fused=False)
    emit("cola_ae_bwd/model_hbm_fused_MB", hbm_f / 2**20,
         f"unfused={hbm_u / 2**20:.1f}MB ratio={hbm_u / hbm_f:.2f}x")


def _cola_ae_split_bench(emit):
    """Monolith vs two-stage split vs old XLA fallback, modeled HBM bytes
    (kernels/cola_ae/kernel.py traffic model) at the two site classes the
    split exists for: megatron row-parallel (the z_pre-psum seam) and
    over-VMEM (internlm2 down-proj — the monolith row is hypothetical
    there: whole weights cannot stage, which is why the split exists;
    'unfused' is what those sites actually ran before this refactor)."""
    from repro.kernels.cola_ae import kernel as cak

    sites = {
        # (T, d_in, r, d_out): a llama-1b o-proj-class site, row-parallel
        # under megatron — pre-split this took XLA math in fwd
        "megatron_rowpar": (2048, 2048, 512, 2048),
        # internlm2-20b down-proj: A alone 50 MB bf16, dw blocks 138 MB f32
        "overvmem_internlm2_down": (4096, 16384, 1536, 6144),
    }
    for name, (T, din, r, dout) in sites.items():
        fits = cak.weights_fit_vmem(din, r, dout)
        for path in ("monolith", "staged", "unfused"):
            note = f"T={T} d_in={din} r={r} d_out={dout}"
            if path == "monolith" and not fits:
                note += " (hypothetical: weights exceed VMEM, cannot run)"
            emit(f"cola_ae_split/{name}_{path}_model_hbm_MB",
                 cak.hbm_traffic(T, din, r, dout, path=path) / 2**20, note)


def _cola_ae_sharded_bench(emit):
    """Sharded-fused (shard_map custom VJP) vs the old gated fallback
    (unfused XLA math, what --fused used to silently run under a 'model'
    mesh) for one AE site per sharding profile, plus the modeled collective
    wire bytes from distributed/sharding.py.

    Uses whatever host devices exist: on a multi-device run (e.g. under
    XLA_FLAGS=--xla_force_host_platform_device_count=8) the 'model' axis is
    real; single-device still exercises the shard_map path with size-1
    psum groups.
    """
    from repro.distributed import sharding as sh
    from repro.kernels.cola_ae import ops as cao
    from repro.models.common import silu

    n = jax.device_count()
    model = max(m for m in (1, 2, 4, 8) if m <= n and n % m == 0)
    mesh = jax.make_mesh((n // model, model), ("data", "model"))
    b, s, din, r, dout = 8, 256, 512, 128, 1024
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(b, s, din), jnp.bfloat16)
    wa = jnp.asarray(0.05 * rng.randn(din, r), jnp.bfloat16)
    wb = jnp.asarray(0.05 * rng.randn(r, dout), jnp.bfloat16)

    def make_fused(in_ax, out_ax):
        return lambda *t: cao.cola_ae_sharded(
            *t, sigma="silu", in_ax=in_ax,
            out_ax=out_ax).astype(jnp.float32).sum()

    def make_unfused(in_ax):
        # what the old gate actually ran: cola_apply's unfused einsums with
        # the act_rank constraint on the bottleneck, GSPMD-sharded
        def unfused(x, wa, wb):
            x = sh.shard(x, "batch", "seq", in_ax)
            z = jnp.einsum("...d,dr->...r", x, wa.astype(x.dtype))
            z = sh.shard(z, "batch", "seq", "act_rank")
            z = silu(z)
            h = jnp.einsum("...r,ro->...o", z, wb.astype(x.dtype))
            return h.astype(jnp.float32).sum()
        return unfused

    fused = make_fused("embed", "ffw")
    unfused = make_unfused("embed")
    for profile in ("baseline", "megatron", "fsdp"):
        with sh.mesh_env(mesh, profile) as env:
            part = sh.cola_ae_partition(env, x.shape, wa.shape, wb.shape,
                                        "embed", "ffw")
            t_f = _time_grad(fused, (x, wa, wb))
            t_u = _time_grad(unfused, (x, wa, wb))
            cb = sh.cola_ae_collective_bytes(env, part, b * s, din, r, dout)
        emit(f"cola_ae_sharded/{profile}_fused_fwdbwd_s", t_f,
             f"model={model} T={b * s} d_in={din} r={r} d_out={dout}")
        emit(f"cola_ae_sharded/{profile}_gated_fallback_s", t_u,
             f"fused_speedup={t_u / t_f:.2f}x")
        emit(f"cola_ae_sharded/{profile}_model_collective_MB", cb / 2**20,
             f"ring-all-reduce wire bytes, 'model'={model}")

    # megatron row-parallel (o/down class): the split-stage pipeline fuses
    # around the z_pre psum — vs the pre-split XLA-math branch those sites
    # used to run (the same GSPMD einsum reference, row-parallel axes)
    with sh.mesh_env(mesh, "megatron"):
        t_f = _time_grad(make_fused("ffw", "embed"), (x, wa, wb))
        t_u = _time_grad(make_unfused("ffw"), (x, wa, wb))
    emit("cola_ae_sharded/megatron_rowpar_split_fwdbwd_s", t_f,
         f"model={model} — staged Pallas around the z_pre psum")
    emit("cola_ae_sharded/megatron_rowpar_xla_branch_s", t_u,
         f"pre-split XLA-math branch, split_speedup={t_u / t_f:.2f}x")


def _cola_ae_decode_bench(emit):
    """Decode-kernel rows: the GEMV-shaped fused launch vs the XLA GEMV
    pair at a decode step's shapes (T = slot batch).  Measured rows use
    impl='auto' (the Pallas kernel on TPU, the identical ref math off-TPU
    — so the CPU numbers compare kernels' *structure*, the TPU run the
    kernels themselves); the modeled HBM rows are backend-independent and
    carry the weight-traffic story: decode reads each weight element
    exactly once, and CoLA's factorized weights are ~r(d_in+d_out)/
    (d_in·d_out) of the dense site's bytes."""
    from repro.kernels.cola_ae import kernel as cak
    from repro.kernels.cola_ae import ops as cao
    from repro.kernels.cola_ae import act as caa

    din, r, dout = 2048, 512, 2048  # llama-1b o-proj-class site
    rng = np.random.RandomState(0)
    a = jnp.asarray(0.05 * rng.randn(din, r), jnp.bfloat16)
    b = jnp.asarray(0.05 * rng.randn(r, dout), jnp.bfloat16)

    def gemv_pair(x, a, b):  # the unfused decode math: z round-trips HBM
        z = jnp.dot(x, a.astype(x.dtype)).astype(jnp.float32)
        return jnp.dot(caa.apply_act(z, "silu").astype(x.dtype),
                       b.astype(x.dtype))

    for T in (1, 8):
        x = jnp.asarray(rng.randn(T, din), jnp.bfloat16)
        fused = lambda *t: cao.cola_ae(*t, mode="infer", impl="auto")
        t_f = _time_fwd(fused, (x, a, b))
        t_u = _time_fwd(gemv_pair, (x, a, b))
        emit(f"serve/decode_kernel_T{T}_s", t_f,
             f"d_in={din} r={r} d_out={dout} bf16")
        emit(f"serve/decode_xla_gemv_T{T}_s", t_u,
             f"fused_speedup={t_u / t_f:.2f}x")
        hf = cak.decode_hbm_traffic(T, din, r, dout, fused=True)
        hu = cak.decode_hbm_traffic(T, din, r, dout, fused=False)
        dense = 2 * (T * din + din * dout + T * dout)  # dense GEMV, bf16
        emit(f"serve/decode_model_hbm_T{T}_MB", hf / 2**20,
             f"xla_gemv={hu / 2**20:.2f}MB dense_site={dense / 2**20:.2f}MB"
             f" (paper 2x: dense/cola={dense / hf:.2f}x)")


def _serve_engine_bench(emit):
    """serve/* engine rows: decode tok/s + p50 per-token latency through
    the continuous-batching engine — cola vs dense parameterization (the
    paper's Table-11 2x-smaller/faster-decode claim at engine grain), and
    the jitted lax.scan inner loop vs the old one-dispatch-per-token
    Python loop on the identical model."""
    from repro.serve.engine import make_engine

    rng = np.random.RandomState(0)
    res = {}
    for param in ("cola", "dense"):
        cfg = get_config("qwen2-1.5b").smoke().with_overrides(
            parameterization=param)
        eng = make_engine(cfg, max_batch=4, max_seq=96, decode_block=8)
        prompts = rng.randint(1, cfg.vocab_size, (4, 16)).astype(np.int32)
        eng.generate(prompts, 32)            # compile
        _, s = eng.generate(prompts, 32)     # steady state
        res[param] = s
        emit(f"serve/decode_tok_s_{param}", s["decode_tok_per_s"],
             "B=4 new=32 k=8, qwen2 smoke")
        emit(f"serve/per_token_p50_ms_{param}",
             s["per_token_p50_s"] * 1e3,
             f"p95={s['per_token_p95_s']*1e3:.2f}ms")
        if param == "cola":
            eng.generate_python_loop(prompts, 32)          # compile
            _, sl = eng.generate_python_loop(prompts, 32)  # steady state
            emit("serve/scan_loop_decode_s", s["decode_s"],
                 f"{s['decode_dispatches']} dispatches (k=8)")
            emit("serve/python_loop_decode_s", sl["decode_s"],
                 f"{sl['decode_dispatches']} dispatches, "
                 f"scan_speedup={sl['decode_s'] / s['decode_s']:.2f}x")
    emit("serve/cola_vs_dense_decode_speedup",
         res["cola"]["decode_tok_per_s"] / res["dense"]["decode_tok_per_s"],
         "paper Table 11: 1.64x on A100 (CPU-relative here)")


def _serve_sharded_bench(emit):
    """serve_sharded/* rows — the distributed-serving story in numbers:

    * modeled per-dispatch collective wire bytes for one decode step per
      TP profile (``cola_ae_collective_bytes(mode='infer')`` over a
      column- plus a row-class site: baseline pays a (T, d_out) out-psum
      everywhere, megatron one f32 (T, r) z_pre psum at the decode-split
      seam of o/down only),
    * modeled per-shard decode HBM bytes (``decode_hbm_traffic`` with the
      profile's shard counts — weight traffic drops by the TP degree),
    * measured paged-vs-dense KV-cache HBM from a served ragged batch
      (pages released at finish ⇒ peak < dense worst case).

    Like _cola_ae_sharded_bench this uses whatever host devices exist;
    the shard terms use the actual 'model' axis size."""
    from repro.distributed import sharding as sh
    from repro.kernels.cola_ae import kernel as cak
    from repro.serve.engine import make_engine
    from repro.serve.scheduler import Request

    n = jax.device_count()
    model = max(m for m in (1, 2, 4, 8) if m <= n and n % m == 0)
    mesh = jax.make_mesh((n // model, model), ("data", "model"))
    B = 4                           # decode slot batch: T = B × 1
    din, r, dout = 2048, 512, 2048  # llama-1b o-proj-class site
    for profile in ("baseline", "megatron"):
        with sh.mesh_env(mesh, profile) as env:
            col = sh.cola_ae_partition(env, (B, 1, din), (din, r),
                                       (r, dout), "embed", "ffw")
            row = sh.cola_ae_partition(env, (B, 1, dout), (dout, r),
                                       (r, din), "ffw", "embed")
            cb = (sh.cola_ae_collective_bytes(env, col, B, din, r, dout,
                                              mode="infer")
                  + sh.cola_ae_collective_bytes(env, row, B, dout, r, din,
                                                mode="infer"))
        emit(f"serve_sharded/{profile}_decode_collective_KB", cb / 2**10,
             f"model={model} col+row site pair, one decode step, B={B}")
        if profile == "baseline":
            hbm = 2 * cak.decode_hbm_traffic(B, din, r, dout,
                                             shards_rank=model)
        else:
            hbm = (cak.decode_hbm_traffic(B, din, r, dout,
                                          shards_out=model)
                   + cak.decode_hbm_traffic(B, dout, r, din,
                                            shards_in=model, split=True))
        full = 2 * cak.decode_hbm_traffic(B, din, r, dout)
        emit(f"serve_sharded/{profile}_decode_shard_hbm_MB", hbm / 2**20,
             f"unsharded={full / 2**20:.2f}MB "
             f"({full / hbm:.2f}x less weight traffic per device)")

    # measured cache footprint: serve a ragged batch through the paged
    # engine and compare its peak page-backed bytes to the dense layout
    rng = np.random.RandomState(0)
    cfg = get_config("qwen2-1.5b").smoke()
    eng = make_engine(cfg, max_batch=4, max_seq=128, decode_block=8,
                      page_size=16)
    reqs = [Request(uid=i, prompt=rng.randint(
                1, cfg.vocab_size, (L,)).astype(np.int32),
                    max_new_tokens=16)
            for i, L in enumerate([8, 24, 48, 12, 30, 6])]
    eng.serve(reqs)
    hbm = eng.cache_hbm_bytes()
    emit("serve_sharded/kv_cache_paged_peak_MB", hbm["paged_bytes"] / 2**20,
         f"page_size=16 peak_pages={eng.alloc.peak_pages} "
         f"(pages released at finish)")
    emit("serve_sharded/kv_cache_dense_MB", hbm["dense_bytes"] / 2**20,
         f"B=4 max_seq=128 dense layout, "
         f"paged_saving={hbm['dense_bytes'] / hbm['paged_bytes']:.2f}x")


def _serve_spec_bench(emit, quick=False):
    """serve_spec/* rows — speculative decoding with the low-rank
    self-draft (serve/draft.py + engine.spec_chunk):

    * accepted-tokens/s through the spec engine vs plain decode on the
      same trained model (the committed full run must clear 1.0x),
    * modeled weight-stream HBM per accepted token vs plain decode
      (draft.spec_hbm_per_accepted_token) + the measured KV footprint the
      draft cache adds,
    * acceptance rate vs draft rank: rank-energy drafts at three alpha
      levels, each row noting the mean per-site draft rank.

    The full run trains a 12-layer llama-60m smoke model for 200 steps on
    a high-determinism markov:0.95 corpus — an untrained model has no
    sequential structure for a *depth*-truncated draft to predict, so
    acceptance (and any wall-clock win) only exists post-training.
    ``quick`` (CI schema checks) keeps every row name but swaps in an
    untrained model with a rank-energy draft and a short token budget.
    """
    from repro.data.synthetic import MarkovZipf
    from repro.serve import draft as draft_mod
    from repro.serve.engine import make_engine
    from repro.train.loop import train

    layers = 4 if quick else 12
    steps = 0 if quick else 200
    new_tokens = 8 if quick else 32
    window = 3
    mc = get_config("llama-60m").smoke().with_overrides(num_layers=layers)
    params = None
    if steps:
        tc = TrainConfig(steps=steps, global_batch=8, seq_len=128,
                         data="markov:0.95", log_every=100)
        params = train(mc, tc)["state"].params
    # corpus-like prompts: the draft only has structure to predict on
    # sequences from the training distribution
    prompts = MarkovZipf(mc.vocab_size, seed=0,
                         markov_p=0.95).batch(999, 8, 16)["tokens"]
    prompts = np.asarray(prompts, np.int32)

    def tok_per_s(eng):
        eng.generate(prompts, new_tokens)          # compile
        _, s = eng.generate(prompts, new_tokens)   # steady state
        return s

    # depth draft at the calibrated operating point: keep the first 4 of
    # 12 periods (prefix mode — briefly trained models concentrate
    # next-token signal in early blocks); quick mode has no training, so
    # a rank-energy draft keeps acceptance nonzero at random init
    plain = make_engine(mc, params, max_batch=8, max_seq=64,
                        decode_block=8, seed=0)
    spec = make_engine(mc, params, max_batch=8, max_seq=64,
                       decode_block=8, seed=0, speculate=True,
                       spec_window=window,
                       **(dict(draft_alpha=0.95) if quick else
                          dict(draft_depth=3, draft_depth_mode="prefix")))
    sp = tok_per_s(plain)
    ss = tok_per_s(spec)
    plain_tps = sp["decode_tok_per_s"]
    spec_tps = ss["decode_tok_per_s"]  # emitted == accepted stream
    emit("serve_spec/plain_tok_s", plain_tps,
         f"B=8 new={new_tokens} k=8, llama-60m smoke {layers}L "
         f"{'untrained' if quick else 'trained markov:0.95'}")
    emit("serve_spec/accepted_tok_s", spec_tps,
         f"w={window} draft={spec.draft_plan.describe()['depth'] or 'rank'}"
         f" speedup_vs_plain={spec_tps / plain_tps:.2f}x")
    emit("serve_spec/acceptance_rate", ss["spec_acceptance_rate"],
         f"drafted={ss['spec_drafted']} accepted={ss['spec_accepted']}")
    emit("serve_spec/mean_emitted_per_round", ss["spec_mean_emitted"],
         f"window={window} (upper bound)")

    # modeled weight-stream HBM per accepted token (the draft's factors
    # are views — no extra weight bytes at rest, only streamed reads)
    hbm = draft_mod.spec_hbm_per_accepted_token(
        spec.draft_plan, window, ss["spec_mean_emitted"])
    emit("serve_spec/model_hbm_plain_B_per_tok",
         hbm["plain_bytes_per_token"], "full factor stream, one token")
    emit("serve_spec/model_hbm_spec_B_per_accepted_tok",
         hbm["spec_bytes_per_accepted_token"],
         f"ratio_vs_plain={hbm['hbm_ratio_vs_plain']:.2f}x "
         f"(draft_step={hbm['draft_step_bytes'] / 2**10:.1f}KB)")
    # measured KV footprint: the draft cache is the only extra HBM the
    # spec engine holds (weights are shared views)
    full_kv = spec.cache_hbm_bytes()["pool_bytes"]
    draft_kv = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree.leaves(spec._draft_caches))
    emit("serve_spec/kv_cache_draft_MB", draft_kv / 2**20,
         f"full_pool={full_kv / 2**20:.2f}MB "
         f"(+{100 * draft_kv / full_kv:.0f}% for the draft pool)")

    # acceptance vs draft rank: rank-energy drafts at three alpha levels
    for alpha in (0.80, 0.90, 0.99):
        eng = make_engine(mc, params, max_batch=8, max_seq=64,
                          decode_block=8, seed=0, speculate=True,
                          draft_alpha=alpha, spec_window=window)
        eng.generate(prompts, 4 if quick else 12)
        s = eng.stats()
        ranks = [d for _, d in
                 eng.draft_plan.describe()["site_ranks"].values()]
        emit(f"serve_spec/acceptance_alpha_{alpha:.2f}",
             s["spec_acceptance_rate"],
             f"mean_draft_rank={np.mean(ranks):.1f} "
             f"(full={np.mean([r for r, _ in eng.draft_plan.describe()['site_ranks'].values()]):.0f})")


def _serve_quant_bench(emit, quick=False):
    """serve_quant/* rows — quantized weight streaming for decode
    (kernels/cola_ae/quant.py + the quant decode kernels):

    * measured decode tok/s for bf16/int8/int4 engines on the same
      trained model, plain and speculative (rank-energy draft over the
      quantized factors) — all three run the fused Pallas path (interpret
      mode off-TPU) so CPU numbers compare like structure with like,
    * modeled weight-stream HBM bytes per decode token at the llama-1b
      o-proj-class site (``decode_hbm_traffic(weight_bits=...)`` minus
      the activation bytes): the acceptance bar is ≥1.8x (int8) / ≥3.2x
      (int4) vs bf16 — below the raw 2x/4x because the f32 per-row/
      -column scales are charged honestly,
    * measured top-1 greedy agreement vs the bf16 engine (per-step,
      counted only while the context prefixes still match) — the quality
      column that keeps the byte wins honest.

    Same model recipe as _serve_spec_bench: the full run trains a
    12-layer llama-60m smoke model on markov:0.95; ``quick`` keeps every
    row name with an untrained 4-layer model and short budgets.
    """
    from repro.data.synthetic import MarkovZipf
    from repro.kernels.cola_ae import kernel as cak
    from repro.kernels.cola_ae import ops as cao
    from repro.serve.engine import make_engine
    from repro.train.loop import train

    layers = 4 if quick else 12
    steps = 0 if quick else 200
    new_tokens = 8 if quick else 32
    window = 3
    mc = get_config("llama-60m").smoke().with_overrides(num_layers=layers)
    params = None
    if steps:
        tc = TrainConfig(steps=steps, global_batch=8, seq_len=128,
                         data="markov:0.95", log_every=100)
        params = train(mc, tc)["state"].params
    prompts = MarkovZipf(mc.vocab_size, seed=0,
                         markov_p=0.95).batch(999, 8, 16)["tokens"]
    prompts = np.asarray(prompts, np.int32)

    def agreement(got, want):
        # per-step top-1: count a position only while its row's prefixes
        # still match (identical context -> argmax-vs-argmax comparison)
        same = np.asarray(got) == np.asarray(want)
        ctx = np.cumprod(np.concatenate(
            [np.ones((same.shape[0], 1), bool), same[:, :-1]], axis=1),
            axis=1).astype(bool)
        return float(same[ctx].mean())

    din, r, dout = 2048, 512, 2048  # llama-1b o-proj-class site
    act = 2 * (din + dout)          # bf16 activation bytes, T=1
    stream_bf16 = cak.decode_hbm_traffic(1, din, r, dout) - act
    streams = {}
    for wd in ("bf16", "int8", "int4"):
        with cao.force_impl("pallas", True):
            eng = make_engine(mc, params, max_batch=8, max_seq=64,
                              decode_block=8, seed=0, weight_dtype=wd)
            eng.generate(prompts, new_tokens)            # compile
            toks, s = eng.generate(prompts, new_tokens)  # steady state
            spec = make_engine(mc, params, max_batch=8, max_seq=64,
                               decode_block=8, seed=0, weight_dtype=wd,
                               speculate=True, draft_alpha=0.95,
                               spec_window=window)
            spec.generate(prompts, new_tokens)
            _, ss = spec.generate(prompts, new_tokens)
        streams[wd] = toks
        emit(f"serve_quant/plain_tok_s_{wd}", s["decode_tok_per_s"],
             f"B=8 new={new_tokens} k=8, llama-60m smoke {layers}L "
             f"{'untrained' if quick else 'trained markov:0.95'}, "
             f"fused Pallas path for all dtypes")
        emit(f"serve_quant/spec_tok_s_{wd}", ss["decode_tok_per_s"],
             f"w={window} alpha=0.95 "
             f"acceptance={ss['spec_acceptance_rate']:.3f} "
             f"(draft gathers q codes, shares scales)")
        bits = None if wd == "bf16" else int(wd[3:])
        stream = cak.decode_hbm_traffic(1, din, r, dout,
                                        weight_bits=bits) - act
        emit(f"serve_quant/weight_stream_B_per_tok_{wd}", stream,
             f"modeled, d_in={din} r={r} d_out={dout} T=1 "
             f"(q codes + f32 scales), "
             f"ratio_vs_bf16={stream_bf16 / stream:.2f}x")
        if wd != "bf16":
            emit(f"serve_quant/top1_agreement_{wd}",
                 agreement(streams[wd], streams["bf16"]),
                 f"greedy argmax vs bf16 engine, same-context decode "
                 f"steps, {'untrained' if quick else 'trained'} {layers}L")


def _serve_overlap_bench(emit, quick=False):
    """serve_overlap/* rows — chunked prefill with prefill/decode overlap
    (engine.mixed_chunk) under an admission-churn trace:

    * measured per-request TTFT p95 and inter-token-latency p95 with
      overlap on vs off — same trace, same model, warm jits (the warmup
      pass serves the identical trace so every (c, k) mixed shape is
      compiled before timing).  The ITL tail is exactly the admission
      stall the fused mixed dispatch removes: with overlap off, every
      resident stream stalls for a full monolithic prefill each time a
      slot turns over; with overlap on the stall is bounded by one
      chunk.  Fusing admission into the decode dispatch also drops the
      dedicated stall dispatch per turnover, which shortens queue
      waits — the TTFT tail — instead of trading them away,
    * modeled per-chunk weight re-stream overhead: each prefill chunk
      streams the A/B factors once, so an L-token prompt at chunk width
      c re-reads the weights ceil(L/c) - 1 extra times vs a monolithic
      prefill (``decode_hbm_traffic`` at the o-proj-class site) — the
      compute-side price of the latency win.

    ``quick`` keeps every row name on a shorter trace (CI schema
    checks)."""
    from repro.kernels.cola_ae import kernel as cak
    from repro.serve.engine import make_engine
    from repro.serve.scheduler import Request

    rng = np.random.RandomState(0)
    cfg = get_config("qwen2-1.5b").smoke()
    n_short = 6 if quick else 22
    anchor_budget = 41 if quick else 133
    budget, plen, chunk = 9, 288, 144
    # Admission-stall churn with controlled turnover clustering.  Three
    # long-lived "anchor" streams pin three of the four slots and
    # decode for the whole run — they are the residents that feel
    # every admission.  The short requests churn one at a time through
    # the fourth slot, so every short is its own turnover and the
    # number of admissions per prefill-bearing dispatch is identical
    # in both modes — otherwise the non-overlapped engine batches
    # whatever piled up behind its longer stalled rounds into one
    # monolithic prefill and the comparison conflates fusion with
    # admission batching.  The prompt spans two chunks, so the
    # non-overlapped engine stalls the anchors for a full 288-token
    # monolithic prefill at every turnover while the overlap engine
    # bounds each stall at one 144-token chunk — the measured ITL tail
    # gap is exactly that bound, and the restream row below prices the
    # extra weight stream the second chunk costs on a real
    # accelerator.  decode_block = 4 keeps each dispatch short enough that
    # admission rounds are >5% of *token* samples (k - 1 of every k
    # inter-token gaps are zero inside a chunk), so the ITL p95 — not
    # just the p99 — lands on the stall gap the fusion removes.  All
    # budgets ≡ 1 (mod decode_block) keep every slot's remaining count
    # on multiples of 4 after its first token, whenever it was
    # admitted, so the clamped decode width is always exactly k = 4:
    # the jitted shape family is tiny and deterministic and the warmup
    # serve compiles all of it.
    budgets = [anchor_budget] * 3 + [budget] * n_short
    n_reqs = len(budgets)
    arrivals = np.concatenate(
        [[0.0, 0.0, 0.0], np.cumsum(rng.uniform(0.0, 0.01, n_short))])
    prompts = [rng.randint(1, cfg.vocab_size, (plen,)).astype(np.int32)
               for _ in range(n_reqs)]

    def trace():
        return [Request(uid=i, prompt=prompts[i],
                        max_new_tokens=budgets[i],
                        arrival_s=float(arrivals[i]))
                for i in range(n_reqs)]

    stats = {}
    for overlap in (True, False):
        eng = make_engine(cfg, max_batch=4, max_seq=448, decode_block=4,
                          prefill_chunk=chunk, overlap=overlap)
        eng.serve(trace())   # compile every (c, k) shape on this trace
        reps = []
        for _ in range(2):   # best-of-2: shed OS-scheduling stragglers
            eng.reset_stats()
            eng.serve(trace())   # steady state
            reps.append(eng.stats())
        stats[overlap] = {
            k: (min(r[k] for r in reps) if k.endswith("_s") else v)
            for k, v in reps[-1].items()}
    on, off = stats[True], stats[False]
    note = (f"B=4 k=4 chunk={chunk} reqs={n_reqs} prompt={plen} "
            f"new={budget} (3 anchors new={anchor_budget}), qwen2 smoke")
    emit("serve_overlap/ttft_p95_ms_overlap", on["ttft_p95_s"] * 1e3,
         f"p50={on['ttft_p50_s'] * 1e3:.1f}ms "
         f"mixed_dispatches={on['mixed_dispatches']} " + note)
    emit("serve_overlap/ttft_p95_ms_no_overlap", off["ttft_p95_s"] * 1e3,
         f"p50={off['ttft_p50_s'] * 1e3:.1f}ms "
         f"overlap/no_overlap="
         f"{on['ttft_p95_s'] / off['ttft_p95_s']:.2f}x (bound: 1.10x)")
    emit("serve_overlap/itl_p95_ms_overlap", on["itl_p95_s"] * 1e3,
         f"p50={on['itl_p50_s'] * 1e3:.2f}ms p99="
         f"{on['itl_p99_s'] * 1e3:.1f}ms " + note)
    emit("serve_overlap/itl_p95_ms_no_overlap", off["itl_p95_s"] * 1e3,
         f"p50={off['itl_p50_s'] * 1e3:.2f}ms p99="
         f"{off['itl_p99_s'] * 1e3:.1f}ms tail_cut="
         f"{off['itl_p95_s'] / on['itl_p95_s']:.2f}x with overlap")
    # modeled weight re-stream overhead of chunking (o-proj-class site):
    # one extra full factor stream per extra chunk, T = B×c resident
    din, r, dout = 2048, 512, 2048
    per_chunk = cak.decode_hbm_traffic(4 * chunk, din, r, dout)
    extra = -(-plen // chunk) - 1
    emit("serve_overlap/chunk_weight_restream_MB", per_chunk / 2**20,
         f"modeled per extra prefill chunk, d_in={din} r={r} d_out={dout}"
         f" T={4 * chunk}; extra chunks/prompt={extra} at chunk={chunk}")


def run(emit):
    _cola_ae_bwd_bench(emit)
    _cola_ae_split_bench(emit)
    _cola_ae_sharded_bench(emit)
    _cola_ae_decode_bench(emit)
    _serve_engine_bench(emit)
    _serve_sharded_bench(emit)
    _serve_overlap_bench(emit)
    _serve_spec_bench(emit)
    _serve_quant_bench(emit)
    variants = {
        "full_rank": dict(parameterization="dense", remat="none"),
        "vanilla_gcp": dict(parameterization="dense", remat="full"),
        "cola": dict(parameterization="cola", remat="none"),
        "cola_m": dict(parameterization="cola", remat="cola_m"),
    }
    tokens = 4 * 256
    times = {}
    for name, over in variants.items():
        cfg = get_config("llama-60m").with_overrides(**over)
        dt = _step_time(cfg)
        times[name] = dt
        emit(f"table9_step_s/{name}", dt, f"tok_per_s={tokens/dt:.0f}")
    emit("fig8/cola_speedup_vs_full", times["full_rank"] / times["cola"],
         "paper: 1.86x on A100")
    emit("fig8/colam_speedup_vs_gcp", times["vanilla_gcp"] / times["cola_m"],
         "paper: CoLA-M > GCP")
