"""Paper Tables 2 & 3: per-layer compute of each pre-training method,
plus validation of the analytical CoLA/full-rank model against the
loop-aware HLO measurement of the real train step."""
import time

import jax
import jax.numpy as jnp

from repro.analysis.hlo import analyze
from repro.config import TrainConfig, get_config
from repro.core import flops
from repro.models.model import build_model
from repro.train.step import build_train_step, make_train_state


def run(emit):
    cfg = get_config("llama-1b")
    dims = flops.LayerDims.from_config(cfg, n=256)
    c_full = flops.full_rank(dims)
    for method in ("full_rank", "cola", "cola_m", "lora", "sltrain",
                   "galore", "vanilla_gcp"):
        c = flops.per_layer(method, dims)
        emit(f"table3/{method}", c, f"{c / c_full:.3f}x_full_rank")

    # measured: tiny configs, dense vs cola train-step HLO flops
    measured = {}
    for param in ("dense", "cola"):
        cfg_s = get_config("llama-60m").with_overrides(
            parameterization=param, remat="none")
        model = build_model(cfg_s)
        tc = TrainConfig(steps=10, global_batch=2, seq_len=256)
        state = jax.eval_shape(
            lambda: make_train_state(model, tc, jax.random.PRNGKey(0)))
        step = build_train_step(model, tc)
        batch = {"tokens": jax.ShapeDtypeStruct((2, 256), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((2, 256), jnp.int32)}
        comp = jax.jit(step).lower(state, batch).compile()
        measured[param] = analyze(comp.as_text())["flops"]
        emit(f"measured_hlo/{param}", measured[param], "llama-60m@2x256")
    ratio = measured["cola"] / measured["dense"]
    # analytic ratio for the same config (embeddings excluded from model
    # but dominate at 60M; compare layer-only portion)
    dims60 = flops.LayerDims.from_config(get_config("llama-60m"), n=256)
    ana = flops.cola(dims60) / flops.full_rank(dims60)
    emit("measured_vs_analytic/cola_over_full", ratio,
         f"analytic_layer_only={ana:.3f}")
