"""Paper Table 11 analogue: inference throughput + parameter bytes,
CoLA vs full-rank, through the serve engine."""
import jax
import numpy as np

from repro.config import get_config
from repro.serve.engine import make_engine


def run(emit):
    stats = {}
    for param in ("dense", "cola"):
        cfg = get_config("llama-60m").with_overrides(parameterization=param)
        eng = make_engine(cfg, max_batch=4, max_seq=96)
        n_params = sum(x.size for x in jax.tree.leaves(eng.params))
        prompts = np.ones((4, 32), np.int32)
        _, s = eng.generate(prompts, 32)  # warmup+measure in one (compile
        _, s = eng.generate(prompts, 32)  # second run = steady state
        stats[param] = (s["decode_tok_per_s"], n_params)
        emit(f"table11_decode_tok_s/{param}", s["decode_tok_per_s"],
             f"params={n_params/1e6:.1f}M")
    emit("table11/cola_speedup", stats["cola"][0] / stats["dense"][0],
         "paper: 1.64x on A100")
    emit("table11/param_reduction", stats["dense"][1] / stats["cola"][1],
         "paper: ~2x smaller")
