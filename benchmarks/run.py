# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: flops,memory,pretrain,throughput,"
                         "inference,roofline")
    args = ap.parse_args()

    from benchmarks import (flops_table, inference_table, memory_table,
                            pretrain_table, roofline_table, scaling_table,
                            throughput_table)
    tables = {
        "flops": flops_table,
        "memory": memory_table,
        "throughput": throughput_table,
        "inference": inference_table,
        "pretrain": pretrain_table,
        "scaling": scaling_table,
        "roofline": roofline_table,
    }
    sel = args.only.split(",") if args.only else list(tables)
    print("name,us_per_call,derived")

    def emit(name, value, derived=""):
        print(f"{name},{float(value):.6g},{derived}", flush=True)

    for key in sel:
        t0 = time.time()
        tables[key].run(emit)
        emit(f"_bench_wall_s/{key}", time.time() - t0)


if __name__ == "__main__":
    main()
