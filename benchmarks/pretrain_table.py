"""Paper Table 5 / Table 7 analogue at container scale: validation loss of
full-rank vs CoLA vs Control (width-scaled full-rank at CoLA's FLOPs) vs
GaLore vs SLTrain vs ReLoRA on the deterministic synthetic corpus.

Absolute perplexities are not comparable to the paper's C4 numbers (no C4
offline); the *ordering and gaps* are the reproduction target:
CoLA ≈ full-rank, Control worse, baselines ≳ full-rank (paper §5.1).

Learning rates follow paper App. D: 3e-3 for full-rank/baselines (the
Han et al. setup the paper inherits) and 6e-3 for small-scale CoLA ("for
smaller models like CoLA-60M, an even larger learning rate such 0.006 can
be adopted") — measured here: CoLA@6e-3 beats full-rank@3e-3 while
CoLA@3e-3 trails it, reproducing the paper's LR sensitivity note."""
import dataclasses

import numpy as np

from repro.config import TrainConfig, get_config
from repro.train.loop import train

STEPS = 150
COLA_LR = 6e-3  # paper App. D, small-model regime


def _cfg(param, **kw):
    cfg = get_config("llama-60m").smoke().with_overrides(
        parameterization=param, **kw)
    return cfg


def run(emit):
    tc = TrainConfig(steps=STEPS, global_batch=8, seq_len=128,
                     learning_rate=3e-3, log_every=0,
                     eval_every=0)
    results = {}

    def eval_loss(cfg, tc=tc):
        out = train(cfg, tc)
        return out["ce_loss"]

    results["full_rank"] = eval_loss(_cfg("dense"))
    results["cola"] = eval_loss(
        _cfg("cola"), dataclasses.replace(tc, learning_rate=COLA_LR))
    # Control: full-rank scaled down to CoLA's FLOPs class (paper Table 7):
    # halve d_ff and width-related dims
    ctl = _cfg("dense")
    ctl = dataclasses.replace(ctl, d_ff=ctl.d_ff // 2, d_model=48,
                              head_dim=12)
    results["control"] = eval_loss(ctl)
    results["sltrain"] = eval_loss(_cfg("sltrain"))
    relora = _cfg("lora")
    relora = dataclasses.replace(
        relora, lora=dataclasses.replace(relora.lora, relora_every=40))
    results["relora"] = eval_loss(relora)
    results["galore"] = eval_loss(
        _cfg("dense"), dataclasses.replace(tc, galore_rank=8,
                                           galore_update_every=40))

    for k, v in results.items():
        emit(f"table5_ce/{k}", v, f"ppl={np.exp(min(v, 20)):.2f}")
    emit("table5_gap/cola_minus_full",
         results["cola"] - results["full_rank"],
         "paper: ~0 (34.04 vs 34.06)")
    emit("table7_gap/control_minus_cola",
         results["control"] - results["cola"],
         "paper: control significantly worse")
