"""Paper Table 4 / Fig. 7: activation memory + recompute across
checkpointing strategies — analytical model + measured saved-residual
bytes per remat policy on a real (small) stack."""
import io
import contextlib
import re

import jax
import jax.numpy as jnp

from repro.config import get_config
from repro.core import memory
from repro.models.model import build_model
from repro.train.step import build_loss_fn

_SHAPE = re.compile(r"(f32|bf16|i32|s32|bool|pred)\[([0-9,]+)\]")
_BYTES = {"f32": 4, "bf16": 2, "i32": 4, "s32": 4, "bool": 1, "pred": 1}


def _saved_bytes(cfg):
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 256), jnp.int32),
             "labels": jnp.ones((2, 256), jnp.int32)}
    loss_fn = build_loss_fn(model)
    buf = io.StringIO()
    with contextlib.redirect_stdout(buf):
        jax.ad_checkpoint.print_saved_residuals(loss_fn, params, batch)
    total = 0
    for ln in buf.getvalue().splitlines():
        if "from the argument" in ln:
            continue  # params, not activations
        m = _SHAPE.search(ln)
        if m:
            n = 1
            for d in m.group(2).split(","):
                n *= int(d)
            total += n * _BYTES[m.group(1)]
    return total


def run(emit):
    cfg1b = get_config("llama-1b")
    t = memory.model_totals(cfg1b, n=256)
    for k, v in t.items():
        emit(f"table4_elems/{k}", v, "llama-1b@n256")
    emit("fig7/recompute_reduction_vs_gcp",
         memory.recompute_reduction_vs_gcp(cfg1b, 256), "paper=4.6x")

    base = get_config("llama-60m")
    for policy in ("none", "full", "cola_m", "dots"):
        b = _saved_bytes(base.with_overrides(remat=policy))
        emit(f"measured_residual_bytes/{policy}", b, "llama-60m@2x256")
