"""Committed serving benchmark: BENCH_serve.json at the repo root.

    # regenerate the committed file (trains the spec-decode model — ~3min)
    PYTHONPATH=src python -m benchmarks.serve_json --out BENCH_serve.json

    # CI schema gate: regenerate quickly (untrained model, short budgets)
    # and fail if the row-name schema drifted from the committed file
    PYTHONPATH=src python -m benchmarks.serve_json --quick \
        --check BENCH_serve.json

The file holds the serving rows of benchmarks/throughput_table.py —
plain continuous-batching engine rows (serve/*), the chunked-prefill
latency rows (serve_overlap/*: TTFT p95 + inter-token-latency p95 with
overlap on vs off under a churny staggered-arrival trace, modeled
per-chunk weight re-stream overhead), the speculative-decoding rows
(serve_spec/*), and the quantized-weight-streaming rows
(serve_quant/*: bf16/int8/int4 tok/s plain + speculative, modeled
weight-stream bytes/token, top-1 agreement vs bf16) — as
``{"schema_version", "mode", "rows": [{"name", "value", "note"}]}``.  Values are machine-relative and drift
freely; the *row names* are the contract: a PR that renames, drops or
adds a serving metric must regenerate the committed file in the same
change, or the CI check fails with the name diff.
"""
import argparse
import json
import sys

SCHEMA_VERSION = 1


def collect(quick: bool):
    from benchmarks import throughput_table as tt
    rows = []

    def emit(name, value, note=""):
        rows.append({"name": name, "value": float(value), "note": note})
        print(f"{name},{float(value):.6g},{note}", flush=True)

    tt._serve_engine_bench(emit)
    tt._serve_overlap_bench(emit, quick=quick)
    tt._serve_spec_bench(emit, quick=quick)
    tt._serve_quant_bench(emit, quick=quick)
    return {"schema_version": SCHEMA_VERSION,
            "mode": "quick" if quick else "full",
            "rows": rows}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="write the collected rows to this JSON file")
    ap.add_argument("--check", default=None,
                    help="compare row-name schema against this committed "
                         "JSON file; exit nonzero on drift")
    ap.add_argument("--quick", action="store_true",
                    help="untrained model + short budgets (same row "
                         "names; CI schema checks)")
    args = ap.parse_args()
    if not args.out and not args.check:
        ap.error("need --out and/or --check")

    doc = collect(args.quick)

    if args.out:
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=1)
            f.write("\n")
        print(f"wrote {args.out} ({len(doc['rows'])} rows, "
              f"mode={doc['mode']})")

    if args.check:
        with open(args.check) as f:
            want = json.load(f)
        errs = []
        if want.get("schema_version") != SCHEMA_VERSION:
            errs.append(f"schema_version: committed "
                        f"{want.get('schema_version')} != {SCHEMA_VERSION}")
        got_names = sorted(r["name"] for r in doc["rows"])
        want_names = sorted(r["name"] for r in want.get("rows", []))
        missing = sorted(set(want_names) - set(got_names))
        extra = sorted(set(got_names) - set(want_names))
        if missing:
            errs.append(f"rows in {args.check} no longer emitted: "
                        f"{missing}")
        if extra:
            errs.append(f"new rows not in {args.check}: {extra} "
                        f"— regenerate it (--out) and commit")
        if errs:
            print("SCHEMA DRIFT:\n  " + "\n  ".join(errs), file=sys.stderr)
            sys.exit(1)
        print(f"schema check OK: {len(want_names)} rows match "
              f"{args.check}")


if __name__ == "__main__":
    main()
