"""§Roofline summary: aggregates the dry-run sweep's per-cell JSONs into
the EXPERIMENTS.md table (runs on whatever cells exist under
experiments/dryrun/)."""
import glob
import json
import os


def rows(variant_glob="experiments/dryrun/*/*.json"):
    out = []
    for path in sorted(glob.glob(variant_glob)):
        with open(path) as f:
            rec = json.load(f)
        if "roofline" in rec:
            out.append(rec)
    return out


def run(emit):
    for rec in rows():
        r = rec["roofline"]
        cell = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}/{rec['variant']}"
        emit(f"roofline_step_s/{cell}", r["step_s"],
             f"bound={r['bound']};frac={r['roofline_fraction']:.4f};"
             f"mem_gb={rec['peak_bytes_per_chip']/1e9:.2f}")
