"""Paper Table 7: CoLA's scaling behaviour in rank — the default r = d/4
(≈0.4× compute) matches full-rank; a moderately larger rank (≈0.7×
compute) *outperforms* it while still being smaller and cheaper."""
import dataclasses

import numpy as np

from repro.config import TrainConfig, get_config
from repro.core import flops
from repro.train.loop import train

STEPS = 150


def run(emit):
    base = get_config("llama-60m").smoke()
    d = base.d_model
    tc = lambda lr: TrainConfig(steps=STEPS, global_batch=8, seq_len=128,
                                learning_rate=lr, log_every=0)
    results = {}
    results["full_rank_1.0x"] = train(
        base.with_overrides(parameterization="dense"), tc(3e-3))["ce_loss"]
    for tag, r in {"cola_0.4x": d // 4, "cola_0.7x": d // 2}.items():
        cfg = dataclasses.replace(
            base, cola=dataclasses.replace(base.cola, rank_attn=r,
                                           rank_mlp=r))
        results[tag] = train(cfg, tc(6e-3))["ce_loss"]
        dims = flops.LayerDims.from_config(cfg, n=256)
        dims = dataclasses.replace(dims, r=r)
        ratio = flops.cola(dims) / flops.full_rank(dims)
        emit(f"table7_flops_ratio/{tag}", ratio, f"rank={r}")
    for k, v in results.items():
        emit(f"table7_ce/{k}", v, f"ppl={np.exp(min(v, 20)):.2f}")
    emit("table7/larger_rank_beats_full",
         float(results["cola_0.7x"] < results["full_rank_1.0x"]),
         "paper: CoLA@0.7x beats full-rank at all scales")
