"""Flash attention reference: pure-jnp, memory-optimal via custom_vjp.

Forward saves only (q, k, v, out, lse); backward recomputes probabilities
blockwise (Dao et al. 2022 recurrences) — no (sq × skv) tensor and no
per-chunk scan residuals ever materialize.  This is both the oracle for the
Pallas kernel and the production fallback on non-TPU backends (used by
models/attention.py for every ≥1k-token attention).

Layout: q (b, sq, h, hd); k/v (b, skv, kvh, hd); GQA via h = kvh·g.
Masking is encoded in a per-query visibility horizon ``q_positions``
(b, sq): KV slot s is visible to query i iff s <= q_positions[b, i]
(plus s < true kv length).  causal=True with no explicit positions means
q_positions = arange(sq); causal=False means full visibility.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -1e30
Q_CHUNK = 512
KV_CHUNK = 1024


def flash_attention(q, k, v, causal: bool = True,
                    q_positions: Optional[jax.Array] = None,
                    chunks: Tuple[int, int] = (Q_CHUNK, KV_CHUNK)):
    b, sq = q.shape[0], q.shape[1]
    skv = k.shape[1]
    if q_positions is None:
        if causal:
            q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        else:
            q_positions = jnp.full((b, sq), skv - 1, jnp.int32)
    return _flash(q, k, v, q_positions.astype(jnp.int32), chunks)


def _chunks(n: int, c: int) -> int:
    return (n + c - 1) // c


def _pad_to(x: jax.Array, n: int, axis: int) -> jax.Array:
    pad = n - x.shape[axis]
    if pad <= 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def _flash(q, k, v, qpos, chunks):
    out, _ = _fwd_impl(q, k, v, qpos, chunks)
    return out


def _fwd_impl(q, k, v, qpos_arr, chunks):
    qc, kc = chunks
    b, sq0, h, hd = q.shape
    skv0, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    hv = v.shape[-1]
    sq, skv = _chunks(sq0, qc) * qc, _chunks(skv0, kc) * kc
    qp = _pad_to(q, sq, 1).reshape(b, sq, kvh, g, hd)
    kp = _pad_to(k, skv, 1)
    vp = _pad_to(v, skv, 1)
    qpos_p = _pad_to(qpos_arr, sq, 1)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / np.sqrt(hd)

    def q_block(qi):
        qb = jax.lax.dynamic_slice_in_dim(qp, qi * qc, qc, axis=1)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_p, qi * qc, qc, axis=1)

        def kv_step(carry, kj):
            m, l, acc = carry
            kb = jax.lax.dynamic_slice_in_dim(kp, kj * kc, kc, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, kj * kc, kc, 1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32)
            s = s * scale
            # loop-varying zero: ties the mask to the data so XLA's
            # while-loop-invariant code motion cannot hoist a precomputed
            # (nq, nk, b, …) boolean stack out of the loop (8.6 GB at 4k).
            lv0 = (s.reshape(-1)[0] * 0).astype(jnp.int32)
            kpos = kj * kc + jnp.arange(kc) + lv0
            ok = ((kpos[None, None, :] <= qpos[:, :, None]) &
                  (kpos[None, None, :] < skv0))
            s = jnp.where(ok[:, None, None, :, :], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            e = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(e, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", e, vb.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((b, kvh, g, qc), _NEG, jnp.float32),
                jnp.zeros((b, kvh, g, qc), jnp.float32),
                jnp.zeros((b, kvh, g, qc, hv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out, lse                       # (b,kvh,g,qc,hv), (b,kvh,g,qc)

    outs, lses = jax.lax.map(q_block, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 3).reshape(b, kvh, g, sq, hv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, hv)[:, :sq0]
    lse = jnp.concatenate(list(lses), axis=3)          # (b,kvh,g,sq)
    return out, lse


def _fwd_vjp(q, k, v, qpos, chunks):
    # NOTE: custom_vjp fwd receives args in original positions (nondiff
    # included); only bwd gets nondiff args first.
    out, lse = _fwd_impl(q, k, v, qpos, chunks)
    return out, (q, k, v, qpos, out, lse)


def _bwd_vjp(chunks, res, dout):
    q, k, v, qpos_arr, out, lse = res
    qc, kc = chunks
    b, sq0, h, hd = q.shape
    skv0, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    hv = v.shape[-1]
    sq, skv = _chunks(sq0, qc) * qc, _chunks(skv0, kc) * kc
    qp = _pad_to(q, sq, 1).reshape(b, sq, kvh, g, hd)
    kp = _pad_to(k, skv, 1)
    vp = _pad_to(v, skv, 1)
    op = _pad_to(out, sq, 1).reshape(b, sq, kvh, g, hv)
    dop = _pad_to(dout, sq, 1).reshape(b, sq, kvh, g, hv)
    lse_p = _pad_to(lse, sq, 3)
    qpos_p = _pad_to(qpos_arr, sq, 1)
    nq, nk = sq // qc, skv // kc
    scale = 1.0 / np.sqrt(hd)
    # D = rowsum(dout * out) — the softmax-grad diagonal term
    D = jnp.einsum("bskgh,bskgh->bkgs", dop.astype(jnp.float32),
                   op.astype(jnp.float32))

    def q_block(carry, qi):
        dk_acc, dv_acc = carry
        qb = jax.lax.dynamic_slice_in_dim(qp, qi * qc, qc, axis=1)
        dob = jax.lax.dynamic_slice_in_dim(dop, qi * qc, qc, axis=1)
        lseb = jax.lax.dynamic_slice_in_dim(lse_p, qi * qc, qc, axis=3)
        Db = jax.lax.dynamic_slice_in_dim(D, qi * qc, qc, axis=3)
        qpos = jax.lax.dynamic_slice_in_dim(qpos_p, qi * qc, qc, axis=1)

        @jax.checkpoint
        def kv_step(inner, kj):
            dq_b, dk_a, dv_a = inner
            kb = jax.lax.dynamic_slice_in_dim(kp, kj * kc, kc, 1)
            vb = jax.lax.dynamic_slice_in_dim(vp, kj * kc, kc, 1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qb, kb).astype(jnp.float32)
            s = s * scale
            lv0 = (s.reshape(-1)[0] * 0).astype(jnp.int32)  # defeat LICM
            kpos = kj * kc + jnp.arange(kc) + lv0
            ok = ((kpos[None, None, :] <= qpos[:, :, None]) &
                  (kpos[None, None, :] < skv0))
            s = jnp.where(ok[:, None, None, :, :], s, _NEG)
            p = jnp.exp(s - lseb[..., None])                    # (b,k,g,q,s)
            dv_blk = jnp.einsum("bkgqs,bqkgh->bskh", p,
                                dob.astype(jnp.float32))
            dp = jnp.einsum("bqkgh,bskh->bkgqs",
                            dob.astype(jnp.float32),
                            vb.astype(jnp.float32))
            ds = p * (dp - Db[..., None]) * scale
            dq_b = dq_b + jnp.einsum("bkgqs,bskh->bqkgh", ds,
                                     kb.astype(jnp.float32))
            dk_blk = jnp.einsum("bkgqs,bqkgh->bskh", ds,
                                qb.astype(jnp.float32))
            dk_a = jax.lax.dynamic_update_slice_in_dim(
                dk_a, jax.lax.dynamic_slice_in_dim(dk_a, kj * kc, kc, 1)
                + dk_blk, kj * kc, 1)
            dv_a = jax.lax.dynamic_update_slice_in_dim(
                dv_a, jax.lax.dynamic_slice_in_dim(dv_a, kj * kc, kc, 1)
                + dv_blk, kj * kc, 1)
            return (dq_b, dk_a, dv_a), None

        dq0 = jnp.zeros((b, qc, kvh, g, hd), jnp.float32)
        (dq_b, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(nk))
        return (dk_acc, dv_acc), dq_b

    dk0 = jnp.zeros((b, skv, kvh, hd), jnp.float32)
    dv0 = jnp.zeros((b, skv, kvh, hv), jnp.float32)
    (dk, dv), dqs = jax.lax.scan(q_block, (dk0, dv0), jnp.arange(nq))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, kvh, g, hd)
    dq = dq.reshape(b, sq, h, hd)[:, :sq0].astype(q.dtype)
    dqpos = np.zeros(qpos_arr.shape, jax.dtypes.float0)
    return (dq, dk[:, :skv0].astype(k.dtype), dv[:, :skv0].astype(v.dtype),
            dqpos)


_flash.defvjp(_fwd_vjp, _bwd_vjp)
