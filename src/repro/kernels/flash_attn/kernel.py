"""Pallas TPU flash-attention forward kernel.

Grid (b·h, nq): each step owns one (q-tile × head); the kv loop runs inside
with running (m, l, acc) in VMEM — score tiles never touch HBM.  GQA is
free: the k/v BlockSpec index_map maps query head → kv head (h // group),
no k/v expansion copy.  Causality via the per-query horizon ``q_positions``
(same contract as ref.py); fully-masked tiles are skipped with a cheap
bounds check on the block's position range.

Training uses ref.py's custom_vjp (whose fwd dispatches here on TPU via
ops.py); serving calls this directly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG = -1e30


def _fwd_kernel(qpos_ref, q_ref, k_ref, v_ref, o_ref, *, kc: int,
                skv: int, skv_valid: int, scale: float):
    """q_ref: (bq, hd); k_ref/v_ref: (skv, hd); qpos_ref: (bq,);
    o_ref: (bq, hd)."""
    bq, hd = q_ref.shape
    n_k = skv // kc
    q = q_ref[...]

    def body(kj, carry):
        m, l, acc = carry
        kb = k_ref[pl.ds(kj * kc, kc), :]
        vb = v_ref[pl.ds(kj * kc, kc), :]
        s = jnp.dot(q, kb.T, preferred_element_type=jnp.float32) * scale
        kpos = kj * kc + jax.lax.broadcasted_iota(jnp.int32, (1, kc), 1)
        ok = (kpos <= qpos_ref[...][:, None]) & (kpos < skv_valid)
        s = jnp.where(ok, s, _NEG)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        e = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(e, axis=-1)
        acc_new = acc * corr[:, None] + jnp.dot(
            e.astype(vb.dtype), vb, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), _NEG, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, n_k, body, (m0, l0, a0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, q_positions, *, q_block: int = 256,
                        kv_block: int = 512, interpret: bool = False):
    """q: (b, sq, h, hd); k/v: (b, skv, kvh, hd); q_positions: (b, sq)."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    bq = min(q_block, sq)
    while sq % bq:
        bq //= 2
    kc = min(kv_block, skv)
    skv_pad = ((skv + kc - 1) // kc) * kc
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
    # layout: (b, h, sq, hd) so each grid step is a clean 2D tile
    qt = jnp.moveaxis(q, 2, 1).reshape(b * h, sq, hd)
    kt = jnp.moveaxis(k, 2, 1).reshape(b * kvh, skv_pad, hd)
    vt = jnp.moveaxis(v, 2, 1).reshape(b * kvh, skv_pad, hd)
    qpos = jnp.repeat(q_positions.astype(jnp.int32), h, axis=0)  # (b*h, sq)

    grid = (b * h, sq // bq)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, kc=kc, skv=skv_pad,
                          skv_valid=skv, scale=1.0 / np.sqrt(hd)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq), lambda bh, qi: (bh, qi)),
            pl.BlockSpec((None, bq, hd), lambda bh, qi: (bh, qi, 0)),
            pl.BlockSpec((None, skv_pad, hd), lambda bh, qi: (bh // g, 0, 0)),
            pl.BlockSpec((None, skv_pad, hd), lambda bh, qi: (bh // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda bh, qi: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        interpret=interpret,
    )(qpos, qt, kt, vt)
    return jnp.moveaxis(out.reshape(b, h, sq, hd), 1, 2)


def flash_attention(q, k, v, *, causal=True, q_positions=None,
                    interpret: bool = False):
    b, sq = q.shape[:2]
    skv = k.shape[1]
    if q_positions is None:
        if causal:
            q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        else:
            q_positions = jnp.full((b, sq), skv - 1, jnp.int32)
    return flash_attention_fwd(q, k, v, q_positions, interpret=interpret)
