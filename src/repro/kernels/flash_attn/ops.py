"""Dispatching wrapper for flash attention.

``impl='ref'`` — pure-jnp custom_vjp (memory-optimal, runs everywhere; the
production fallback off-TPU and the oracle);
``impl='pallas'`` — the TPU kernel (kernel.py), validated in interpret mode.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.flash_attn import ref as _ref


def flash_attention(q, k, v, *, causal: bool = True,
                    q_positions: Optional[jax.Array] = None,
                    impl: str = "auto", interpret: bool = False):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.flash_attention(q, k, v, causal, q_positions)
    from repro.kernels.flash_attn import kernel as _k
    return _k.flash_attention(q, k, v, causal=causal,
                              q_positions=q_positions, interpret=interpret)
