"""Symmetric per-axis quantization for streamed CoLA-AE weight factors.

Decode is weight-traffic-bound: every A/B element is read from HBM
exactly once per token (see ``decode_hbm_traffic``).  Quantizing the
*streamed* representation to int8 or nibble-packed int4 shrinks that
dominant byte term by ~2x / ~4x while the in-register math stays f32:
the kernels stream q-blocks + their f32 scales through VMEM and
dequantize just before the MXU dot, so accumulation precision is
unchanged and the quantized kernel is bit-identical to running the
bf16 kernel on ``dequantize(...)`` of the same factors.

Layout contract (scale granularity follows the weight-grid streaming
axis so every grid step can dequantize its block locally):

* A factors (``kind='in'``, shape (..., d_in, r)) get one scale per
  *input row*: ``scale`` has shape (..., d_in, 1).  int4 packs two
  consecutive d_in rows per byte -> ``q`` is (..., d_in//2, r).
* B factors (``kind='out'``, shape (..., r, d_out)) get one scale per
  *output column*: ``scale`` has shape (..., 1, d_out).  int4 packs two
  consecutive d_out columns per byte -> ``q`` is (..., r, d_out//2).

Both layouts slice cleanly along the decode kernels' weight-grid axes
(d_in blocks for A, d_out blocks for B) and commute with tensor-
parallel sharding of d_in / d_out / rank, so factors are quantized
once globally and the *arrays* are sharded — sharded decode streams
local q-blocks with local scales and stays bit-identical to the
single-device quantized engine.

Symmetric quantization, zero-point-free:

    scale = max(|w|, eps) / q_max          q_max = 127 (int8), 7 (int4)
    q     = clip(round(w / scale), -q_max, q_max)
    w~    = q * scale

Nibble packing stores element ``2i`` in the low nibble and ``2i+1`` in
the high nibble of byte ``i``; unpacking sign-extends via int8
arithmetic shifts, so pack/unpack round-trips bit-exactly.

This module deliberately imports nothing from kernel.py/ops.py (they
import *it*) and nothing stateful: scale layout is a pure function of
the weight values, independent of PYTHONHASHSEED or dict order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_QMAX = {8: 127, 4: 7}
_KINDS = ("in", "out")


@jax.tree_util.register_pytree_node_class
class QuantFactor:
    """A quantized weight factor: packed int8 codes + f32 scales.

    Behaves enough like the array it replaces (``.shape``/``.ndim``
    report the *logical* unpacked shape) that the draft planner and
    sharding resolver work unchanged, while being a pytree whose
    leaves (q, scale) shard / gather / donate like plain arrays.
    """

    __slots__ = ("q", "scale", "kind", "bits")

    def __init__(self, q, scale, *, kind, bits):
        if kind not in _KINDS:
            raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
        if bits not in _QMAX:
            raise ValueError(f"bits must be one of {tuple(_QMAX)}, got {bits!r}")
        self.q = q
        self.scale = scale
        self.kind = kind
        self.bits = bits

    @property
    def shape(self):
        # logical (unpacked) shape: the packed axis is the non-rank
        # axis, whose true extent the scale layout always carries
        if self.kind == "in":      # q (..., d_in//pk, r), scale (..., d_in, 1)
            return tuple(self.scale.shape[:-1]) + (self.q.shape[-1],)
        # 'out':                   q (..., r, d_out//pk), scale (..., 1, d_out)
        return tuple(self.q.shape[:-1]) + (self.scale.shape[-1],)

    @property
    def ndim(self):
        return len(self.shape)

    def tree_flatten(self):
        return (self.q, self.scale), (self.kind, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        q, scale = children
        return cls(q, scale, kind=aux[0], bits=aux[1])

    def __repr__(self):
        return (f"QuantFactor(shape={self.shape}, kind={self.kind!r}, "
                f"bits={self.bits})")


def quantize_array(x, *, bits: int = 8, axis=None):
    """Symmetric quantization of ``x`` to ``bits`` with scales reduced
    over ``axis`` (None -> one scalar scale, the legacy
    optim/compression behaviour).  Returns ``(q, scale)`` with q int8
    (int4 values live in int8 storage until packed) and scale f32
    broadcastable against x."""
    qmax = _QMAX[bits]
    x32 = jnp.asarray(x, jnp.float32)
    if axis is None:
        amax = jnp.max(jnp.abs(x32))
    else:
        amax = jnp.max(jnp.abs(x32), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    q = jnp.clip(jnp.round(x32 / scale), -qmax, qmax).astype(jnp.int8)
    return q, scale


def pack_nibbles(q, axis: int = -1):
    """Pack int4 values (int8 storage, range [-7, 7]) pairwise along
    ``axis``: byte i holds element 2i in the low nibble and 2i+1 in
    the high nibble.  The packed axis must be even."""
    axis = axis % q.ndim
    if q.shape[axis] % 2:
        raise ValueError(
            f"int4 packing needs an even extent along axis {axis}, "
            f"got shape {q.shape}")
    lo = jax.lax.slice_in_dim(q, 0, None, stride=2, axis=axis)
    hi = jax.lax.slice_in_dim(q, 1, None, stride=2, axis=axis)
    return jnp.bitwise_or(jnp.bitwise_and(lo, jnp.int8(0x0F)),
                          jnp.left_shift(hi, jnp.int8(4)))


def unpack_nibbles(packed, axis: int = -1):
    """Inverse of :func:`pack_nibbles`: sign-extends both nibbles via
    int8 arithmetic shifts and re-interleaves along ``axis``."""
    axis = axis % packed.ndim
    lo = jnp.right_shift(jnp.left_shift(packed, jnp.int8(4)), jnp.int8(4))
    hi = jnp.right_shift(packed, jnp.int8(4))
    out = jnp.stack([lo, hi], axis=axis + 1)
    shape = packed.shape[:axis] + (2 * packed.shape[axis],) + packed.shape[axis + 1:]
    return out.reshape(shape)


def quantize_factor(w, kind: str, bits: int = 8) -> QuantFactor:
    """Quantize one CoLA-AE factor.  ``kind='in'`` for A (..., d_in, r)
    with per-d_in-row scales; ``kind='out'`` for B (..., r, d_out) with
    per-d_out-column scales.  int4 packs along the non-rank axis."""
    if kind not in _KINDS:
        raise ValueError(f"kind must be one of {_KINDS}, got {kind!r}")
    reduce_axis = -1 if kind == "in" else -2
    pack_axis = -2 if kind == "in" else -1
    q, scale = quantize_array(w, bits=bits, axis=reduce_axis)
    if bits == 4:
        q = pack_nibbles(q, axis=pack_axis)
    return QuantFactor(q, jnp.asarray(scale, jnp.float32), kind=kind, bits=bits)


def dequant_block(q_blk, s_blk, *, kind: str, bits: int):
    """Reference dequantization of one streamed block: unpack (int4),
    widen to f32, scale.  This exact expression runs inside the Pallas
    kernel bodies, so whole-tensor XLA dequantization (this function on
    the full q/scale arrays) is elementwise bit-identical to what the
    quantized kernels compute in-register."""
    if bits == 4:
        q_blk = unpack_nibbles(q_blk, axis=-2 if kind == "in" else -1)
    return q_blk.astype(jnp.float32) * s_blk


def dequantize(qf: QuantFactor):
    """Whole-factor f32 reconstruction (the XLA reference)."""
    return dequant_block(qf.q, qf.scale, kind=qf.kind, bits=qf.bits)


def _is_cola_site(node) -> bool:
    return isinstance(node, dict) and "a" in node and "b" in node


def quantize_params(params, bits: int = 8):
    """Quantize every CoLA-AE site (dicts carrying both "a" and "b")
    under ``params['blocks']``, leaving biases, embeddings, norms and
    the lm head untouched.  Returns a new tree; raises if the model has
    no factorized sites (dense parameterizations can't stream
    q-blocks)."""
    n_sites = 0

    def walk(node):
        nonlocal n_sites
        if _is_cola_site(node):
            n_sites += 1
            out = dict(node)
            out["a"] = quantize_factor(node["a"], "in", bits)
            out["b"] = quantize_factor(node["b"], "out", bits)
            return out
        if isinstance(node, dict):
            return {k: walk(node[k]) for k in sorted(node)}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    out = dict(params)
    out["blocks"] = walk(params["blocks"])
    if n_sites == 0:
        raise ValueError(
            "quantize_params found no CoLA-AE factor sites under "
            "params['blocks'] — weight-dtype quantization needs the "
            "factorized (cola) parameterization")
    return out
