"""Fused CoLA auto-encoder Pallas kernel: out = B · σ(A · x).

The paper's core op (Eq. 3) as one TPU kernel.  The r-dimensional
bottleneck ``z = σ(Ax)`` lives **entirely in VMEM scratch** — it never
round-trips to HBM, so the AE pair's HBM traffic drops from
``n(d_in + 2r + d_out)`` to ``n(d_in + d_out)`` plus weight tiles
(DESIGN.md §2: the paper's activation-residency idea pushed one level down
the memory hierarchy).

Grid: (T/bt, d_out/bo), TPU iterates the last dim innermost, so for each
token tile the z-scratch is computed once (at j == 0) and reused across all
d_out tiles.  MXU alignment: bt/bo multiples of 128 (Mosaic pads r < 128 —
whisper's r=96 — with the padding loss quantified in the roofline).

VMEM budget at the largest assigned site (internlm2 down-proj,
d_in=16384, r=1536): x-tile (128×16384 bf16) 4 MB + A (16384×1536 bf16
blocked over k? no — A rides whole) … A whole = 50 MB ✗ ⇒ A is blocked over
d_in with an inner fori_loop accumulating into the z scratch; per-step
A-block (1024, r≤1536) ≤ 3 MB.  Everything fits < 12 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np


def _silu(x):
    return x * jax.nn.sigmoid(x)


def _fwd_kernel(x_ref, a_ref, b_ref, out_ref, z_ref, *, n_k: int,
                bk: int, sigma: bool):
    """x_ref: (bt, d_in); a_ref: (d_in, r); b_ref: (r, bo);
    out_ref: (bt, bo); z_ref (scratch): (bt, r) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_z():
        def body(k, acc):
            xk = x_ref[:, pl.ds(k * bk, bk)]
            ak = a_ref[pl.ds(k * bk, bk), :]
            return acc + jnp.dot(xk, ak, preferred_element_type=jnp.float32)
        acc = jax.lax.fori_loop(
            0, n_k, body,
            jnp.zeros((x_ref.shape[0], a_ref.shape[1]), jnp.float32))
        if sigma:
            acc = _silu(acc)
        z_ref[...] = acc

    z = z_ref[...].astype(x_ref.dtype)
    out_ref[...] = jnp.dot(z, b_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def _pick_tiles(T: int, d_in: int, r: int, d_out: int):
    bt = 128
    while bt * 2 <= min(T, 512) and T % (bt * 2) == 0:
        bt *= 2
    bo = 128
    while bo * 2 <= min(d_out, 512) and d_out % (bo * 2) == 0:
        bo *= 2
    bk = min(d_in, 1024)
    while d_in % bk:
        bk //= 2
    return bt, bo, max(bk, 1)


def cola_ae_fwd(x: jax.Array, a: jax.Array, b: jax.Array, *,
                sigma: bool = True, interpret: bool = False) -> jax.Array:
    """x: (T, d_in) [callers flatten (b, s)]; a: (d_in, r); b: (r, d_out)."""
    T, d_in = x.shape
    r, d_out = b.shape
    bt, bo, bk = _pick_tiles(T, d_in, r, d_out)
    pad_t = (-T) % bt
    if pad_t:
        x = jnp.pad(x, ((0, pad_t), (0, 0)))
    Tp = x.shape[0]
    n_k = d_in // bk
    grid = (Tp // bt, d_out // bo)
    out = pl.pallas_call(
        functools.partial(_fwd_kernel, n_k=n_k, bk=bk, sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bo), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), x.dtype),
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(x, a, b)
    return out[:T] if pad_t else out
