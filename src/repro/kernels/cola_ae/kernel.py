"""Fused CoLA auto-encoder Pallas kernels: out = B · σ(A · x), fwd **and** bwd.

The paper's core op (Eq. 3) as TPU kernels.  The r-dimensional bottleneck
``z = σ(Ax)`` lives **entirely in VMEM scratch** — it never round-trips to
HBM at full width, so the AE pair's HBM traffic drops from
``n(d_in + 2r + d_out)`` to ``n(d_in + d_out)`` plus weight tiles and one
r-dim residual (DESIGN.md §2: the paper's activation-residency idea pushed
one level down the memory hierarchy).

Forward
-------
Grid: (T/bt, d_out/bo), TPU iterates the last dim innermost, so for each
token tile the z-scratch is computed once (at j == 0) and reused across all
d_out tiles.  The scratch now holds the f32 **pre-activation** ``z_pre``
(σ is re-applied per output tile — (bt, r) VPU work, free next to the MXU
GEMMs) and, when training, ``z_pre`` is emitted as a second output: the only
extra HBM write the fused training path makes, and exactly the
``cola_r``-named tensor the CoLA-M remat policy (core/colam.py) keeps.
MXU alignment: bt/bo multiples of 128 (Mosaic pads r < 128 — whisper's
r=96 — with the padding loss quantified in the roofline).

Backward (two kernels; per-tile traffic model)
----------------------------------------------
``dx`` kernel, grid (T/bt, d_in/bi), d_in innermost:
    reads per token tile: g (bt·d_out) + z_pre (4·bt·r), plus B whole and
    A blocked (bi, r) per step; writes dx (bt·bi) per step.
    At j == 0 it fuses ``dz = (g·Bᵀ) ⊙ σ′(z_pre)`` into a (bt, r) f32 VMEM
    scratch (the r-dim ``dz`` intermediate of the unfused path never touches
    HBM); every j then computes ``dx = dz·Aᵀ`` against the j-th A block.

``dA/dB`` kernel, grid (T/bt,), token tiles only:
    reads per step: x (bt·d_in) + g (bt·d_out) + z_pre (4·bt·r) + B whole;
    recomputes dz and σ(z_pre) in VMEM and accumulates
    ``dA += xᵀ·dz``, ``dB += σ(z_pre)ᵀ·g`` into f32 output blocks with
    constant index maps — revisited-output accumulation: the (d_in, r) and
    (r, d_out) grad blocks stay resident in VMEM across all token tiles and
    are written to HBM exactly once.

VMEM budget (honest accounting).  These kernels stage A and B *whole* into
VMEM via full-array BlockSpecs — the inner ``pl.ds`` loops slice the
VMEM-resident block for MXU sizing, they do not block the HBM copy.  That
bounds the sites the fused path can serve: ``weights_fit_vmem`` models the
residency (weights + per-step token tiles + f32 scratch ≤ FWD_VMEM_BUDGET)
and the ops layer falls back to the unfused XLA math when it fails — e.g.
the internlm2 down-proj (d_in=16384, r=1536, d_out=6144: A alone is 50 MB
bf16) is out of reach until the weights gain their own grid dimension
(future work).  The dA/dB kernel additionally keeps both f32 grad blocks
resident; ``dw_fits_vmem`` budgets grads + B + token tiles against
DW_VMEM_BUDGET and the ops layer keeps the fused dx kernel while taking
XLA GEMMs for dA/dB when it fails (the r-dim residency story is unchanged:
every fallback consumes the same (x, z_pre) residuals).

Tensor parallelism changes the budget arithmetic in the kernels' favor:
``ops.cola_ae_sharded`` resolves impl *inside* the shard_map body, so both
guards receive the per-device **local** shapes.  A site whose whole weights
overflow the budget can take the fused path once its rank dim (``baseline``
profile) or output dim (``megatron``) is sharded — e.g. a (2048, 2048,
2048) bf16 site is 16.8 MB of whole weights unsharded but ~1 MB of A+B per
device on a 16-way rank shard.  The internlm2 down-proj still needs the
future weight-grid dimension: its d_in/d_out token tiles dominate and those
dims are not sharded by any current profile.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np

from repro.kernels.cola_ae import act as _act

# Bytes the fwd/dx kernels may keep resident in VMEM (whole weights +
# per-step tiles out of ~16 MB/core, leaving headroom for double buffering).
FWD_VMEM_BUDGET = 12 * 1024 * 1024
# Bytes the dA/dB kernel may keep resident (f32 grad blocks + B + tiles).
DW_VMEM_BUDGET = 8 * 1024 * 1024
# Worst-case token tile _pick_tiles can choose (used by the guards, which
# run before tiles are picked).
_MAX_BT = 512


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _fwd_kernel(x_ref, a_ref, b_ref, out_ref, z_out_ref, z_ref, *, n_k: int,
                bk: int, sigma: str, emit_z: bool):
    """x_ref: (bt, d_in); a_ref: (d_in, r); b_ref: (r, bo);
    out_ref: (bt, bo); z_out_ref: (bt, r) f32 (None unless emit_z);
    z_ref (scratch): (bt, r) f32 holding the *pre-activation*."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_z():
        def body(k, acc):
            xk = x_ref[:, pl.ds(k * bk, bk)]
            ak = a_ref[pl.ds(k * bk, bk), :]
            return acc + jnp.dot(xk, ak, preferred_element_type=jnp.float32)
        acc = jax.lax.fori_loop(
            0, n_k, body,
            jnp.zeros((x_ref.shape[0], a_ref.shape[1]), jnp.float32))
        z_ref[...] = acc
        if emit_z:
            z_out_ref[...] = acc

    z = _act.apply_act(z_ref[...], sigma).astype(x_ref.dtype)
    out_ref[...] = jnp.dot(z, b_ref[...],
                           preferred_element_type=jnp.float32
                           ).astype(out_ref.dtype)


def _pick_block(d: int, cap: int = 1024) -> int:
    """Largest power-of-two block ≤ cap that divides d (≥1)."""
    b = min(d, cap)
    while d % b:
        b //= 2
    return max(b, 1)


def _pick_tiles(T: int, d_in: int, r: int, d_out: int):
    bt = 128
    while bt * 2 <= min(T, 512) and T % (bt * 2) == 0:
        bt *= 2
    # bo must divide d_out — a non-dividing tile would silently truncate
    # the grid and leave output columns unwritten.
    bo = _pick_block(d_out, 128)
    while bo * 2 <= min(d_out, 512) and d_out % (bo * 2) == 0:
        bo *= 2
    return bt, bo, _pick_block(d_in, 1024)


def _pad_tokens(arrs, bt: int):
    """Zero-pad each (T, ·) array to a multiple of bt rows."""
    T = arrs[0].shape[0]
    pad = (-T) % bt
    if pad:
        arrs = [jnp.pad(v, ((0, pad), (0, 0))) for v in arrs]
    return arrs, pad


def cola_ae_fwd(x: jax.Array, a: jax.Array, b: jax.Array, *,
                sigma=True, interpret: bool = False,
                return_zpre: bool = False):
    """x: (T, d_in) [callers flatten (b, s)]; a: (d_in, r); b: (r, d_out).

    With ``return_zpre=True`` also returns the f32 pre-activation
    ``z_pre = A·x`` (T, r) — the training residual; the A-GEMM runs once.
    """
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = b.shape
    bt, bo, bk = _pick_tiles(T, d_in, r, d_out)
    (x,), pad_t = _pad_tokens([x], bt)
    Tp = x.shape[0]
    n_k = d_in // bk
    grid = (Tp // bt, d_out // bo)
    kernel = functools.partial(_fwd_kernel, n_k=n_k, bk=bk, sigma=sigma,
                               emit_z=return_zpre)
    if not return_zpre:
        kernel = functools.partial(_drop_zout, kernel)
    out_shape = [jax.ShapeDtypeStruct((Tp, d_out), x.dtype)]
    out_specs = [pl.BlockSpec((bt, bo), lambda i, j: (i, j))]
    if return_zpre:
        out_shape.append(jax.ShapeDtypeStruct((Tp, r), jnp.float32))
        out_specs.append(pl.BlockSpec((bt, r), lambda i, j: (i, 0)))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i, j: (i, 0)),
            pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, bo), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(x, a, b)
    if return_zpre:
        out, z_pre = res
        return (out[:T], z_pre[:T]) if pad_t else (out, z_pre)
    out = res[0]
    return out[:T] if pad_t else out


def _drop_zout(kernel, x_ref, a_ref, b_ref, out_ref, z_ref, **kw):
    kernel(x_ref, a_ref, b_ref, out_ref, None, z_ref)


# --------------------------------------------------------------------------
# backward: dx = (g·Bᵀ ⊙ σ′(z_pre)) · Aᵀ
# --------------------------------------------------------------------------
def _bwd_dx_kernel(g_ref, zp_ref, a_ref, b_ref, out_ref, dz_ref, *,
                   n_o: int, bko: int, sigma: str):
    """g_ref: (bt, d_out); zp_ref: (bt, r) f32; a_ref: (bi, r);
    b_ref: (r, d_out); out_ref: (bt, bi); dz_ref (scratch): (bt, r) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_dz():
        def body(k, acc):
            gk = g_ref[:, pl.ds(k * bko, bko)]
            bk_ = b_ref[:, pl.ds(k * bko, bko)]
            # (bt, bko) · (r, bko)ᵀ — contract over d_out without transpose
            return acc + jax.lax.dot_general(
                gk, bk_, dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        dzl = jax.lax.fori_loop(
            0, n_o, body,
            jnp.zeros((g_ref.shape[0], b_ref.shape[0]), jnp.float32))
        dz_ref[...] = dzl * _act.act_grad(zp_ref[...], sigma)

    dz = dz_ref[...].astype(g_ref.dtype)
    # (bt, r) · (bi, r)ᵀ — contract over r
    out_ref[...] = jax.lax.dot_general(
        dz, a_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def cola_ae_bwd_dx(g: jax.Array, z_pre: jax.Array, a: jax.Array,
                   b: jax.Array, *, sigma=True,
                   interpret: bool = False) -> jax.Array:
    """g: (T, d_out) cotangent; z_pre: (T, r) f32; returns dx (T, d_in)."""
    sigma = _act.canon(sigma)
    T, d_out = g.shape
    d_in, r = a.shape
    bt, bi, _ = _pick_tiles(T, d_out, r, d_in)
    bko = _pick_block(d_out, 1024)
    (g, z_pre), pad_t = _pad_tokens([g, z_pre], bt)
    Tp = g.shape[0]
    grid = (Tp // bt, d_in // bi)
    dx = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, n_o=d_out // bko, bko=bko,
                          sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_out), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bi, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r, d_out), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_in), g.dtype),
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(g, z_pre, a, b)
    return dx[:T] if pad_t else dx


# --------------------------------------------------------------------------
# backward: dA += xᵀ·dz, dB += σ(z_pre)ᵀ·g over token tiles
# --------------------------------------------------------------------------
def _bwd_dw_kernel(x_ref, g_ref, zp_ref, b_ref, da_ref, db_ref, *,
                   n_o: int, bko: int, sigma: str):
    """x_ref: (bt, d_in); g_ref: (bt, d_out); zp_ref: (bt, r) f32;
    b_ref: (r, d_out); da_ref: (d_in, r) f32; db_ref: (r, d_out) f32.
    Outputs have constant index maps: revisited every token tile,
    accumulated in VMEM, flushed to HBM once."""
    i = pl.program_id(0)
    zp = zp_ref[...]

    def body(k, acc):
        gk = g_ref[:, pl.ds(k * bko, bko)]
        bk_ = b_ref[:, pl.ds(k * bko, bko)]
        return acc + jax.lax.dot_general(
            gk, bk_, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    dzl = jax.lax.fori_loop(
        0, n_o, body, jnp.zeros((g_ref.shape[0], b_ref.shape[0]),
                                jnp.float32))
    dt = x_ref.dtype
    dz = (dzl * _act.act_grad(zp, sigma)).astype(dt)
    z = _act.apply_act(zp, sigma).astype(dt)
    # contract over the token tile dim (0, 0)
    da = jax.lax.dot_general(
        x_ref[...], dz, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    db = jax.lax.dot_general(
        z, g_ref[...], dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = da
        db_ref[...] = db

    @pl.when(i > 0)
    def _accum():
        da_ref[...] += da
        db_ref[...] += db


def cola_ae_bwd_dw(x: jax.Array, g: jax.Array, z_pre: jax.Array,
                   b: jax.Array, *, sigma=True, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Returns (dA (d_in, r), dB (r, d_out)), both f32 accumulators."""
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = b.shape
    bt, _, _ = _pick_tiles(T, d_in, r, d_out)
    bko = _pick_block(d_out, 1024)
    (x, g, z_pre), pad_t = _pad_tokens([x, g, z_pre], bt)
    Tp = x.shape[0]
    da, db = pl.pallas_call(
        functools.partial(_bwd_dw_kernel, n_o=d_out // bko, bko=bko,
                          sigma=sigma),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bt, d_out), lambda i: (i, 0)),
            pl.BlockSpec((bt, r), lambda i: (i, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, r), jnp.float32),
            jax.ShapeDtypeStruct((r, d_out), jnp.float32),
        ],
        interpret=interpret,
    )(x, g, z_pre, b)
    return da, db


def weights_fit_vmem(d_in: int, r: int, d_out: int, *,
                     bytes_el: int = 2) -> bool:
    """Whether the fwd/dx kernels' residency fits FWD_VMEM_BUDGET:
    A and B whole, a worst-case token tile of x/g/out, and the f32
    z scratch."""
    resident = (bytes_el * (d_in * r + r * d_out)            # A + B whole
                + _MAX_BT * bytes_el * (d_in + d_out)        # x/g + out tile
                + _MAX_BT * 8 * r)                           # z_pre + dz f32
    return resident <= FWD_VMEM_BUDGET


def dw_fits_vmem(d_in: int, r: int, d_out: int, *,
                 bytes_el: int = 2) -> bool:
    """Whether the dA/dB kernel's residency fits DW_VMEM_BUDGET: both f32
    grad blocks, B whole, and a worst-case token tile of x/g/z_pre."""
    resident = (4 * (d_in + d_out) * r                       # dA + dB f32
                + bytes_el * r * d_out                       # B whole
                + _MAX_BT * (bytes_el * (d_in + d_out) + 4 * r))
    return resident <= DW_VMEM_BUDGET


# --------------------------------------------------------------------------
# HBM traffic model (benchmarks/throughput_table.py `cola_ae_bwd` row)
# --------------------------------------------------------------------------
def hbm_traffic(T: int, d_in: int, r: int, d_out: int, *,
                bytes_el: int = 2, fused: bool = True) -> int:
    """Modeled fwd+bwd HBM bytes for one AE site over T tokens.

    fused: one fwd kernel (z_pre is the only extra write, f32), one dx
    kernel (dz stays in VMEM), one dA/dB kernel (grads written once).
    unfused: every XLA GEMM and the σ/σ′ element-wise ops round-trip their
    full operands, including the (T, r) dzl/dz intermediates.  Weight grads
    are written in f32 in both cases.
    """
    w = d_in * r + r * d_out          # weight elements
    zp32 = 4 * T * r                  # f32 z_pre residual
    if fused:
        fwd = bytes_el * (T * d_in + w + T * d_out) + zp32
        bwd_dx = bytes_el * (T * d_out + w + T * d_in) + zp32
        bwd_dw = bytes_el * (T * d_in + T * d_out + r * d_out) + zp32 + 4 * w
        return fwd + bwd_dx + bwd_dw
    e = bytes_el
    fwd = (e * (T * d_in + d_in * r) + zp32          # x·A → z_pre
           + 2 * zp32 + e * T * r                    # σ: read z_pre, write z
           + e * (T * r + r * d_out + T * d_out))    # z·B → out
    bwd = (e * (T * d_out + r * d_out) + e * T * r         # g·Bᵀ → dzl
           + e * T * r + zp32 + e * T * r                  # dzl⊙σ′ → dz
           + e * (T * r + d_in * r + T * d_in)             # dz·Aᵀ → dx
           + e * (T * d_in + T * r) + 4 * d_in * r         # xᵀ·dz → dA
           + e * (T * r + T * d_out) + 4 * r * d_out)      # σ(z)ᵀ·g → dB
    return fwd + bwd
