"""CoLA auto-encoder Pallas kernels: out = B · σ(A · x), fwd **and** bwd.

The paper's core op (Eq. 3) as TPU kernels, in three flavors the ops-layer
planner (ops.py) composes per site:

* the **monolithic** fused kernel — one launch computes both GEMMs with the
  r-dimensional bottleneck ``z = σ(Ax)`` living entirely in VMEM scratch
  (it never round-trips HBM at full width), so the AE pair's HBM traffic
  drops from ``n(d_in + 2r + d_out)`` to ``n(d_in + d_out)`` plus weight
  tiles and one r-dim residual.  Fastest path; biases fold directly into
  the body (bias_a into the z scratch + emitted residual, bias_b into the
  output tile), so small bias sites (whisper MLP) keep the single launch.
  It stages A and B *whole* in VMEM and cannot admit a collective between
  the A-GEMM and σ;
* the **two-stage pipeline** — ``cola_ae_stage_a`` (x·A → z_pre, f32) and
  ``cola_ae_stage_b`` (σ(z_pre)·B [+ bias] → out), each with a **weight-
  grid dimension** that tiles d_in/d_out so weights stream through VMEM in
  blocks instead of requiring whole-weight residency.  One extra f32 (T, r)
  z_pre round-trip buys two things the monolith cannot give: sites whose
  local weights exceed VMEM (internlm2 down-proj) and a seam for the
  row-parallel ``psum`` of z_pre between the A-GEMM and σ (megatron
  o/down — previously XLA math);
* the **decode** kernel (``cola_ae_decode``) — GEMV-shaped single launch
  for small T (a decode step's B×1 tokens, where the token-tile grids
  above are degenerate): one phased grid streams A then B through VMEM in
  weight-grid blocks against a whole resident token tile, fusing both
  GEMMs, σ and both biases with f32 accumulation and emitting no z_pre.
  Decode is weight-traffic-bound (see ``decode_hbm_traffic``); this kernel
  reads each weight element exactly once.  Its split twin —
  ``cola_ae_decode_stage_a`` / ``cola_ae_decode_stage_b`` — is the same
  GEMV shape cut at the z seam for TP row-parallel serving (megatron
  o/down): stage A emits the partial f32 z_pre (the psum payload), the
  caller runs the collective (+ bias_a), stage B applies σ·B [+ bias_b].
  One f32 (T, r) round-trip buys the mid-pipeline collective that the
  single launch cannot admit — the serve-side mirror of the training
  two-stage pipeline, at decode grain (``decode_hbm_traffic(split=True)``
  models it; ``shards_in/rank/out`` give the per-shard byte terms).

Monolithic forward
------------------
Grid: (T/bt, d_out/bo), TPU iterates the last dim innermost, so for each
token tile the z-scratch is computed once (at j == 0) and reused across all
d_out tiles.  The scratch holds the f32 **pre-activation** ``z_pre``
(σ is re-applied per output tile — (bt, r) VPU work, free next to the MXU
GEMMs) and, when training, ``z_pre`` is emitted as a second output: the only
extra HBM write the fused training path makes, and exactly the
``cola_r``-named tensor the CoLA-M remat policy (core/colam.py) keeps.
MXU alignment: bt/bo multiples of 128 (Mosaic pads r < 128 — whisper's
r=96 — with the padding loss quantified in the roofline).

Monolithic backward (two kernels)
---------------------------------
``dx`` kernel, grid (T/bt, d_in/bi), d_in innermost:
    reads per token tile: g (bt·d_out) + z_pre (4·bt·r), plus B whole and
    A blocked (bi, r) per step; writes dx (bt·bi) per step.
    At j == 0 it fuses ``dz = (g·Bᵀ) ⊙ σ′(z_pre)`` into a (bt, r) f32 VMEM
    scratch (the r-dim ``dz`` intermediate of the unfused path never touches
    HBM); every j then computes ``dx = dz·Aᵀ`` against the j-th A block.

``dA/dB`` kernel, grid (T/bt,), token tiles only:
    reads per step: x (bt·d_in) + g (bt·d_out) + z_pre (4·bt·r) + B whole;
    recomputes dz and σ(z_pre) in VMEM and accumulates
    ``dA += xᵀ·dz``, ``dB += σ(z_pre)ᵀ·g`` into f32 output blocks with
    constant index maps — revisited-output accumulation: the (d_in, r) and
    (r, d_out) grad blocks stay resident in VMEM across all token tiles and
    are written to HBM exactly once.

Two-stage pipeline (weight-grid tiling)
---------------------------------------
Every stage kernel carries a weight-grid dimension whose block size is
chosen per call by ``_fit_block`` so the *per-tile* residency fits the
budget — ``weights_fit_vmem``/``dw_fits_vmem`` gate only the monolithic
fast path now; the staged kernels admit any site by shrinking their weight
blocks:

* ``cola_ae_stage_a``   grid (T/bt, d_in/bi): A streams in (bi, r) blocks;
  the f32 z_pre output block (bt, r) is revisited across the d_in grid and
  accumulates partial GEMMs (same revisited-output trick as the dA/dB
  kernel), flushed to HBM once per token tile.
* ``cola_ae_stage_b``   grid (T/bt, d_out/bo): B streams in (r, bo)
  blocks; σ is recomputed per output tile from the VMEM-resident z_pre
  tile, and an optional (1, bo) f32 bias block is folded into the body.
* ``cola_ae_bwd_dzl``   grid (T/bt, d_out/bo): ``dzl = g·Bᵀ`` accumulated
  over d_out blocks into a revisited (bt, r) f32 output — the stage-B
  backward; its HBM materialization is the seam for the column-parallel
  psum.
* ``cola_ae_bwd_dx_staged`` grid (T/bt, d_in/bi): fuses
  ``dz = dzl ⊙ σ′(z_pre)`` into scratch at j == 0, then ``dx = dz·Aᵀ``
  against streamed A blocks — the stage-A input backward.
* ``cola_ae_dz``        grid (T/bt,): materializes ``dz = dzl ⊙ σ′(z_pre)``
  once (pure VPU, one extra f32 (T, r) round-trip) so the dA weight passes
  below re-read a single r-dim tensor instead of two.
* ``cola_ae_bwd_da``    grid (d_in/bi, T/bt), tokens innermost: consumes
  the materialized dz and accumulates ``dA += xᵀ·dz`` into a revisited
  (bi, r) f32 block; x streams in (bt, bi) tiles, so no full-width token
  tile is ever resident.
* ``cola_ae_bwd_db``    grid (d_out/bo, T/bt): recomputes σ(z_pre) per
  token tile and accumulates ``dB += σ(z_pre)ᵀ·g`` into a revisited
  (r, bo) f32 block.

The streamed dA/dB pair replaces the old XLA-GEMM fallback for sites whose
f32 grad blocks exceed DW_VMEM_BUDGET: over-budget sites now stay on
Pallas with smaller weight blocks instead of leaving the fused path.

VMEM budgets (honest accounting).  The monolithic kernels stage A and B
whole via full-array BlockSpecs — ``weights_fit_vmem`` models that
residency (weights + per-step token tiles + f32 scratch ≤ FWD_VMEM_BUDGET)
and the planner takes the two-stage pipeline when it fails, e.g. the
internlm2 down-proj (d_in=16384, r=1536, d_out=6144: A alone is 50 MB
bf16).  ``dw_fits_vmem`` budgets the monolithic dA/dB kernel (both f32
grad blocks + B whole + full-width token tiles ≤ DW_VMEM_BUDGET); over
budget, the backward streams through bwd_dzl/bwd_da/bwd_db instead.

Tensor parallelism still shifts the arithmetic in the monolith's favor:
``ops.cola_ae_sharded`` resolves the plan *inside* the shard_map body, so
the guards receive the per-device **local** shapes — a rank- (baseline) or
output-sharded (megatron) site can take the monolith once sharded.  Sites
that need a mid-pipeline collective (row-parallel z_pre psum, column-
parallel dzl psum) take the two-stage path regardless of size, which is
what makes megatron row-parallel sites fully fused for the first time.

Quantized weight streaming (decode only)
----------------------------------------
Decode reads every weight element exactly once per token, so shrinking
the *streamed representation* shrinks the dominant byte term directly.
``cola_ae_decode_quant`` and the split twins
``cola_ae_decode_stage_a_quant`` / ``cola_ae_decode_stage_b_quant`` run
the same phased grid as their bf16 counterparts but stream
``quant.QuantFactor`` blocks: int8 codes (int4 nibble-packed pairwise
along the non-rank axis) plus f32 per-row (A) / per-column (B) scales.
Per grid step k the BlockSpecs deliver

    A phase (k < n_i):  x (Tp, bi) · [q_a (bi/pk, r), s_a (bi, 1)]
    B phase (k ≥ n_i):  [q_b (r, bo/pk), s_b (1, bo)] → out (Tp, bo)

where pk = 2 for int4, 1 for int8.  The body dequantizes in-register —
``q.astype(f32) * scale`` (plus a nibble unpack for int4), cast to the
compute dtype — immediately before the MXU dot, so f32 accumulation and
the grid/loop structure are untouched.  Block sizes bi/bo come from the
SAME ``_fit_block`` calls as the bf16 kernels, keyed on the *compute*
element size, so the quantized kernel is bit-identical to running the
bf16 kernel on ``quant.dequantize(...)`` of the same factors (the
scale layouts slice exactly along the weight-grid axes).  VMEM residency
only shrinks: q-blocks are 1–2 bytes-per-4 cheaper than the bf16 blocks
budgeted for, scales add 4·(bi + bo) bytes.  ``decode_hbm_traffic``'s
``weight_bits`` term models the payoff: weight bytes drop to
``ceil(w·bits/8)`` plus the honest 4-byte-per-row/column scale charge.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np

from repro.kernels.cola_ae import act as _act
from repro.kernels.cola_ae import quant as _quant

# Bytes the fwd/dx kernels may keep resident in VMEM (whole weights +
# per-step tiles out of ~16 MB/core, leaving headroom for double buffering).
FWD_VMEM_BUDGET = 12 * 1024 * 1024
# Bytes the dA/dB kernel may keep resident (f32 grad blocks + B + tiles).
DW_VMEM_BUDGET = 8 * 1024 * 1024
# Worst-case token tile _pick_tiles can choose (used by the guards, which
# run before tiles are picked).
_MAX_BT = 512


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------
def _fwd_kernel(x_ref, a_ref, b_ref, *rest, n_k: int, bk: int, sigma: str,
                emit_z: bool, has_ba: bool, has_bb: bool):
    """x_ref: (bt, d_in); a_ref: (d_in, r); b_ref: (r, bo);
    ba_ref: (1, r) f32 when has_ba; bb_ref: (1, bo) f32 when has_bb;
    out_ref: (bt, bo); z_out_ref: (bt, r) f32 (only when emit_z);
    z_ref (scratch): (bt, r) f32 holding the *pre-activation* — post-bias_a,
    so the emitted residual is the true σ input."""
    refs = list(rest)
    ba_ref = refs.pop(0) if has_ba else None
    bb_ref = refs.pop(0) if has_bb else None
    out_ref = refs.pop(0)
    z_out_ref = refs.pop(0) if emit_z else None
    z_ref = refs.pop(0)
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_z():
        def body(k, acc):
            xk = x_ref[:, pl.ds(k * bk, bk)]
            ak = a_ref[pl.ds(k * bk, bk), :]
            return acc + jnp.dot(xk, ak, preferred_element_type=jnp.float32)
        acc = jax.lax.fori_loop(
            0, n_k, body,
            jnp.zeros((x_ref.shape[0], a_ref.shape[1]), jnp.float32))
        if has_ba:
            acc = acc + ba_ref[...]
        z_ref[...] = acc
        if emit_z:
            z_out_ref[...] = acc

    z = _act.apply_act(z_ref[...], sigma).astype(x_ref.dtype)
    acc = jnp.dot(z, b_ref[...], preferred_element_type=jnp.float32)
    if has_bb:
        acc = acc + bb_ref[...]
    out_ref[...] = acc.astype(out_ref.dtype)


def _pick_block(d: int, cap: int = 1024) -> int:
    """Largest power-of-two block ≤ cap that divides d (≥1)."""
    b = min(d, cap)
    while d % b:
        b //= 2
    return max(b, 1)


def _pick_bt(T: int) -> int:
    """Token tile: 128 grown to ≤512 while it divides T (callers pad)."""
    bt = 128
    while bt * 2 <= min(T, 512) and T % (bt * 2) == 0:
        bt *= 2
    return bt


def _pick_tiles(T: int, d_in: int, r: int, d_out: int):
    bt = _pick_bt(T)
    # bo must divide d_out — a non-dividing tile would silently truncate
    # the grid and leave output columns unwritten.
    bo = _pick_block(d_out, 128)
    while bo * 2 <= min(d_out, 512) and d_out % (bo * 2) == 0:
        bo *= 2
    return bt, bo, _pick_block(d_in, 1024)


def _fit_block(d: int, per_unit_bytes: int, fixed_bytes: int,
               budget: int, cap: int = 512) -> int:
    """Weight-grid block size: the largest power-of-two divisor of ``d``
    (≤ cap) whose per-tile residency ``fixed + block·per_unit`` fits the
    budget.  Floors at the smallest dividing power of two ≥ 8 (MXU sublane
    minimum) — best effort: a floor-sized block over a tiny forced budget
    still streams, it just double-buffers less."""
    blk = _pick_block(d, cap)
    while blk > 8 and blk % 2 == 0 and \
            fixed_bytes + blk * per_unit_bytes > budget:
        blk //= 2
    return blk


def _pick_dw_tiles(T: int, d: int, r: int, bytes_el: int,
                   fixed_per_bt: int, budget: int):
    """(bt, blk) for the streamed dA/dB kernels, minimizing weight passes.

    Each pass over the weight grid re-reads the f32 r-dim tiles (dzl,
    z_pre) in full — the dominant streamed-path traffic term — so a
    *smaller* token tile that frees VMEM for a larger weight block is
    usually the right trade: the fixed cost scales with bt, the pass count
    with d/blk.  Scans bt ∈ {128, 256, 512}, picks the fewest passes
    (largest bt on ties, for longer MXU runs)."""
    best = None
    for bt in (512, 256, 128):
        if bt > max(_pick_bt(T), 128):
            continue
        blk = _fit_block(d, bytes_el * bt + 4 * r, fixed_per_bt * bt,
                         budget)
        passes = -(-d // blk)
        if best is None or passes < best[0]:
            best = (passes, bt, blk)
    _, bt, blk = best
    return bt, blk


def _pad_tokens(arrs, bt: int):
    """Zero-pad each (T, ·) array to a multiple of bt rows."""
    T = arrs[0].shape[0]
    pad = (-T) % bt
    if pad:
        arrs = [jnp.pad(v, ((0, pad), (0, 0))) for v in arrs]
    return arrs, pad


def cola_ae_fwd(x: jax.Array, a: jax.Array, b: jax.Array,
                bias_a: "jax.Array | None" = None,
                bias_b: "jax.Array | None" = None, *,
                sigma=True, interpret: bool = False,
                return_zpre: bool = False):
    """x: (T, d_in) [callers flatten (b, s)]; a: (d_in, r); b: (r, d_out);
    bias_a: (r,) folded into the pre-activation (and the emitted residual),
    bias_b: (d_out,) folded into the output tile — the monolith bias fold,
    which keeps small bias sites (whisper MLP) on the single-launch path.

    With ``return_zpre=True`` also returns the f32 pre-activation
    ``z_pre = A·x [+ bias_a]`` (T, r) — the training residual; the A-GEMM
    runs once.
    """
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = b.shape
    bt, bo, bk = _pick_tiles(T, d_in, r, d_out)
    (x,), pad_t = _pad_tokens([x], bt)
    Tp = x.shape[0]
    n_k = d_in // bk
    grid = (Tp // bt, d_out // bo)
    kernel = functools.partial(_fwd_kernel, n_k=n_k, bk=bk, sigma=sigma,
                               emit_z=return_zpre,
                               has_ba=bias_a is not None,
                               has_bb=bias_b is not None)
    in_specs = [
        pl.BlockSpec((bt, d_in), lambda i, j: (i, 0)),
        pl.BlockSpec((d_in, r), lambda i, j: (0, 0)),
        pl.BlockSpec((r, bo), lambda i, j: (0, j)),
    ]
    args = [x, a, b]
    if bias_a is not None:
        in_specs.append(pl.BlockSpec((1, r), lambda i, j: (0, 0)))
        args.append(bias_a.astype(jnp.float32).reshape(1, r))
    if bias_b is not None:
        in_specs.append(pl.BlockSpec((1, bo), lambda i, j: (0, j)))
        args.append(bias_b.astype(jnp.float32).reshape(1, d_out))
    out_shape = [jax.ShapeDtypeStruct((Tp, d_out), x.dtype)]
    out_specs = [pl.BlockSpec((bt, bo), lambda i, j: (i, j))]
    if return_zpre:
        out_shape.append(jax.ShapeDtypeStruct((Tp, r), jnp.float32))
        out_specs.append(pl.BlockSpec((bt, r), lambda i, j: (i, 0)))
    res = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(*args)
    if return_zpre:
        out, z_pre = res
        return (out[:T], z_pre[:T]) if pad_t else (out, z_pre)
    out = res[0]
    return out[:T] if pad_t else out


# --------------------------------------------------------------------------
# decode: GEMV-shaped fused auto-encoder for small T (B×1 decode batches)
# --------------------------------------------------------------------------
def _decode_kernel(x_ref, a_ref, b_ref, *rest, n_i: int, sigma: str,
                   has_ba: bool, has_bb: bool):
    """Phased single-grid kernel over (n_i + n_o) steps: the first n_i
    steps stream A in (bi, r) blocks and accumulate the f32 pre-activation
    into the VMEM scratch; the remaining n_o steps apply σ (+ bias_a) and
    stream B in (r, bo) blocks to emit output tiles (+ bias_b).  TPU grids
    iterate sequentially, so the scratch is complete before the first
    emit step.  z never touches HBM — decode's only residual is nothing."""
    refs = list(rest)
    ba_ref = refs.pop(0) if has_ba else None
    bb_ref = refs.pop(0) if has_bb else None
    out_ref, z_ref = refs
    k = pl.program_id(0)

    @pl.when(k < n_i)
    def _accum_z():
        acc = jnp.dot(x_ref[...], a_ref[...],
                      preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _init():
            z_ref[...] = acc

        @pl.when(k > 0)
        def _add():
            z_ref[...] += acc

    @pl.when(k >= n_i)
    def _emit():
        zp = z_ref[...]
        if has_ba:
            zp = zp + ba_ref[...]
        z = _act.apply_act(zp, sigma).astype(b_ref.dtype)
        acc = jnp.dot(z, b_ref[...], preferred_element_type=jnp.float32)
        if has_bb:
            acc = acc + bb_ref[...]
        out_ref[...] = acc.astype(out_ref.dtype)


def cola_ae_decode(x: jax.Array, a: jax.Array, b: jax.Array,
                   bias_a: "jax.Array | None" = None,
                   bias_b: "jax.Array | None" = None, *, sigma=True,
                   out_dtype=None, interpret: bool = False) -> jax.Array:
    """Fused GEMV-shaped auto-encoder for decode: x is (T, d_in) with T the
    decode batch (B slots × 1 token) — weight-traffic-bound, so both GEMMs,
    σ and both biases run in ONE launch with A and B streamed through VMEM
    in weight-grid blocks and the whole (padded) token tile resident.  No
    z_pre is emitted: decode saves no residuals.

    The training kernels' token-tile grids are degenerate here (bt=128
    against T=1 pads 127/128 of every MXU pass); this kernel instead tiles
    only the weight dims, reading each weight element exactly once.
    """
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = b.shape
    out_dtype = out_dtype or x.dtype
    e = jnp.dtype(x.dtype).itemsize
    # whole token tile resident: pad T to the f32 sublane minimum
    pad = (-T) % 8
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]
    # per-phase residency: f32 z scratch is the fixed cost; weight blocks
    # stream.  Large caps — decode makes exactly one pass over each weight,
    # so bigger blocks just mean fewer grid steps.
    bi = _fit_block(d_in, per_unit_bytes=e * (Tp + r),
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    bo = _fit_block(d_out, per_unit_bytes=e * (r + Tp) + 4,
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    n_i, n_o = d_in // bi, d_out // bo
    in_specs = [
        pl.BlockSpec((Tp, bi), lambda k: (0, jnp.minimum(k, n_i - 1))),
        pl.BlockSpec((bi, r), lambda k: (jnp.minimum(k, n_i - 1), 0)),
        pl.BlockSpec((r, bo), lambda k: (0, jnp.maximum(k - n_i, 0))),
    ]
    args = [x, a, b]
    if bias_a is not None:
        in_specs.append(pl.BlockSpec((1, r), lambda k: (0, 0)))
        args.append(bias_a.astype(jnp.float32).reshape(1, r))
    if bias_b is not None:
        in_specs.append(
            pl.BlockSpec((1, bo), lambda k: (0, jnp.maximum(k - n_i, 0))))
        args.append(bias_b.astype(jnp.float32).reshape(1, d_out))
    out = pl.pallas_call(
        functools.partial(_decode_kernel, n_i=n_i, sigma=sigma,
                          has_ba=bias_a is not None,
                          has_bb=bias_b is not None),
        grid=(n_i + n_o,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tp, bo),
                               lambda k: (0, jnp.maximum(k - n_i, 0))),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((Tp, r), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:T] if pad else out


# --------------------------------------------------------------------------
# decode split: the decode kernel cut at the z seam, for row-parallel TP
# sites (megatron o/down) where a z_pre psum must run mid-pipeline.  Same
# GEMV-shaped grids as cola_ae_decode (whole token tile resident, weights
# streamed, T padded to the f32 sublane minimum) — the training stage
# kernels' 128-token tiles are degenerate at decode T.
# --------------------------------------------------------------------------
def _decode_stage_a_kernel(x_ref, a_ref, zp_ref):
    """x_ref: (Tp, bi); a_ref: (bi, r); zp_ref: (Tp, r) f32 revisited
    across the d_in grid dim, accumulating partial GEMV products."""
    k = pl.program_id(0)
    acc = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        zp_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        zp_ref[...] += acc


def cola_ae_decode_stage_a(x: jax.Array, a: jax.Array, *,
                           interpret: bool = False) -> jax.Array:
    """x: (T, d_in) decode batch; a: (d_in, r) → z_pre = x·A (T, r) f32.

    The partial pre-activation leaves the chip here — at row-parallel
    sites it is the psum payload (4·T·r bytes per shard); the caller runs
    the collective (and any bias_a add) before ``cola_ae_decode_stage_b``.
    """
    T, d_in = x.shape
    r = a.shape[1]
    e = jnp.dtype(x.dtype).itemsize
    pad = (-T) % 8
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]
    bi = _fit_block(d_in, per_unit_bytes=e * (Tp + r),
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    zp = pl.pallas_call(
        _decode_stage_a_kernel,
        grid=(d_in // bi,),
        in_specs=[
            pl.BlockSpec((Tp, bi), lambda k: (0, k)),
            pl.BlockSpec((bi, r), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((Tp, r), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, r), jnp.float32),
        interpret=interpret,
    )(x, a)
    return zp[:T] if pad else zp


def _decode_stage_b_kernel(zp_ref, b_ref, *rest, sigma: str, has_bias: bool):
    """zp_ref: (Tp, r) f32 resident; b_ref: (r, bo) streamed; bias_ref:
    (1, bo) f32 when has_bias; out_ref: (Tp, bo)."""
    bias_ref, out_ref = rest if has_bias else (None, rest[0])
    z = _act.apply_act(zp_ref[...], sigma).astype(b_ref.dtype)
    acc = jnp.dot(z, b_ref[...], preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + bias_ref[...]
    out_ref[...] = acc.astype(out_ref.dtype)


def cola_ae_decode_stage_b(z_pre: jax.Array, b: jax.Array,
                           bias: "jax.Array | None" = None, *, sigma=True,
                           out_dtype=None, interpret: bool = False
                           ) -> jax.Array:
    """z_pre: (T, r) f32 (post-psum, post-bias_a); b: (r, d_out);
    bias: (d_out,) or None → out = σ(z_pre)·B [+ bias] (T, d_out)."""
    sigma = _act.canon(sigma)
    T, r = z_pre.shape
    d_out = b.shape[1]
    out_dtype = out_dtype or b.dtype
    e = jnp.dtype(b.dtype).itemsize
    pad = (-T) % 8
    if pad:
        z_pre = jnp.pad(z_pre, ((0, pad), (0, 0)))
    Tp = z_pre.shape[0]
    bo = _fit_block(d_out, per_unit_bytes=e * (r + Tp) + 4,
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    in_specs = [
        pl.BlockSpec((Tp, r), lambda k: (0, 0)),
        pl.BlockSpec((r, bo), lambda k: (0, k)),
    ]
    args = (z_pre, b)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bo), lambda k: (0, k)))
        args += (bias.astype(jnp.float32).reshape(1, d_out),)
    out = pl.pallas_call(
        functools.partial(_decode_stage_b_kernel, sigma=sigma,
                          has_bias=bias is not None),
        grid=(d_out // bo,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tp, bo), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:T] if pad else out


# --------------------------------------------------------------------------
# quantized decode: the same phased GEMV grids streaming int8/int4 q-blocks
# + f32 scales, dequantized in-register just before the MXU dot.  Block
# planning is keyed on the COMPUTE element size (not the packed size), so
# grid/loop structure — and therefore f32 accumulation order — matches the
# bf16 kernels exactly: quant kernel ≡ bf16 kernel on dequantize(factors).
# --------------------------------------------------------------------------
def _check_quant_factors(qa, qb):
    if not isinstance(qa, _quant.QuantFactor) or qa.kind != "in":
        raise ValueError(
            f"qa must be a QuantFactor(kind='in'), got {qa!r}")
    if not isinstance(qb, _quant.QuantFactor) or qb.kind != "out":
        raise ValueError(
            f"qb must be a QuantFactor(kind='out'), got {qb!r}")


def _decode_quant_kernel(x_ref, qa_ref, sa_ref, qb_ref, sb_ref, *rest,
                         n_i: int, sigma: str, has_ba: bool, has_bb: bool,
                         bits_a: int, bits_b: int):
    """``_decode_kernel`` with streamed q-blocks: qa_ref (bi/pk_a, r) int8
    + sa_ref (bi, 1) f32 in the A phase, qb_ref (r, bo/pk_b) int8 +
    sb_ref (1, bo) f32 in the B phase.  Dequantization (nibble unpack for
    int4, widen, scale, cast to the compute dtype) happens in-register;
    the dots and the f32 z scratch are identical to the bf16 body."""
    refs = list(rest)
    ba_ref = refs.pop(0) if has_ba else None
    bb_ref = refs.pop(0) if has_bb else None
    out_ref, z_ref = refs
    k = pl.program_id(0)

    @pl.when(k < n_i)
    def _accum_z():
        a_blk = _quant.dequant_block(
            qa_ref[...], sa_ref[...], kind="in",
            bits=bits_a).astype(x_ref.dtype)
        acc = jnp.dot(x_ref[...], a_blk, preferred_element_type=jnp.float32)

        @pl.when(k == 0)
        def _init():
            z_ref[...] = acc

        @pl.when(k > 0)
        def _add():
            z_ref[...] += acc

    @pl.when(k >= n_i)
    def _emit():
        b_blk = _quant.dequant_block(
            qb_ref[...], sb_ref[...], kind="out",
            bits=bits_b).astype(x_ref.dtype)
        zp = z_ref[...]
        if has_ba:
            zp = zp + ba_ref[...]
        z = _act.apply_act(zp, sigma).astype(b_blk.dtype)
        acc = jnp.dot(z, b_blk, preferred_element_type=jnp.float32)
        if has_bb:
            acc = acc + bb_ref[...]
        out_ref[...] = acc.astype(out_ref.dtype)


def cola_ae_decode_quant(x: jax.Array, qa, qb,
                         bias_a: "jax.Array | None" = None,
                         bias_b: "jax.Array | None" = None, *, sigma=True,
                         out_dtype=None, interpret: bool = False
                         ) -> jax.Array:
    """``cola_ae_decode`` over quantized factors: qa/qb are
    ``quant.QuantFactor``s (kind 'in'/'out'); their q-blocks + scales
    stream through VMEM and dequantize in-register.  Same grid, same
    block planning (keyed on the compute dtype), same f32 accumulation
    — bit-identical to ``cola_ae_decode(x, dequantize(qa).astype(...),
    dequantize(qb).astype(...), ...)``."""
    _check_quant_factors(qa, qb)
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = qb.shape                       # logical (unpacked) shape
    out_dtype = out_dtype or x.dtype
    e = jnp.dtype(x.dtype).itemsize           # compute dtype, NOT packed
    pad = (-T) % 8
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]
    bi = _fit_block(d_in, per_unit_bytes=e * (Tp + r),
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    bo = _fit_block(d_out, per_unit_bytes=e * (r + Tp) + 4,
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    n_i, n_o = d_in // bi, d_out // bo
    pk_a = 2 if qa.bits == 4 else 1
    pk_b = 2 if qb.bits == 4 else 1
    in_specs = [
        pl.BlockSpec((Tp, bi), lambda k: (0, jnp.minimum(k, n_i - 1))),
        pl.BlockSpec((bi // pk_a, r), lambda k: (jnp.minimum(k, n_i - 1), 0)),
        pl.BlockSpec((bi, 1), lambda k: (jnp.minimum(k, n_i - 1), 0)),
        pl.BlockSpec((r, bo // pk_b), lambda k: (0, jnp.maximum(k - n_i, 0))),
        pl.BlockSpec((1, bo), lambda k: (0, jnp.maximum(k - n_i, 0))),
    ]
    args = [x, qa.q, qa.scale, qb.q, qb.scale]
    if bias_a is not None:
        in_specs.append(pl.BlockSpec((1, r), lambda k: (0, 0)))
        args.append(bias_a.astype(jnp.float32).reshape(1, r))
    if bias_b is not None:
        in_specs.append(
            pl.BlockSpec((1, bo), lambda k: (0, jnp.maximum(k - n_i, 0))))
        args.append(bias_b.astype(jnp.float32).reshape(1, d_out))
    out = pl.pallas_call(
        functools.partial(_decode_quant_kernel, n_i=n_i, sigma=sigma,
                          has_ba=bias_a is not None,
                          has_bb=bias_b is not None,
                          bits_a=qa.bits, bits_b=qb.bits),
        grid=(n_i + n_o,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tp, bo),
                               lambda k: (0, jnp.maximum(k - n_i, 0))),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), out_dtype),
        scratch_shapes=[pltpu.VMEM((Tp, r), jnp.float32)],
        interpret=interpret,
    )(*args)
    return out[:T] if pad else out


def _decode_stage_a_quant_kernel(x_ref, qa_ref, sa_ref, zp_ref, *,
                                 bits: int):
    """``_decode_stage_a_kernel`` with a streamed q-block + per-row
    scales dequantized in-register before the dot."""
    k = pl.program_id(0)
    a_blk = _quant.dequant_block(qa_ref[...], sa_ref[...], kind="in",
                                 bits=bits).astype(x_ref.dtype)
    acc = jnp.dot(x_ref[...], a_blk, preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        zp_ref[...] = acc

    @pl.when(k > 0)
    def _accum():
        zp_ref[...] += acc


def cola_ae_decode_stage_a_quant(x: jax.Array, qa, *,
                                 interpret: bool = False) -> jax.Array:
    """``cola_ae_decode_stage_a`` over a quantized A factor — the
    row-parallel TP stage, streaming local q-blocks with local scales."""
    if not isinstance(qa, _quant.QuantFactor) or qa.kind != "in":
        raise ValueError(
            f"qa must be a QuantFactor(kind='in'), got {qa!r}")
    T, d_in = x.shape
    r = qa.shape[-1]
    e = jnp.dtype(x.dtype).itemsize
    pad = (-T) % 8
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    Tp = x.shape[0]
    bi = _fit_block(d_in, per_unit_bytes=e * (Tp + r),
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    pk = 2 if qa.bits == 4 else 1
    zp = pl.pallas_call(
        functools.partial(_decode_stage_a_quant_kernel, bits=qa.bits),
        grid=(d_in // bi,),
        in_specs=[
            pl.BlockSpec((Tp, bi), lambda k: (0, k)),
            pl.BlockSpec((bi // pk, r), lambda k: (k, 0)),
            pl.BlockSpec((bi, 1), lambda k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((Tp, r), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, r), jnp.float32),
        interpret=interpret,
    )(x, qa.q, qa.scale)
    return zp[:T] if pad else zp


def _decode_stage_b_quant_kernel(zp_ref, qb_ref, sb_ref, *rest, sigma: str,
                                 has_bias: bool, bits: int):
    """``_decode_stage_b_kernel`` with a streamed q-block + per-column
    scales; the dequantized block is cast to the output dtype (the
    compute dtype the caller threads through ``out_dtype``) so σ(z_pre)
    is cast exactly as in the bf16 body."""
    bias_ref, out_ref = rest if has_bias else (None, rest[0])
    b_blk = _quant.dequant_block(qb_ref[...], sb_ref[...], kind="out",
                                 bits=bits).astype(out_ref.dtype)
    z = _act.apply_act(zp_ref[...], sigma).astype(b_blk.dtype)
    acc = jnp.dot(z, b_blk, preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + bias_ref[...]
    out_ref[...] = acc.astype(out_ref.dtype)


def cola_ae_decode_stage_b_quant(z_pre: jax.Array, qb,
                                 bias: "jax.Array | None" = None, *,
                                 sigma=True, out_dtype,
                                 interpret: bool = False) -> jax.Array:
    """``cola_ae_decode_stage_b`` over a quantized B factor.
    ``out_dtype`` is required: it is the compute dtype (the bf16 twin
    keys block planning and the σ(z_pre) cast on ``b.dtype``, which the
    ops layer sets to the activation dtype — quantized factors carry no
    such dtype, so the caller must thread it)."""
    if not isinstance(qb, _quant.QuantFactor) or qb.kind != "out":
        raise ValueError(
            f"qb must be a QuantFactor(kind='out'), got {qb!r}")
    sigma = _act.canon(sigma)
    T, r = z_pre.shape
    d_out = qb.shape[-1]
    e = jnp.dtype(out_dtype).itemsize
    pad = (-T) % 8
    if pad:
        z_pre = jnp.pad(z_pre, ((0, pad), (0, 0)))
    Tp = z_pre.shape[0]
    bo = _fit_block(d_out, per_unit_bytes=e * (r + Tp) + 4,
                    fixed_bytes=4 * Tp * r, budget=FWD_VMEM_BUDGET,
                    cap=1024)
    pk = 2 if qb.bits == 4 else 1
    in_specs = [
        pl.BlockSpec((Tp, r), lambda k: (0, 0)),
        pl.BlockSpec((r, bo // pk), lambda k: (0, k)),
        pl.BlockSpec((1, bo), lambda k: (0, k)),
    ]
    args = (z_pre, qb.q, qb.scale)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bo), lambda k: (0, k)))
        args += (bias.astype(jnp.float32).reshape(1, d_out),)
    out = pl.pallas_call(
        functools.partial(_decode_stage_b_quant_kernel, sigma=sigma,
                          has_bias=bias is not None, bits=qb.bits),
        grid=(d_out // bo,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((Tp, bo), lambda k: (0, k)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:T] if pad else out


# --------------------------------------------------------------------------
# two-stage pipeline: stage A (x·A → z_pre) / stage B (σ(z_pre)·B + bias)
# with weight-grid tiling — weights stream through VMEM in blocks.
# --------------------------------------------------------------------------
def _stage_a_kernel(x_ref, a_ref, zp_ref):
    """x_ref: (bt, bi); a_ref: (bi, r); zp_ref: (bt, r) f32 — revisited
    across the d_in grid dim (innermost), accumulating partial GEMMs."""
    j = pl.program_id(1)
    acc = jnp.dot(x_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        zp_ref[...] = acc

    @pl.when(j > 0)
    def _accum():
        zp_ref[...] += acc


def cola_ae_stage_a(x: jax.Array, a: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """x: (T, d_in); a: (d_in, r) → z_pre = x·A (T, r) f32.

    A streams in (bi, r) blocks sized by ``_fit_block`` against
    FWD_VMEM_BUDGET, so no whole-weight residency is ever required.
    """
    T, d_in = x.shape
    r = a.shape[1]
    e = jnp.dtype(x.dtype).itemsize
    bt = _pick_bt(T)
    # per-tile residency: zp f32 (fixed) + x tile col + A row per bi unit
    bi = _fit_block(d_in, per_unit_bytes=e * (bt + r),
                    fixed_bytes=4 * bt * r, budget=FWD_VMEM_BUDGET)
    (x,), pad_t = _pad_tokens([x], bt)
    Tp = x.shape[0]
    zp = pl.pallas_call(
        _stage_a_kernel,
        grid=(Tp // bt, d_in // bi),
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
            pl.BlockSpec((bi, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, r), jnp.float32),
        interpret=interpret,
    )(x, a)
    return zp[:T] if pad_t else zp


def _stage_b_kernel(zp_ref, b_ref, *rest, sigma: str, has_bias: bool):
    """zp_ref: (bt, r) f32; b_ref: (r, bo); bias_ref: (1, bo) f32 when
    has_bias; out_ref: (bt, bo).  σ recomputed per output tile (VPU)."""
    bias_ref, out_ref = rest if has_bias else (None, rest[0])
    z = _act.apply_act(zp_ref[...], sigma).astype(b_ref.dtype)
    acc = jnp.dot(z, b_ref[...], preferred_element_type=jnp.float32)
    if has_bias:
        acc = acc + bias_ref[...]
    out_ref[...] = acc.astype(out_ref.dtype)


def cola_ae_stage_b(z_pre: jax.Array, b: jax.Array,
                    bias: "jax.Array | None" = None, *, sigma=True,
                    out_dtype=None, interpret: bool = False) -> jax.Array:
    """z_pre: (T, r) f32; b: (r, d_out); bias: (d_out,) or None
    → out = σ(z_pre)·B [+ bias] (T, d_out).

    B streams in (r, bo) blocks; the bias add is fused into the body as an
    f32 (1, bo) block per output tile — bias-carrying AE sites stay on the
    fused path.
    """
    sigma = _act.canon(sigma)
    T, r = z_pre.shape
    d_out = b.shape[1]
    out_dtype = out_dtype or b.dtype
    e = jnp.dtype(b.dtype).itemsize
    bt = _pick_bt(T)
    bo = _fit_block(d_out, per_unit_bytes=e * (r + bt) + 4,
                    fixed_bytes=4 * bt * r, budget=FWD_VMEM_BUDGET)
    (z_pre,), pad_t = _pad_tokens([z_pre], bt)
    Tp = z_pre.shape[0]
    in_specs = [
        pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
        pl.BlockSpec((r, bo), lambda i, j: (0, j)),
    ]
    args = (z_pre, b)
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bo), lambda i, j: (0, j)))
        args += (bias.astype(jnp.float32).reshape(1, d_out),)
    out = pl.pallas_call(
        functools.partial(_stage_b_kernel, sigma=sigma,
                          has_bias=bias is not None),
        grid=(Tp // bt, d_out // bo),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_out), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:T] if pad_t else out


def _bwd_dzl_kernel(g_ref, b_ref, dzl_ref):
    """g_ref: (bt, bo); b_ref: (r, bo); dzl_ref: (bt, r) f32 revisited
    across the d_out grid dim, accumulating ``g·Bᵀ`` partials."""
    j = pl.program_id(1)
    # (bt, bo) · (r, bo)ᵀ — contract over d_out without transpose
    acc = jax.lax.dot_general(
        g_ref[...], b_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        dzl_ref[...] = acc

    @pl.when(j > 0)
    def _accum():
        dzl_ref[...] += acc


def cola_ae_bwd_dzl(g: jax.Array, b: jax.Array, *,
                    interpret: bool = False) -> jax.Array:
    """g: (T, d_out) cotangent; b: (r, d_out) → dzl = g·Bᵀ (T, r) f32.

    The stage-B backward.  Materializing dzl to HBM is the split path's
    deliberate extra round-trip: it is the seam where the column-parallel
    ``psum`` runs before σ′ is applied (ops._bwd_exec).
    """
    T, d_out = g.shape
    r = b.shape[0]
    e = jnp.dtype(g.dtype).itemsize
    bt = _pick_bt(T)
    bo = _fit_block(d_out, per_unit_bytes=e * (bt + r),
                    fixed_bytes=4 * bt * r, budget=FWD_VMEM_BUDGET)
    (g,), pad_t = _pad_tokens([g], bt)
    Tp = g.shape[0]
    dzl = pl.pallas_call(
        _bwd_dzl_kernel,
        grid=(Tp // bt, d_out // bo),
        in_specs=[
            pl.BlockSpec((bt, bo), lambda i, j: (i, j)),
            pl.BlockSpec((r, bo), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, r), jnp.float32),
        interpret=interpret,
    )(g, b)
    return dzl[:T] if pad_t else dzl


def _bwd_dx_staged_kernel(dzl_ref, zp_ref, a_ref, out_ref, dz_ref, *,
                          sigma: str):
    """dzl_ref/zp_ref: (bt, r) f32; a_ref: (bi, r); out_ref: (bt, bi);
    dz_ref (scratch): (bt, r) f32.  At j == 0 fuses dz = dzl ⊙ σ′(z_pre);
    every j computes dx = dz·Aᵀ against the streamed A block."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_dz():
        dz_ref[...] = dzl_ref[...] * _act.act_grad(zp_ref[...], sigma)

    dz = dz_ref[...].astype(a_ref.dtype)
    # (bt, r) · (bi, r)ᵀ — contract over r
    out_ref[...] = jax.lax.dot_general(
        dz, a_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def cola_ae_bwd_dx_staged(dzl: jax.Array, z_pre: jax.Array, a: jax.Array,
                          *, sigma=True, out_dtype=None,
                          interpret: bool = False) -> jax.Array:
    """dzl: (T, r) f32 (post-psum at column-parallel sites); z_pre: (T, r)
    f32; a: (d_in, r) → dx (T, d_in).  A streams in (bi, r) blocks."""
    sigma = _act.canon(sigma)
    T, r = dzl.shape
    d_in = a.shape[0]
    out_dtype = out_dtype or a.dtype
    e = jnp.dtype(a.dtype).itemsize
    bt = _pick_bt(T)
    bi = _fit_block(d_in, per_unit_bytes=e * (r + bt),
                    fixed_bytes=12 * bt * r, budget=FWD_VMEM_BUDGET)
    (dzl, z_pre), pad_t = _pad_tokens([dzl, z_pre], bt)
    Tp = dzl.shape[0]
    dx = pl.pallas_call(
        functools.partial(_bwd_dx_staged_kernel, sigma=sigma),
        grid=(Tp // bt, d_in // bi),
        in_specs=[
            pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bi, r), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_in), out_dtype),
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(dzl, z_pre, a)
    return dx[:T] if pad_t else dx


def _dz_kernel(dzl_ref, zp_ref, dz_ref, *, sigma: str):
    """dzl_ref/zp_ref/dz_ref: (bt, r) f32 — dz = dzl ⊙ σ′(z_pre), pure VPU."""
    dz_ref[...] = dzl_ref[...] * _act.act_grad(zp_ref[...], sigma)


def cola_ae_dz(dzl: jax.Array, z_pre: jax.Array, *, sigma=True,
               interpret: bool = False) -> jax.Array:
    """dzl/z_pre: (T, r) f32 → dz = dzl ⊙ σ′(z_pre) (T, r) f32.

    Materializes dz ONCE (one extra f32 (T, r) round-trip) so the streamed
    dA kernel re-reads a single r-dim tensor per weight pass instead of
    recomputing dz from (dzl, z_pre) — halving the dominant per-pass
    re-read term (see ``hbm_traffic`` 'staged').  Bias grads reuse it too
    (dbias_a = Σ_t dz) with no extra GEMM.
    """
    sigma = _act.canon(sigma)
    T, r = dzl.shape
    bt = _pick_bt(T)
    (dzl, z_pre), pad_t = _pad_tokens([dzl, z_pre], bt)
    Tp = dzl.shape[0]
    dz = pl.pallas_call(
        functools.partial(_dz_kernel, sigma=sigma),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, r), lambda i: (i, 0)),
            pl.BlockSpec((bt, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Tp, r), jnp.float32),
        interpret=interpret,
    )(dzl, z_pre)
    return dz[:T] if pad_t else dz


def _bwd_da_kernel(x_ref, dz_ref, da_ref):
    """x_ref: (bt, bi); dz_ref: (bt, r) f32; da_ref: (bi, r) f32 revisited
    across the token grid dim (innermost), accumulating ``dA += xᵀ·dz``
    from the pre-materialized dz (cola_ae_dz)."""
    k = pl.program_id(1)
    dz = dz_ref[...].astype(x_ref.dtype)
    # contract over the token tile dim (0, 0)
    upd = jax.lax.dot_general(
        x_ref[...], dz, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        da_ref[...] = upd

    @pl.when(k > 0)
    def _accum():
        da_ref[...] += upd


def cola_ae_bwd_da(x: jax.Array, dz: jax.Array, *,
                   interpret: bool = False) -> jax.Array:
    """x: (T, d_in); dz: (T, r) f32 (from cola_ae_dz) → dA = xᵀ·dz
    (d_in, r) f32.

    Grid (d_in/bi, T/bt), tokens innermost: x streams in (bt, bi) tiles —
    no full-width token tile is ever VMEM-resident, so over-DW-budget
    sites (internlm2 down-proj) stay on the fused path.  Each weight pass
    re-reads only dz (4·bt·r fixed bytes per token tile), half of what the
    old recompute-from-(dzl, z_pre) body paid.
    """
    T, d_in = x.shape
    r = dz.shape[1]
    e = jnp.dtype(x.dtype).itemsize
    bt, bi = _pick_dw_tiles(T, d_in, r, e, fixed_per_bt=4 * r,
                            budget=DW_VMEM_BUDGET)
    (x, dz), pad_t = _pad_tokens([x, dz], bt)
    Tp = x.shape[0]
    return pl.pallas_call(
        _bwd_da_kernel,
        grid=(d_in // bi, Tp // bt),
        in_specs=[
            pl.BlockSpec((bt, bi), lambda i, k: (k, i)),
            pl.BlockSpec((bt, r), lambda i, k: (k, 0)),
        ],
        out_specs=pl.BlockSpec((bi, r), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((d_in, r), jnp.float32),
        interpret=interpret,
    )(x, dz)


def _bwd_db_kernel(zp_ref, g_ref, db_ref, *, sigma: str):
    """zp_ref: (bt, r) f32; g_ref: (bt, bo); db_ref: (r, bo) f32 revisited
    across the token grid dim, accumulating ``dB += σ(z_pre)ᵀ·g``."""
    k = pl.program_id(1)
    z = _act.apply_act(zp_ref[...], sigma).astype(g_ref.dtype)
    upd = jax.lax.dot_general(
        z, g_ref[...], dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == 0)
    def _init():
        db_ref[...] = upd

    @pl.when(k > 0)
    def _accum():
        db_ref[...] += upd


def cola_ae_bwd_db(z_pre: jax.Array, g: jax.Array, *, sigma=True,
                   interpret: bool = False) -> jax.Array:
    """z_pre: (T, r) f32; g: (T, d_out) → dB = σ(z_pre)ᵀ·g (r, d_out) f32.

    Grid (d_out/bo, T/bt), tokens innermost; g streams in (bt, bo) tiles.
    """
    sigma = _act.canon(sigma)
    T, d_out = g.shape
    r = z_pre.shape[1]
    e = jnp.dtype(g.dtype).itemsize
    bt, bo = _pick_dw_tiles(T, d_out, r, e, fixed_per_bt=4 * r,
                            budget=DW_VMEM_BUDGET)
    (z_pre, g), pad_t = _pad_tokens([z_pre, g], bt)
    Tp = g.shape[0]
    return pl.pallas_call(
        functools.partial(_bwd_db_kernel, sigma=sigma),
        grid=(d_out // bo, Tp // bt),
        in_specs=[
            pl.BlockSpec((bt, r), lambda i, k: (k, 0)),
            pl.BlockSpec((bt, bo), lambda i, k: (k, i)),
        ],
        out_specs=pl.BlockSpec((r, bo), lambda i, k: (0, i)),
        out_shape=jax.ShapeDtypeStruct((r, d_out), jnp.float32),
        interpret=interpret,
    )(z_pre, g)


# --------------------------------------------------------------------------
# backward: dx = (g·Bᵀ ⊙ σ′(z_pre)) · Aᵀ
# --------------------------------------------------------------------------
def _bwd_dx_kernel(g_ref, zp_ref, a_ref, b_ref, out_ref, dz_ref, *,
                   n_o: int, bko: int, sigma: str):
    """g_ref: (bt, d_out); zp_ref: (bt, r) f32; a_ref: (bi, r);
    b_ref: (r, d_out); out_ref: (bt, bi); dz_ref (scratch): (bt, r) f32."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _compute_dz():
        def body(k, acc):
            gk = g_ref[:, pl.ds(k * bko, bko)]
            bk_ = b_ref[:, pl.ds(k * bko, bko)]
            # (bt, bko) · (r, bko)ᵀ — contract over d_out without transpose
            return acc + jax.lax.dot_general(
                gk, bk_, dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        dzl = jax.lax.fori_loop(
            0, n_o, body,
            jnp.zeros((g_ref.shape[0], b_ref.shape[0]), jnp.float32))
        dz_ref[...] = dzl * _act.act_grad(zp_ref[...], sigma)

    dz = dz_ref[...].astype(g_ref.dtype)
    # (bt, r) · (bi, r)ᵀ — contract over r
    out_ref[...] = jax.lax.dot_general(
        dz, a_ref[...], dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def cola_ae_bwd_dx(g: jax.Array, z_pre: jax.Array, a: jax.Array,
                   b: jax.Array, *, sigma=True,
                   interpret: bool = False) -> jax.Array:
    """g: (T, d_out) cotangent; z_pre: (T, r) f32; returns dx (T, d_in)."""
    sigma = _act.canon(sigma)
    T, d_out = g.shape
    d_in, r = a.shape
    bt, bi, _ = _pick_tiles(T, d_out, r, d_in)
    bko = _pick_block(d_out, 1024)
    (g, z_pre), pad_t = _pad_tokens([g, z_pre], bt)
    Tp = g.shape[0]
    grid = (Tp // bt, d_in // bi)
    dx = pl.pallas_call(
        functools.partial(_bwd_dx_kernel, n_o=d_out // bko, bko=bko,
                          sigma=sigma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d_out), lambda i, j: (i, 0)),
            pl.BlockSpec((bt, r), lambda i, j: (i, 0)),
            pl.BlockSpec((bi, r), lambda i, j: (j, 0)),
            pl.BlockSpec((r, d_out), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bt, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Tp, d_in), g.dtype),
        scratch_shapes=[pltpu.VMEM((bt, r), jnp.float32)],
        interpret=interpret,
    )(g, z_pre, a, b)
    return dx[:T] if pad_t else dx


# --------------------------------------------------------------------------
# backward: dA += xᵀ·dz, dB += σ(z_pre)ᵀ·g over token tiles
# --------------------------------------------------------------------------
def _bwd_dw_kernel(x_ref, g_ref, zp_ref, b_ref, da_ref, db_ref, *,
                   n_o: int, bko: int, sigma: str):
    """x_ref: (bt, d_in); g_ref: (bt, d_out); zp_ref: (bt, r) f32;
    b_ref: (r, d_out); da_ref: (d_in, r) f32; db_ref: (r, d_out) f32.
    Outputs have constant index maps: revisited every token tile,
    accumulated in VMEM, flushed to HBM once."""
    i = pl.program_id(0)
    zp = zp_ref[...]

    def body(k, acc):
        gk = g_ref[:, pl.ds(k * bko, bko)]
        bk_ = b_ref[:, pl.ds(k * bko, bko)]
        return acc + jax.lax.dot_general(
            gk, bk_, dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
    dzl = jax.lax.fori_loop(
        0, n_o, body, jnp.zeros((g_ref.shape[0], b_ref.shape[0]),
                                jnp.float32))
    dt = x_ref.dtype
    z32, dsig = _act.act_pair(zp, sigma)
    dz = (dzl * dsig).astype(dt)
    z = z32.astype(dt)
    # contract over the token tile dim (0, 0)
    da = jax.lax.dot_general(
        x_ref[...], dz, dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    db = jax.lax.dot_general(
        z, g_ref[...], dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        da_ref[...] = da
        db_ref[...] = db

    @pl.when(i > 0)
    def _accum():
        da_ref[...] += da
        db_ref[...] += db


def cola_ae_bwd_dw(x: jax.Array, g: jax.Array, z_pre: jax.Array,
                   b: jax.Array, *, sigma=True, interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    """Returns (dA (d_in, r), dB (r, d_out)), both f32 accumulators."""
    sigma = _act.canon(sigma)
    T, d_in = x.shape
    r, d_out = b.shape
    bt, _, _ = _pick_tiles(T, d_in, r, d_out)
    bko = _pick_block(d_out, 1024)
    (x, g, z_pre), pad_t = _pad_tokens([x, g, z_pre], bt)
    Tp = x.shape[0]
    da, db = pl.pallas_call(
        functools.partial(_bwd_dw_kernel, n_o=d_out // bko, bko=bko,
                          sigma=sigma),
        grid=(Tp // bt,),
        in_specs=[
            pl.BlockSpec((bt, d_in), lambda i: (i, 0)),
            pl.BlockSpec((bt, d_out), lambda i: (i, 0)),
            pl.BlockSpec((bt, r), lambda i: (i, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d_in, r), lambda i: (0, 0)),
            pl.BlockSpec((r, d_out), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d_in, r), jnp.float32),
            jax.ShapeDtypeStruct((r, d_out), jnp.float32),
        ],
        interpret=interpret,
    )(x, g, z_pre, b)
    return da, db


def weights_fit_vmem(d_in: int, r: int, d_out: int, *,
                     bytes_el: int = 2) -> bool:
    """Whether the *monolithic* fwd/dx kernels' residency fits
    FWD_VMEM_BUDGET: A and B whole, a worst-case token tile of x/g/out,
    and the f32 z scratch.  Failing this no longer leaves the fused path —
    the planner (ops._plan_fwd) takes the two-stage pipeline, whose
    weight-grid tiles fit by construction (per-tile ``_fit_block``)."""
    resident = (bytes_el * (d_in * r + r * d_out)            # A + B whole
                + _MAX_BT * bytes_el * (d_in + d_out)        # x/g + out tile
                + _MAX_BT * 8 * r)                           # z_pre + dz f32
    return resident <= FWD_VMEM_BUDGET


def dw_fits_vmem(d_in: int, r: int, d_out: int, *,
                 bytes_el: int = 2) -> bool:
    """Whether the *monolithic* dA/dB kernel's residency fits
    DW_VMEM_BUDGET: both f32 grad blocks, B whole, and a worst-case token
    tile of x/g/z_pre.  Over budget, the backward streams through the
    bwd_dzl/bwd_da/bwd_db kernels instead of falling back to XLA GEMMs."""
    resident = (4 * (d_in + d_out) * r                       # dA + dB f32
                + bytes_el * r * d_out                       # B whole
                + _MAX_BT * (bytes_el * (d_in + d_out) + 4 * r))
    return resident <= DW_VMEM_BUDGET


# --------------------------------------------------------------------------
# HBM traffic model (benchmarks/throughput_table.py `cola_ae_*` rows)
# --------------------------------------------------------------------------
def hbm_traffic(T: int, d_in: int, r: int, d_out: int, *,
                bytes_el: int = 2, fused=True, path: str = None) -> int:
    """Modeled fwd+bwd HBM bytes for one AE site over T tokens.

    path (``fused`` kept as a legacy bool alias: True → 'monolith',
    False → 'unfused'):

    * ``monolith`` — one fwd kernel (z_pre is the only extra write, f32),
      one dx kernel (dz stays in VMEM), one dA/dB kernel (grads written
      once).  Weights counted once: A's index map is constant and B's
      revisits are consecutive per token tile (double-buffered).
    * ``staged``  — the two-stage pipeline.  Two extra costs vs the
      monolith, both deliberate: the f32 z_pre/dzl round-trips between
      stages (the collective/bias seam), and weight *re-streaming* — each
      stage re-reads its streamed weight once per token tile (n_t =
      ⌈T/bt⌉ passes), the price of dropping whole-weight residency.  The
      dA kernel consumes the once-materialized dz (cola_ae_dz: one extra
      f32 (T, r) round-trip) and re-reads only it per weight pass (n_wi
      passes) — half the old recompute-from-(dzl, z_pre) term; the dB
      kernel still re-reads z_pre per pass (n_wo).  x/g are read once.
    * ``unfused`` — every XLA GEMM and the σ/σ′ element-wise ops round-
      trip their full operands, including the (T, r) dzl/dz
      intermediates.  Weight grads are written in f32 in all cases.

    Read the comparison honestly: the monolith strictly beats the split
    (the split's whole point is sites the monolith cannot serve), and the
    split's *modeled* bytes can exceed the unfused model's because the two
    models are not symmetric — the staged model charges every real
    re-stream, while the unfused model charges each XLA GEMM operand once
    (an infinite-cache ideal; real XLA tiling re-streams too, it just
    doesn't tell you).  The split's wins are structural: σ/σ′ and the
    elementwise products never round-trip at full precision as separate
    ops, six launches replace ~12 XLA ops, per-tile VMEM residency is
    bounded for *any* site, and the z_pre/dzl HBM materializations are
    exactly the seams where the TP collectives and bias adds fuse.
    """
    if path is None:
        path = "monolith" if fused else "unfused"
    e = bytes_el
    w = d_in * r + r * d_out          # weight elements
    zp32 = 4 * T * r                  # f32 z_pre residual
    if path == "monolith":
        fwd = e * (T * d_in + w + T * d_out) + zp32
        bwd_dx = e * (T * d_out + w + T * d_in) + zp32
        bwd_dw = e * (T * d_in + T * d_out + r * d_out) + zp32 + 4 * w
        return fwd + bwd_dx + bwd_dw
    if path == "staged":
        bt = _pick_bt(T)
        n_t = -(-T // bt)             # weight re-streams, one per token tile
        _, bi = _pick_dw_tiles(T, d_in, r, e, 4 * r, DW_VMEM_BUDGET)
        _, bo = _pick_dw_tiles(T, d_out, r, e, 4 * r, DW_VMEM_BUDGET)
        n_wi = -(-d_in // bi)         # dA passes re-reading dz (only)
        n_wo = -(-d_out // bo)        # dB passes re-reading z_pre
        stage_a = e * T * d_in + n_t * e * d_in * r + zp32
        stage_b = zp32 + n_t * e * r * d_out + e * T * d_out
        bwd_dzl = e * T * d_out + n_t * e * r * d_out + zp32
        bwd_dx = 2 * zp32 + n_t * e * d_in * r + e * T * d_in
        dz_mat = 3 * zp32             # cola_ae_dz: read dzl + z_pre, write dz
        bwd_da = e * T * d_in + n_wi * zp32 + 4 * d_in * r
        bwd_db = n_wo * zp32 + e * T * d_out + 4 * r * d_out
        return (stage_a + stage_b + bwd_dzl + bwd_dx + dz_mat + bwd_da
                + bwd_db)
    fwd = (e * (T * d_in + d_in * r) + zp32          # x·A → z_pre
           + 2 * zp32 + e * T * r                    # σ: read z_pre, write z
           + e * (T * r + r * d_out + T * d_out))    # z·B → out
    bwd = (e * (T * d_out + r * d_out) + e * T * r         # g·Bᵀ → dzl
           + e * T * r + zp32 + e * T * r                  # dzl⊙σ′ → dz
           + e * (T * r + d_in * r + T * d_in)             # dz·Aᵀ → dx
           + e * (T * d_in + T * r) + 4 * d_in * r         # xᵀ·dz → dA
           + e * (T * r + T * d_out) + 4 * r * d_out)      # σ(z)ᵀ·g → dB
    return fwd + bwd


def decode_hbm_traffic(T: int, d_in: int, r: int, d_out: int, *,
                       bytes_el: int = 2, fused: bool = True,
                       shards_in: int = 1, shards_rank: int = 1,
                       shards_out: int = 1, split: bool = False,
                       weight_bits: "int | None" = None) -> int:
    """Modeled forward-only HBM bytes for one AE site at decode (T = decode
    batch, typically 1–64 — weight-traffic-bound, activations negligible).

    ``fused`` — the single-launch ``cola_ae_decode`` kernel: x, each weight
    element exactly once, out; the r-dim z never leaves VMEM.  ``unfused``
    — the XLA GEMV pair: z and σ(z) round-trip HBM between ops.  The gap is
    the paper's Table-11 story at kernel grain: CoLA decode moves ~half the
    dense weight bytes, and fusing the bottleneck keeps the remainder pure
    weight traffic.

    TP-sharded serving (`serve_sharded/*` rows): ``shards_in`` /
    ``shards_rank`` / ``shards_out`` divide the weight dims the active
    profile actually shards, so the model returns *per-shard* bytes —
    baseline shards the rank dim (A and B both shrink, x/out stay whole);
    megatron column-parallel shards d_out, row-parallel shards d_in.
    ``split=True`` models the row-parallel ``decode_split`` plan: two
    launches with an f32 (T, r) z_pre round-trip at the psum seam (stage A
    writes it, stage B reads it back post-collective) — the collective's
    own wire bytes live in ``sharding.cola_ae_collective_bytes``.

    ``weight_bits`` (None | 8 | 4) — the quantized streaming kernels
    (``cola_ae_decode_quant`` and split twins): each *weight* term drops
    from ``e·w`` to ``ceil(w·bits/8)`` (int4 nibble-packs two elements
    per byte) **plus** the honest scale charge — 4 bytes per A row and
    per B column, i.e. ``4·(di + do)`` per shard — which does not shrink
    with bits or rank truncation.  Activation terms (x, out, the f32
    z_pre seam) are charged at ``bytes_el`` unchanged: quantization
    touches only what streams from the weight grid.
    """
    e = bytes_el
    di = d_in // shards_in
    rr = r // shards_rank
    do = d_out // shards_out
    w = di * rr + rr * do

    def wbytes(n_el, n_scales):
        """Bytes to stream n_el weight elements (+ their scale rows)."""
        if weight_bits is None:
            return e * n_el
        return (n_el * weight_bits + 7) // 8 + 4 * n_scales
    if split:
        # x·A → z_pre seam; σ(z_pre)·B + bias.  A charges d_in-row
        # scales, B charges d_out-column scales.
        stage_a = e * T * di + wbytes(di * rr, di) + 4 * T * rr
        stage_b = 4 * T * rr + wbytes(rr * do, do) + e * T * do
        return stage_a + stage_b
    if fused:
        return e * (T * di + T * do) + wbytes(w, di + do)
    return (e * (T * di + T * rr) + wbytes(di * rr, di)  # x·A → z
            + 2 * e * T * rr                        # σ: read z, write σ(z)
            + e * (T * rr + T * do) + wbytes(rr * do, do))  # σ(z)·B → out
