"""Pure-jnp oracle for the fused CoLA auto-encoder.

``sigma`` accepts the legacy bool (True → silu) or one of the four modes in
:mod:`repro.kernels.cola_ae.act`.  ``jax.grad`` of this function is the
gradient oracle the fused kernels (monolithic and two-stage) are tested
against.  ``bias_a`` is added to the pre-activation before σ and ``bias_b``
to the output — the same placement the stage-A/stage-B pipeline fuses.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cola_ae import act as _act


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma=True, bias_a: Optional[jax.Array] = None,
            bias_b: Optional[jax.Array] = None) -> jax.Array:
    mode = _act.canon(sigma)
    z = jnp.dot(x, a.astype(x.dtype))
    if bias_a is not None:
        z = z.astype(jnp.float32) + bias_a.astype(jnp.float32)
        z = z.astype(x.dtype) if mode == "none" else z
    if mode != "none":
        z32 = z.astype(jnp.float32)
        z = _act.apply_act(z32, mode).astype(x.dtype)
    out = jnp.dot(z.astype(x.dtype), b.astype(x.dtype))
    if bias_b is not None:
        out = out + bias_b.astype(out.dtype)
    return out
