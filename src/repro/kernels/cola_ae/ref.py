"""Pure-jnp oracle for the fused CoLA auto-encoder."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma: bool = True) -> jax.Array:
    z = jnp.dot(x, a.astype(x.dtype))
    if sigma:
        z32 = z.astype(jnp.float32)
        z = (z32 * jax.nn.sigmoid(z32)).astype(x.dtype)
    return jnp.dot(z, b.astype(x.dtype))
