"""Pure-jnp oracle for the fused CoLA auto-encoder.

``sigma`` accepts the legacy bool (True → silu) or one of the four modes in
:mod:`repro.kernels.cola_ae.act`.  ``jax.grad`` of this function is the
gradient oracle the fused backward kernels are tested against.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.cola_ae import act as _act


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma=True) -> jax.Array:
    mode = _act.canon(sigma)
    z = jnp.dot(x, a.astype(x.dtype))
    if mode != "none":
        z32 = z.astype(jnp.float32)
        z = _act.apply_act(z32, mode).astype(x.dtype)
    return jnp.dot(z, b.astype(x.dtype))
