"""jit'd wrappers for the fused CoLA auto-encoder with custom VJPs, plus
the **stage planner** that picks how each site executes.

Every entry point resolves to one of four plans (pure function of shapes,
mode, bias presence, and whether a collective must run mid-pipeline):

* ``monolith`` — the single fused kernel (kernel.cola_ae_fwd + the fused
  bwd pair), biases folded into the body.  Fast path: weights stay whole
  in VMEM, z_pre never leaves the chip except as the (T, r) residual.
  Requires ``kernel.weights_fit_vmem`` and no mid-pipeline collective.
* ``staged``   — the two-stage pipeline: ``stage_a`` (x·A → z_pre, f32)
  → optional z_pre ``psum`` (megatron row-parallel) → optional bias_a add
  → ``stage_b`` (σ·B + bias_b).  Backward mirrors it: ``bwd_dzl``
  (g·Bᵀ) → optional ``psum`` (megatron column-parallel) → ``cola_ae_dz``
  (dz materialized once) → ``bwd_dx_staged`` ‖ ``bwd_da`` ‖ ``bwd_db``.
  Weight-grid tiling means *any* site fits — over-VMEM sites (internlm2
  down-proj) and collective-split sites stay fused.
* ``decode``   — inference only: the GEMV-shaped ``cola_ae_decode`` single
  launch for T ≤ ``DECODE_T_MAX`` (a decode step's B×1 tokens).  No z_pre
  is computed or emitted; both biases fuse into the launch.
* ``ref``      — plain XLA math; the off-TPU/interpret oracle only.

Forward and backward planning agree on the structural seams (a mid-
pipeline collective forces ``staged`` on both sides); they no longer need
to pick the *same* plan — a bias site takes the monolith forward (bias
folded) while its backward rides the staged kernels, whose materialized
dzl seam yields the bias grads for free.  Both fused plans save the
identical ``(x, z_pre)`` residual pair, so any fwd/bwd pairing composes.

Inference mode (``mode='infer'``, threaded ``linear_apply → cola_apply →``
here from the model facade's prefill/decode paths): the custom VJP is
bypassed entirely — no residual is saved, no z_pre emitted (prefill rides
the fused no-residual forward; decode picks ``cola_ae_decode`` below the T
threshold).  Because no residual exists, infer mode cannot interact with
remat policies: ``cola_m`` wraps only the training stack (see
core/colam.py).  The ``infer_*`` DISPATCH counters let the serve tests
assert decode never silently takes a training-shaped kernel.

Sharded inference (``cola_ae_sharded(mode='infer')`` → ``_sh_infer``) adds
a fifth, infer-only plan — ``decode_split``, the decode kernel cut at the
z seam (kernel.cola_ae_decode_stage_a/_b) — and resolves per site, inside
the shard_map body, against the *local* shapes and the partition's
collective needs.  The sharded-infer plan table (T = local flattened
tokens after the optional sequence-entry all_gather):

    site partitioning        mid collective   T ≤ DECODE_T_MAX   T above
    ───────────────────────  ───────────────  ─────────────────  ────────
    baseline (rank-sharded   out psum         decode             monolith
      A/B)                   (after body)                        /staged
    megatron column-parallel —                decode             monolith
      (qkv/gate/up: B d_out)                                     /staged
    megatron row-parallel    z_pre psum       decode_split       staged
      (o/down: A d_in)       (mid-pipeline)
    fsdp / replicated        —                decode             monolith
                                                                 /staged
    speculative verify       (per profile,    decode             (never: the
      (B×k draft window)      as above)       /decode_split      engine caps
                                                                 B·k ≤ T_MAX)

Quantized weight streaming adds a ``weight_dtype ∈ {bf16, int8, int4}``
routing axis to ``_plan_infer`` (inference only — ``quantize_params``'d
factors arrive as ``quant.QuantFactor``s).  The decode-grain plans swap
in the quantized kernels; prefill-grain plans dequantize the whole
factors once (XLA) and ride the bf16 kernels, renamed so the counters
stay honest; a non-pallas impl with a quant request is an **error**, not
a bf16/ref dispatch — there is no silent fallback:

    weight_dtype   T ≤ DECODE_T_MAX        T above            impl != pallas
    ─────────────  ──────────────────────  ─────────────────  ──────────────
    bf16           decode[_split]          monolith/staged    ref
    int8 / int4    decode[_split] over     dequant_monolith   ValueError
                   q-blocks + scales       /dequant_staged

Counters gain a ``quant_`` tag prefix *inside* the role scope —
``quant_infer_decode``, ``quant_sharded_infer_decode_split``,
``draft_quant_infer_decode``, ``verify_quant_infer_decode``, and
``quant_infer_dequant_monolith`` for the prefill dequant path — so the
serve tests can assert a quantized stream shows zero bare-bf16 decode
counters, per role.

The speculative-decoding engine (serve/engine.py) tags its dispatches by
role through ``dispatch_scope``: the reduced-rank draft scan traces under
``dispatch_scope('draft_')`` and the one-dispatch k-position verify under
``dispatch_scope('verify_')``, prefixing every infer counter —
``draft_infer_decode``, ``verify_infer_decode`` (and their
``*_sharded_infer_decode`` / ``*_sharded_infer_decode_split`` forms under
a mesh).  The verify window rides the same resident-token-tile decode
kernel as a plain chunk step (weights streamed once per dispatch, not
once per draft position), which is the whole amortization argument; the
serve tests assert ``verify_infer_decode > 0`` with zero ``*_ref`` and
zero training-shaped counters — no silent fallback, per role.

Each taken plan lands a ``sharded_infer_{plan}`` DISPATCH counter; the
serve parity harness (tests/test_serve_sharded.py) asserts a served
stream shows only ``sharded_infer_decode``/``sharded_infer_decode_split``
plus the entry all_gather — zero training-shaped kernels, zero ref
fallbacks.  The exit psum sits exactly where the training forward puts
it: rank-sharded sites psum the B-GEMM output (bias_b folded post-psum),
row-parallel sites psum z_pre between the stage launches.

Both fused plans save only ``(x, z_pre)`` where z_pre = A·x [+ bias_a] is
r-dimensional — the CoLA-M residency recipe at kernel level; σ and the
grad GEMMs are evaluated from those:

    dz = (g · Bᵀ) ⊙ σ'(z_pre);  dx = dz · Aᵀ;  dA = xᵀ·dz;  dB = σ(z_pre)ᵀ·g
    dbias_a = Σ_t dz;           dbias_b = Σ_t g

Composition with CoLA-M (core/colam.py): the custom VJP residuals are the
same r-dim, ``cola_r``-named tensor the ``cola_m`` policy saves on the
unfused path — identically for the monolith and the two-stage pipeline, so
the remat policy needs no plan awareness; wrapping a fused block in
``jax.checkpoint(save_only_these_names('cola_r'))`` simply replays the
fused forward (one or two kernels) during backward.

Tensor parallelism (``cola_ae_sharded``): the kernels run per-shard inside
``shard_map`` with explicit collectives placed *between* stages.  The
partitioning is resolved per sharding profile by
``distributed.sharding.cola_ae_partition``:

* ``baseline``  — rank dim of A/B and of the z_pre residual shard over
                  'model'; one psum at the B-GEMM output in fwd and one at
                  ``dz·Aᵀ`` in bwd (a psum_scatter when the sequence dim
                  re-shards, see below),
* ``megatron``  — rank replicated; column-parallel sites (qkv/gate/up)
                  shard B's d_out with a bwd psum of the r-dim ``g·Bᵀ``
                  partial *between* bwd_dzl and the σ′ product;
                  row-parallel sites (o/down) shard A's d_in with a fwd
                  psum of z_pre *between* stage A and stage B — both run
                  the Pallas stage kernels on each side of the collective
                  (the old XLA-math row-parallel branch is gone),
* ``fsdp``      — trivially local: kernels per batch shard, no collective.

Sequence-parallel entry: when the profile seq-shards the residual stream
('seq_save' over 'model') and the site's d_in is not itself model-sharded,
``x_spec`` consumes x sequence-sharded and the body runs an explicit
``all_gather`` fused ahead of the first stage-A token-tile load — the
gather that GSPMD used to insert implicitly outside the shard_map now has
an owner (DISPATCH['sharded_entry_allgather']).  The dx cotangent re-
shards on exit: a single ``psum_scatter`` when the rank psum and the seq
shard ride the same axes (baseline), a local slice otherwise.

Because plan resolution happens *inside* the shard_map body, the monolith
guards see the per-device local shapes: a rank- or output-sharded site can
take the monolith even when the unsharded weights would not fit.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.cola_ae import act as _act
from repro.kernels.cola_ae import quant as _quant

# --------------------------------------------------------------------------
# Dispatch accounting + test override
# --------------------------------------------------------------------------
# Trace-time counters: which path each AE site actually took.  Incremented
# while tracing (once per eager call; once per compile under jit), so tests
# can assert "the fused path dispatched, no silent fallback to XLA math".
DISPATCH = collections.Counter()


def reset_dispatch() -> None:
    DISPATCH.clear()


_force = threading.local()


@contextlib.contextmanager
def force_impl(impl: Optional[str] = None, interpret: Optional[bool] = None,
               plan: Optional[str] = None):
    """Override impl/interpret/plan for every cola_ae entry point in scope.

    Lets CPU test harnesses drive the real Pallas kernels in interpret mode
    through code paths (model apply, shard_map bodies) that do not expose
    the ``impl`` argument.  ``plan`` pins the planner to 'monolith',
    'staged' or (infer entry points only) 'decode' — ignored where the
    plan is structurally impossible: mid-pipeline collective sites cannot
    take the monolith or decode launch, and bias *grads* still require the
    staged backward.

    All three overrides act at *trace time*: they are resolved when a
    cola_ae entry point is traced and baked into the custom_vjp's static
    args.  A callable jitted and executed before entering this context
    keeps its cached lowering — trace (or jit) inside the context, as the
    tests do.
    """
    prev = getattr(_force, "v", (None, None, None))
    _force.v = (impl, interpret, plan)
    try:
        yield
    finally:
        _force.v = prev


_scope = threading.local()


@contextlib.contextmanager
def dispatch_scope(prefix: str):
    """Prefix every infer DISPATCH tag traced in scope — the speculative-
    decoding engine wraps its draft scan in ``dispatch_scope('draft_')``
    and its verify dispatch in ``dispatch_scope('verify_')``, so the
    serve tests can assert the verify dispatch took the decode plan
    (``verify_infer_decode`` / ``verify_sharded_infer_decode``) and the
    reduced-rank draft steps the GEMV path (``draft_infer_decode``) —
    no-silent-fallback, per role.  Trace-time, like force_impl: the
    prefix is read while the jitted spec chunk traces its body."""
    prev = getattr(_scope, "v", "")
    _scope.v = prev + prefix
    try:
        yield
    finally:
        _scope.v = prev


def _scoped(tag: str) -> str:
    return getattr(_scope, "v", "") + tag


def _apply_force(impl: str, interpret: bool) -> Tuple[str, bool]:
    """Resolve the force_impl overrides at entry (= trace time).  The plan
    override is *baked into* the returned impl string ("pallas:staged") so
    it travels through the custom_vjp's static nondiff args and therefore
    participates in jit cache keys — a jitted callable traced under
    force_impl(plan=...) and one traced outside it lower separately."""
    fi, fint, fplan = getattr(_force, "v", (None, None, None))
    impl = fi or impl
    if fplan is not None:
        impl = f"{impl}:{fplan}"
    return impl, (interpret if fint is None else fint)


def _split_impl(impl: str) -> Tuple[str, Optional[str]]:
    """'pallas:staged' -> ('pallas', 'staged'); 'pallas' -> ('pallas', None)."""
    if ":" in impl:
        base, plan = impl.split(":", 1)
        return base, plan
    return impl, None


def _canon_impl(impl: str) -> str:
    impl, _ = _split_impl(impl)
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


# --------------------------------------------------------------------------
# The planner: shapes + structure -> 'monolith' | 'staged' | 'decode' | 'ref'
# --------------------------------------------------------------------------
# Largest flattened token count that dispatches the GEMV-shaped decode
# kernel in infer mode — sized to cover a full slot batch (B×1) of the
# serve engine.  The boundary is by token count, not by caller: a
# production prefill (B×P in the hundreds+) lands above it and takes
# monolith/staged, but a tiny prefill (smoke configs: B=2, P=16 → T=32)
# legitimately takes the decode launch too — small-T is small-T.
DECODE_T_MAX = 64


def _plan(impl: str, a, b, *, needs_seam: bool) -> str:
    """Shared plan resolution.  ``needs_seam``: the pipeline must expose an
    HBM materialization between the two GEMMs — a mid-pipeline collective
    (row-parallel z_pre psum in fwd, column-parallel dzl psum in bwd) or a
    bias *grad* (the materialized dzl yields dbias) — which structurally
    excludes the monolith."""
    _, forced = _split_impl(impl)
    impl = _canon_impl(impl)
    if impl != "pallas":
        return "ref"
    if needs_seam:
        return "staged"
    if forced in ("monolith", "staged"):
        return forced
    from repro.kernels.cola_ae import kernel as _k
    d_in, r = a.shape
    d_out = b.shape[1]
    bytes_el = jnp.dtype(a.dtype).itemsize
    return ("monolith"
            if _k.weights_fit_vmem(d_in, r, d_out, bytes_el=bytes_el)
            else "staged")


def _plan_fwd(impl: str, a, b, *, has_bias: bool = False,
              mid_psum: bool = False) -> str:
    """Forward plan.  ``mid_psum``: a collective must run between the
    A-GEMM and σ (row-parallel z_pre psum).  Bias no longer forces the
    two-stage pipeline — the monolith folds both biases into its body
    (``has_bias`` is kept for signature stability; only the *backward*
    needs the dzl seam for bias grads)."""
    del has_bias
    return _plan(impl, a, b, needs_seam=mid_psum)


def _plan_bwd(impl: str, a, b, *, want_dbias: bool = False,
              mid_psum: bool = False) -> str:
    """Backward plan.  ``mid_psum``: the r-dim ``g·Bᵀ`` partial must be
    psummed before σ′ (column-parallel) — only the staged backward
    materializes that seam; bias grads also need the materialized dzl."""
    return _plan(impl, a, b, needs_seam=want_dbias or mid_psum)


def _plan_infer(impl: str, a, b, T: int, *, mid_psum: bool = False,
                weight_dtype: str = "bf16") -> str:
    """Inference plan: like ``_plan_fwd`` but with the decode fast paths —
    T ≤ DECODE_T_MAX takes a GEMV-shaped launch, which streams weights so
    *any* site fits and fuses both biases.  A mid-pipeline collective
    (row-parallel z_pre psum) cannot ride the single launch; at decode T it
    takes ``decode_split`` — the decode kernel cut at the z seam — and
    above the threshold the training stage pipeline.
    ``force_impl(plan='decode')`` pins the GEMV grain for tests (it
    resolves to decode_split at collective sites).

    ``weight_dtype != 'bf16'`` (QuantFactor args; a/b are then compute-
    dtype shape proxies): decode-grain plans are served by the quantized
    streaming kernels unchanged-in-name; prefill-grain plans become
    ``dequant_monolith``/``dequant_staged`` (whole-factor XLA dequant,
    then the bf16 kernel); a non-pallas impl raises — quantized factors
    have no ref math and silently streaming bf16 would falsify every
    byte model built on the weight_bits term."""
    if weight_dtype not in ("bf16", "int8", "int4"):
        raise ValueError(f"weight_dtype must be bf16|int8|int4, "
                         f"got {weight_dtype!r}")
    _, forced = _split_impl(impl)
    base = _canon_impl(impl)
    if base != "pallas":
        if weight_dtype != "bf16":
            raise ValueError(
                f"no {base!r} implementation for weight_dtype="
                f"{weight_dtype}: quantized weight streaming is "
                f"Pallas-only and does not fall back (off-TPU, trace "
                f"under force_impl('pallas', interpret=True))")
        return "ref"
    if mid_psum:
        if forced in ("monolith", "staged"):
            plan = "staged"
        elif T <= DECODE_T_MAX or forced == "decode":
            plan = "decode_split"
        else:
            plan = "staged"
    elif forced == "decode":
        plan = "decode"
    elif forced in ("monolith", "staged"):
        plan = forced
    elif T <= DECODE_T_MAX:
        plan = "decode"
    else:
        plan = _plan(impl, a, b, needs_seam=False)
    if weight_dtype != "bf16" and plan in ("monolith", "staged"):
        plan = f"dequant_{plan}"
    return plan


# --------------------------------------------------------------------------
# Forward execution (shared by the local VJPs and the shard_map bodies —
# under shard_map the args are per-device shards, so the planner budgets
# against local shapes)
# --------------------------------------------------------------------------
def _fwd_exec(x2, a, b, bias_a, bias_b, sigma, impl, interpret, *,
              psum_zpre=None, tag="fwd"):
    """(out, z_pre) with one A-GEMM — the shared training forward.

    psum_zpre: optional collective applied to the partial z_pre between
    stage A and σ (megatron row-parallel); its presence forces the
    two-stage pipeline.  The saved z_pre is post-psum and post-bias_a, so
    σ/σ′ recomputation in backward sees the true pre-activation.
    """
    plan = _plan_fwd(impl, a, b,
                     has_bias=bias_a is not None or bias_b is not None,
                     mid_psum=psum_zpre is not None)
    if plan == "monolith":
        DISPATCH[f"{tag}_pallas"] += 1
        DISPATCH[f"{tag}_monolith"] += 1
        from repro.kernels.cola_ae import kernel as _k
        # one kernel, one A-GEMM: z_pre comes out of the VMEM scratch,
        # post-bias_a so backward sees the true σ input
        return _k.cola_ae_fwd(x2, a, b, bias_a, bias_b, sigma=sigma,
                              interpret=interpret, return_zpre=True)
    if plan == "staged":
        DISPATCH[f"{tag}_pallas"] += 1
        DISPATCH[f"{tag}_staged"] += 1
        from repro.kernels.cola_ae import kernel as _k
        z_pre = _k.cola_ae_stage_a(x2, a, interpret=interpret)
        if psum_zpre is not None:
            z_pre = psum_zpre(z_pre)
        if bias_a is not None:
            z_pre = z_pre + bias_a.astype(jnp.float32)
        out = _k.cola_ae_stage_b(z_pre, b, bias_b, sigma=sigma,
                                 out_dtype=x2.dtype, interpret=interpret)
        return out, z_pre
    DISPATCH[f"{tag}_ref"] += 1
    z_pre = jnp.dot(x2, a.astype(x2.dtype)).astype(jnp.float32)
    if psum_zpre is not None:
        z_pre = psum_zpre(z_pre)
    if bias_a is not None:
        z_pre = z_pre + bias_a.astype(jnp.float32)
    z = _act.apply_act(z_pre, sigma).astype(x2.dtype)
    out = jnp.dot(z, b.astype(x2.dtype))
    if bias_b is not None:
        out = out + bias_b.astype(out.dtype)
    return out, z_pre


def _fwd_infer(x2, a, b, bias_a, bias_b, sigma, impl, interpret, *,
               psum_zpre=None, tag="infer"):
    """Inference forward: no z_pre emitted or saved, no residuals.

    The plan adds the decode fast path: T ≤ DECODE_T_MAX dispatches the
    GEMV-shaped single launch — a decode step's slot batch always lands
    here, and so does any prefill small enough to be GEMV-shaped (smoke
    configs).  Production-sized prefills (B×P above the threshold) ride
    the same monolith/staged kernels as training, minus the z_pre write.

    Quantized factors (a/b are ``quant.QuantFactor``s): decode-grain
    plans stream q-blocks + scales through the quantized kernel twins;
    prefill-grain plans dequantize whole factors once and ride the bf16
    kernels (``dequant_*`` counters).  The planner sees compute-dtype
    shape proxies so block/plan choices match the bf16 engine exactly —
    the quantized stream is bit-identical to an engine holding
    ``dequantize(...)`` of the same factors.
    """
    is_quant = isinstance(a, _quant.QuantFactor)
    if is_quant:
        # plan against compute-dtype proxies: byte-based plan guards
        # (weights_fit_vmem) must key on what the bf16 twin would do,
        # not on the packed storage — identical routing, identical grids
        plan = _plan_infer(
            impl, jax.ShapeDtypeStruct(a.shape, x2.dtype),
            jax.ShapeDtypeStruct(b.shape, x2.dtype), x2.shape[0],
            mid_psum=psum_zpre is not None, weight_dtype=f"int{a.bits}")
        tag = _scoped("quant_" + tag)
    else:
        plan = _plan_infer(impl, a, b, x2.shape[0],
                           mid_psum=psum_zpre is not None)
        tag = _scoped(tag)  # draft_/verify_ speculative-decoding roles
    DISPATCH[f"{tag}_{plan}"] += 1
    if plan != "ref":
        DISPATCH[f"{tag}_pallas"] += 1
    if plan == "decode":
        from repro.kernels.cola_ae import kernel as _k
        if is_quant:
            return _k.cola_ae_decode_quant(x2, a, b, bias_a, bias_b,
                                           sigma=sigma, out_dtype=x2.dtype,
                                           interpret=interpret)
        return _k.cola_ae_decode(x2, a, b, bias_a, bias_b, sigma=sigma,
                                 out_dtype=x2.dtype, interpret=interpret)
    if plan == "decode_split":
        # the decode kernel cut at the z seam: stage A emits the partial
        # f32 z_pre, the row-parallel psum (+ bias_a) runs between, stage B
        # applies σ·B [+ bias_b] — same GEMV-shaped grids as `decode`
        from repro.kernels.cola_ae import kernel as _k
        if is_quant:
            z_pre = _k.cola_ae_decode_stage_a_quant(x2, a,
                                                    interpret=interpret)
        else:
            z_pre = _k.cola_ae_decode_stage_a(x2, a, interpret=interpret)
        if psum_zpre is not None:
            z_pre = psum_zpre(z_pre)
        if bias_a is not None:
            z_pre = z_pre + bias_a.astype(jnp.float32)
        if is_quant:
            return _k.cola_ae_decode_stage_b_quant(z_pre, b, bias_b,
                                                   sigma=sigma,
                                                   out_dtype=x2.dtype,
                                                   interpret=interpret)
        return _k.cola_ae_decode_stage_b(z_pre, b, bias_b, sigma=sigma,
                                         out_dtype=x2.dtype,
                                         interpret=interpret)
    if plan in ("dequant_monolith", "dequant_staged"):
        # prefill grain: weight traffic is amortized over T tokens, so
        # dequantize the whole factors once (XLA) and ride the bf16
        # kernels — the counters keep the dequant_ name so a quantized
        # stream can still assert zero bare-bf16 dispatches
        a = _quant.dequantize(a).astype(x2.dtype)
        b = _quant.dequantize(b).astype(x2.dtype)
        plan = plan[len("dequant_"):]
    if plan == "monolith":
        from repro.kernels.cola_ae import kernel as _k
        return _k.cola_ae_fwd(x2, a, b, bias_a, bias_b, sigma=sigma,
                              interpret=interpret)
    if plan == "staged":
        from repro.kernels.cola_ae import kernel as _k
        z_pre = _k.cola_ae_stage_a(x2, a, interpret=interpret)
        if psum_zpre is not None:
            z_pre = psum_zpre(z_pre)
        if bias_a is not None:
            z_pre = z_pre + bias_a.astype(jnp.float32)
        return _k.cola_ae_stage_b(z_pre, b, bias_b, sigma=sigma,
                                  out_dtype=x2.dtype, interpret=interpret)
    z_pre = jnp.dot(x2, a.astype(x2.dtype)).astype(jnp.float32)
    if psum_zpre is not None:
        z_pre = psum_zpre(z_pre)
    if bias_a is not None:
        z_pre = z_pre + bias_a.astype(jnp.float32)
    z = _act.apply_act(z_pre, sigma).astype(x2.dtype)
    out = jnp.dot(z, b.astype(x2.dtype))
    if bias_b is not None:
        out = out + bias_b.astype(out.dtype)
    return out


# --------------------------------------------------------------------------
# Backward execution
# --------------------------------------------------------------------------
def _bwd_exec(sigma, impl, interpret, res, g, *, psum_dzl=None,
              want_dbias=False):
    """(dx, da, db[, dbias_a, dbias_b]) from the (x, z_pre) residuals.

    psum_dzl: optional collective applied to the r-dim ``g·Bᵀ`` partial
    before the σ′ product (megatron column-parallel) — forces the staged
    backward, whose bwd_dzl kernel materializes exactly that seam.
    """
    x2, z_pre, a, b = res
    g = g.astype(x2.dtype)
    plan = _plan_bwd(impl, a, b, want_dbias=want_dbias,
                     mid_psum=psum_dzl is not None)
    if plan == "ref":
        DISPATCH["bwd_ref"] += 1
        return _bwd_unfused(sigma, x2, z_pre, a, b, g,
                            psum_dzl=psum_dzl, want_dbias=want_dbias)
    from repro.kernels.cola_ae import kernel as _k
    if plan == "monolith":
        DISPATCH["bwd_pallas"] += 1
        DISPATCH["bwd_monolith"] += 1
        dx = _k.cola_ae_bwd_dx(g, z_pre, a, b, sigma=sigma,
                               interpret=interpret)
        d_in, r = a.shape
        d_out = b.shape[1]
        if _k.dw_fits_vmem(d_in, r, d_out,
                           bytes_el=jnp.dtype(a.dtype).itemsize):
            da, db = _k.cola_ae_bwd_dw(x2, g, z_pre, b, sigma=sigma,
                                       interpret=interpret)
        else:
            # grad blocks exceed VMEM: stream them through the weight-grid
            # kernels (the old XLA-GEMM fallback is gone)
            DISPATCH["bwd_dw_streamed"] += 1
            dzl = _k.cola_ae_bwd_dzl(g, b, interpret=interpret)
            dz = _k.cola_ae_dz(dzl, z_pre, sigma=sigma, interpret=interpret)
            da = _k.cola_ae_bwd_da(x2, dz, interpret=interpret)
            db = _k.cola_ae_bwd_db(z_pre, g, sigma=sigma,
                                   interpret=interpret)
        return dx, da, db
    DISPATCH["bwd_pallas"] += 1
    DISPATCH["bwd_staged"] += 1
    dzl = _k.cola_ae_bwd_dzl(g, b, interpret=interpret)
    if psum_dzl is not None:
        dzl = psum_dzl(dzl)
    # dz materialized ONCE (one extra f32 (T, r) round-trip) so the dA
    # weight passes re-read a single r-dim tensor — see cola_ae_dz
    dz = _k.cola_ae_dz(dzl, z_pre, sigma=sigma, interpret=interpret)
    dx = _k.cola_ae_bwd_dx_staged(dzl, z_pre, a, sigma=sigma,
                                  out_dtype=x2.dtype, interpret=interpret)
    da = _k.cola_ae_bwd_da(x2, dz, interpret=interpret)
    db = _k.cola_ae_bwd_db(z_pre, g, sigma=sigma, interpret=interpret)
    if not want_dbias:
        return dx, da, db
    # bias grads from the already-materialized r-dim seams: XLA reductions
    # over (T, r)/(T, d_out) — no extra GEMM, no extra kernel
    dba = dz.sum(axis=0)
    dbb = g.astype(jnp.float32).sum(axis=0)
    return dx, da, db, dba, dbb


def _bwd_unfused(sigma, x2, z_pre, a, b, g, *, psum_dzl=None,
                 want_dbias=False):
    """Reference backward: XLA GEMMs from the (x, z_pre) residuals."""
    dzl = jax.lax.dot_general(
        g, b.astype(g.dtype), dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (T, r)
    if psum_dzl is not None:
        dzl = psum_dzl(dzl)
    z32, dsig = _act.act_pair(z_pre.astype(jnp.float32), sigma)
    dz = (dzl * dsig).astype(x2.dtype)
    z = z32.astype(x2.dtype)
    dx = jnp.dot(dz, a.T.astype(dz.dtype))
    da = jnp.dot(x2.T, dz)
    db = jnp.dot(z.T, g)
    if not want_dbias:
        return dx, da, db
    return dx, da, db, (dzl * dsig).sum(axis=0), \
        g.astype(jnp.float32).sum(axis=0)


# --------------------------------------------------------------------------
# Local custom VJPs (no mesh) — bias-free and bias-carrying variants
# --------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _cola_ae2d(x2d, a, b, sigma, impl, interpret):
    return _fwd_infer(x2d, a, b, None, None, sigma, impl, interpret)


def _fwd2(x2d, a, b, sigma, impl, interpret):
    sigma = _act.canon(sigma)
    out, z_pre = _fwd_exec(x2d, a, b, None, None, sigma, impl, interpret)
    return out, (x2d, z_pre, a, b)


def _bwd2(sigma, impl, interpret, res, g):
    sigma = _act.canon(sigma)
    x2d, z_pre, a, b = res
    dx, da, db = _bwd_exec(sigma, impl, interpret, (x2d, z_pre, a, b), g)
    return dx.astype(x2d.dtype), da.astype(a.dtype), db.astype(b.dtype)


_cola_ae2d.defvjp(_fwd2, _bwd2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7))
def _cola_ae2d_bias(x2d, a, b, bias_a, bias_b, sigma, impl, interpret):
    return _fwd_infer(x2d, a, b, bias_a, bias_b, sigma, impl, interpret)


def _fwd2_bias(x2d, a, b, bias_a, bias_b, sigma, impl, interpret):
    sigma = _act.canon(sigma)
    out, z_pre = _fwd_exec(x2d, a, b, bias_a, bias_b, sigma, impl,
                           interpret)
    return out, (x2d, z_pre, a, b, bias_a, bias_b)


def _bwd2_bias(sigma, impl, interpret, res, g):
    sigma = _act.canon(sigma)
    x2d, z_pre, a, b, bias_a, bias_b = res
    dx, da, db, dba, dbb = _bwd_exec(
        sigma, impl, interpret, (x2d, z_pre, a, b), g, want_dbias=True)
    return (dx.astype(x2d.dtype), da.astype(a.dtype), db.astype(b.dtype),
            dba.astype(bias_a.dtype), dbb.astype(bias_b.dtype))


_cola_ae2d_bias.defvjp(_fwd2_bias, _bwd2_bias)


# --------------------------------------------------------------------------
# Tensor-parallel fused path: shard_map around the stage planner, explicit
# collectives between stages in a custom VJP (see module docstring for the
# per-profile placement).  The nondiff args (mesh, ColaAePartition) are
# hashable statics, so jit caches one lowering per (site shape,
# partitioning).
# --------------------------------------------------------------------------
def _flat_axis_index(axes, mesh):
    idx = 0
    for ax in axes:
        idx = idx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return idx


def _seq_size(axes, mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _sh_fwd_res(x, a, b, biases, sigma, impl, interpret, mesh, part):
    from jax.experimental.shard_map import shard_map
    has_bias = biases is not None

    def body(xl, al, bl, *bias_l):
        ba_l, bb_l = bias_l if has_bias else (None, None)
        if part.seq_axes:
            # Sequence-parallel entry: consume the residual stream seq-
            # sharded and gather explicitly, fused ahead of the first
            # stage-A token-tile load — no hidden GSPMD gather outside.
            DISPATCH["sharded_entry_allgather"] += 1
            xl = jax.lax.all_gather(xl, part.seq_axes, axis=1, tiled=True)
        x2 = xl.reshape(-1, xl.shape[-1])
        psum_zpre = ((lambda zp: jax.lax.psum(zp, part.in_axes))
                     if part.in_axes else None)
        # rank-sharded B (baseline): each shard's B-GEMM output is a
        # partial that still needs a psum — fold bias_b after it, once.
        bb_kernel = None if part.rank_axes else bb_l
        out, z_pre = _fwd_exec(x2, al, bl, ba_l, bb_kernel, sigma, impl,
                               interpret, psum_zpre=psum_zpre,
                               tag="sharded_fwd")
        if part.rank_axes:
            out = jax.lax.psum(out, part.rank_axes)
            if bb_l is not None:
                out = out + bb_l.astype(out.dtype)
        return out.reshape(*xl.shape[:-1], out.shape[-1]), z_pre

    in_specs = (part.x_spec, part.a_spec, part.b_spec)
    args = (x, a, b)
    if has_bias:
        in_specs += (part.bias_a_spec, part.bias_b_spec)
        args += tuple(biases)
    out, z_pre = shard_map(
        body, mesh, in_specs=in_specs,
        out_specs=(part.out_spec, part.zpre_spec), check_rep=False)(*args)
    return out, z_pre


def _sh_bwd_core(sigma, impl, interpret, mesh, part, has_bias, res, g):
    from jax.experimental.shard_map import shard_map
    if has_bias:
        x, z_pre, a, b, bias_a, bias_b = res
    else:
        x, z_pre, a, b = res

    def body(xl, zpl, al, bl, gl):
        if part.seq_axes:
            # second gather of the saved x shard (Megatron-SP recompute
            # gather) — dA needs full-sequence x against the full-seq dz
            DISPATCH["sharded_entry_allgather"] += 1
            xl = jax.lax.all_gather(xl, part.seq_axes, axis=1, tiled=True)
        x2 = xl.reshape(-1, xl.shape[-1])
        g2 = gl.reshape(-1, gl.shape[-1]).astype(x2.dtype)
        psum_dzl = ((lambda v: jax.lax.psum(v, part.out_axes))
                    if part.out_axes else None)
        outs = _bwd_exec(sigma, impl, interpret, (x2, zpl, al, bl), g2,
                         psum_dzl=psum_dzl, want_dbias=has_bias)
        dx, da, db = outs[:3]
        dx3 = dx.reshape(xl.shape)
        if part.rank_axes and part.seq_axes == part.rank_axes:
            # dz·Aᵀ partials over r, re-sharding the seq dim on exit: one
            # ring pass instead of psum-then-slice
            dx3 = jax.lax.psum_scatter(dx3, part.rank_axes,
                                       scatter_dimension=1, tiled=True)
        else:
            if part.rank_axes:
                dx3 = jax.lax.psum(dx3, part.rank_axes)
            if part.seq_axes:
                n = _seq_size(part.seq_axes, mesh)
                chunk = dx3.shape[1] // n
                idx = _flat_axis_index(part.seq_axes, mesh)
                dx3 = jax.lax.dynamic_slice_in_dim(
                    dx3, idx * chunk, chunk, axis=1)
        if part.batch_axes:
            # per-site slice of the data-parallel gradient all-reduce
            da = jax.lax.psum(da, part.batch_axes)
            db = jax.lax.psum(db, part.batch_axes)
        rets = [dx3.astype(x.dtype), da.astype(al.dtype),
                db.astype(bl.dtype)]
        if has_bias:
            dba, dbb = outs[3], outs[4]
            if part.batch_axes:
                dba = jax.lax.psum(dba, part.batch_axes)
                dbb = jax.lax.psum(dbb, part.batch_axes)
            rets += [dba.astype(bias_a.dtype), dbb.astype(bias_b.dtype)]
        return tuple(rets)

    out_specs = [part.x_spec, part.a_spec, part.b_spec]
    if has_bias:
        out_specs += [part.bias_a_spec, part.bias_b_spec]
    return shard_map(
        body, mesh,
        in_specs=(part.x_spec, part.zpre_spec, part.a_spec, part.b_spec,
                  part.out_spec),
        out_specs=tuple(out_specs), check_rep=False)(x, z_pre, a, b, g)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _cola_ae3d_sh(x, a, b, sigma, impl, interpret, mesh, part):
    out, _ = _sh_fwd_res(x, a, b, None, sigma, impl, interpret, mesh, part)
    return out


def _sh_fwd(x, a, b, sigma, impl, interpret, mesh, part):
    out, z_pre = _sh_fwd_res(x, a, b, None, sigma, impl, interpret, mesh,
                             part)
    return out, (x, z_pre, a, b)


def _sh_bwd(sigma, impl, interpret, mesh, part, res, g):
    return _sh_bwd_core(sigma, impl, interpret, mesh, part, False, res, g)


_cola_ae3d_sh.defvjp(_sh_fwd, _sh_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _cola_ae3d_sh_bias(x, a, b, bias_a, bias_b, sigma, impl, interpret,
                       mesh, part):
    out, _ = _sh_fwd_res(x, a, b, (bias_a, bias_b), sigma, impl, interpret,
                         mesh, part)
    return out


def _sh_fwd_bias(x, a, b, bias_a, bias_b, sigma, impl, interpret, mesh,
                 part):
    out, z_pre = _sh_fwd_res(x, a, b, (bias_a, bias_b), sigma, impl,
                             interpret, mesh, part)
    return out, (x, z_pre, a, b, bias_a, bias_b)


def _sh_bwd_bias(sigma, impl, interpret, mesh, part, res, g):
    return _sh_bwd_core(sigma, impl, interpret, mesh, part, True, res, g)


_cola_ae3d_sh_bias.defvjp(_sh_fwd_bias, _sh_bwd_bias)


def _sh_infer(x, a, b, biases, sigma, impl, interpret, mesh, part):
    """Inference-mode shard_map forward: per-shard ``_fwd_infer`` bodies
    with the same collective placement as the training forward (z_pre psum
    at row-parallel sites, rank psum of out) — but no residual, no custom
    VJP, and the decode plan available whenever no mid-pipeline collective
    is required."""
    from jax.experimental.shard_map import shard_map
    has_bias = biases is not None

    def body(xl, al, bl, *bias_l):
        ba_l, bb_l = bias_l if has_bias else (None, None)
        if part.seq_axes:
            DISPATCH["sharded_entry_allgather"] += 1
            xl = jax.lax.all_gather(xl, part.seq_axes, axis=1, tiled=True)
        x2 = xl.reshape(-1, xl.shape[-1])
        psum_zpre = ((lambda zp: jax.lax.psum(zp, part.in_axes))
                     if part.in_axes else None)
        bb_kernel = None if part.rank_axes else bb_l
        out = _fwd_infer(x2, al, bl, ba_l, bb_kernel, sigma, impl,
                         interpret, psum_zpre=psum_zpre,
                         tag="sharded_infer")
        if part.rank_axes:
            out = jax.lax.psum(out, part.rank_axes)
            if bb_l is not None:
                out = out + bb_l.astype(out.dtype)
        return out.reshape(*xl.shape[:-1], out.shape[-1])

    in_specs = (part.x_spec, part.a_spec, part.b_spec)
    args = (x, a, b)
    if has_bias:
        in_specs += (part.bias_a_spec, part.bias_b_spec)
        args += tuple(biases)
    return shard_map(body, mesh, in_specs=in_specs,
                     out_specs=part.out_spec, check_rep=False)(*args)


def _sh_infer_quant(x, qa, qb, biases, sigma, impl, interpret, mesh, part):
    """``_sh_infer`` over quantized factors: the q and scale arrays enter
    the shard_map as four leaves (q reuses the factor's weight spec, the
    scales ride ``sharding.cola_ae_quant_specs``) and the body rebuilds
    local ``QuantFactor``s, so each shard streams its local q-blocks with
    its local scales.  Factors were quantized *globally* at engine build
    — scale layouts commute with the sharding, so the sharded stream is
    bit-identical to the single-device quantized engine."""
    from jax.experimental.shard_map import shard_map
    from repro.distributed import sharding as _sh
    has_bias = biases is not None

    def _n(axes):
        n = 1
        for ax in axes:
            n *= mesh.shape[ax]
        return n

    # int4 packs two elements per byte along the *sharded* weight dims
    # (A: d_in, B: d_out) — a packed pair must not straddle a shard
    # boundary, so the local extent must stay even
    d_in = qa.shape[-2]
    d_out = qb.shape[-1]
    if qa.bits == 4 and (d_in // _n(part.in_axes)) % 2:
        raise ValueError(
            f"int4 A factor: local d_in {d_in}/{_n(part.in_axes)} shards "
            f"is odd — nibble pairs would straddle shard boundaries")
    if qb.bits == 4 and (d_out // _n(part.out_axes)) % 2:
        raise ValueError(
            f"int4 B factor: local d_out {d_out}/{_n(part.out_axes)} "
            f"shards is odd — nibble pairs would straddle shard "
            f"boundaries")
    sa_spec, sb_spec = _sh.cola_ae_quant_specs(part)
    kind_a, bits_a = qa.kind, qa.bits
    kind_b, bits_b = qb.kind, qb.bits

    def body(xl, qal, sal, qbl, sbl, *bias_l):
        ba_l, bb_l = bias_l if has_bias else (None, None)
        if part.seq_axes:
            DISPATCH["sharded_entry_allgather"] += 1
            xl = jax.lax.all_gather(xl, part.seq_axes, axis=1, tiled=True)
        x2 = xl.reshape(-1, xl.shape[-1])
        al = _quant.QuantFactor(qal, sal, kind=kind_a, bits=bits_a)
        bl = _quant.QuantFactor(qbl, sbl, kind=kind_b, bits=bits_b)
        psum_zpre = ((lambda zp: jax.lax.psum(zp, part.in_axes))
                     if part.in_axes else None)
        bb_kernel = None if part.rank_axes else bb_l
        out = _fwd_infer(x2, al, bl, ba_l, bb_kernel, sigma, impl,
                         interpret, psum_zpre=psum_zpre,
                         tag="sharded_infer")
        if part.rank_axes:
            out = jax.lax.psum(out, part.rank_axes)
            if bb_l is not None:
                out = out + bb_l.astype(out.dtype)
        return out.reshape(*xl.shape[:-1], out.shape[-1])

    in_specs = (part.x_spec, part.a_spec, sa_spec, part.b_spec, sb_spec)
    args = (x, qa.q, qa.scale, qb.q, qb.scale)
    if has_bias:
        in_specs += (part.bias_a_spec, part.bias_b_spec)
        args += tuple(biases)
    return shard_map(body, mesh, in_specs=in_specs,
                     out_specs=part.out_spec, check_rep=False)(*args)


def cola_ae_sharded(x: jax.Array, a: jax.Array, b: jax.Array, *,
                    sigma=True, bias_a: Optional[jax.Array] = None,
                    bias_b: Optional[jax.Array] = None, env=None,
                    in_ax: Optional[str] = None,
                    out_ax: Optional[str] = None, impl: str = "auto",
                    interpret: bool = False,
                    mode: str = "train") -> jax.Array:
    """Tensor-parallel fused auto-encoder over a (b, s, d_in) activation.

    in_ax/out_ax are the *logical* axis names of the site's weight dims
    (cola_defs convention: a is (in_ax, 'rank'), b is ('rank', out_ax));
    the active MeshEnv's profile decides what they shard over.  Bias sites
    (both biases, as cola_defs creates them) stay fused — bias_a folds into
    the saved z_pre (monolith body or staged seam), bias_b into the output
    tile / stage-B body.

    mode='infer' (prefill/decode): runs the fwd-only shard_map body — no
    custom VJP, no z_pre residual, decode kernel below the T threshold.
    """
    from repro.distributed import sharding as _sh
    env = env or _sh.current_env()
    if env is None:
        raise ValueError("cola_ae_sharded requires an active mesh_env")
    if x.ndim != 3:
        raise ValueError(f"cola_ae_sharded expects (b, s, d) input, "
                         f"got ndim={x.ndim}")
    if (bias_a is None) != (bias_b is None):
        raise ValueError("cola_ae_sharded expects both biases or neither")
    if mode not in ("train", "infer"):
        raise ValueError(f"mode must be 'train'|'infer', got {mode!r}")
    act_mode = _act.canon(sigma)
    impl, interpret = _apply_force(impl, interpret)
    is_quant = isinstance(a, _quant.QuantFactor)
    if is_quant and mode != "infer":
        raise ValueError("quantized factors are inference-only: training "
                         "needs f32/bf16 weights (quantize_params is a "
                         "serve-engine build step)")
    part = _sh.cola_ae_partition(env, x.shape, a.shape, b.shape,
                                 in_ax, out_ax)
    DISPATCH["sharded_call"] += 1
    if mode == "infer":
        biases = (bias_a, bias_b) if bias_a is not None else None
        if is_quant:
            return _sh_infer_quant(x, a, b, biases, act_mode, impl,
                                   interpret, env.mesh, part)
        return _sh_infer(x, a.astype(x.dtype), b.astype(x.dtype), biases,
                         act_mode, impl, interpret, env.mesh, part)
    if bias_a is not None:
        return _cola_ae3d_sh_bias(x, a.astype(x.dtype), b.astype(x.dtype),
                                  bias_a, bias_b, act_mode, impl, interpret,
                                  env.mesh, part)
    return _cola_ae3d_sh(x, a.astype(x.dtype), b.astype(x.dtype), act_mode,
                         impl, interpret, env.mesh, part)


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma=True, bias_a: Optional[jax.Array] = None,
            bias_b: Optional[jax.Array] = None, impl: str = "auto",
            interpret: bool = False, mode: str = "train") -> jax.Array:
    """Fused auto-encoder over the last dim of x (any leading dims).

    sigma: bool (legacy; True → silu) or one of act.SIGMA_MODES.  Bias
    sites stay fused on every plan: the monolith folds both biases into
    its body, the staged pipeline into z_pre / the stage-B body, the
    decode kernel into its single launch.

    mode='infer' (threaded from the model facade's prefill/decode paths):
    bypasses the custom VJP — no residual is saved, no z_pre emitted, and
    T ≤ DECODE_T_MAX dispatches ``cola_ae_decode``.  mode='train' keeps
    the custom-VJP path whose primal is the same no-residual forward.
    """
    act_mode = _act.canon(sigma)
    impl, interpret = _apply_force(impl, interpret)
    if (bias_a is None) != (bias_b is None):
        raise ValueError("cola_ae expects both biases or neither "
                         "(cola_defs always creates the pair)")
    if mode not in ("train", "infer"):
        raise ValueError(f"mode must be 'train'|'infer', got {mode!r}")
    is_quant = isinstance(a, _quant.QuantFactor)
    if is_quant and mode != "infer":
        raise ValueError("quantized factors are inference-only: training "
                         "needs f32/bf16 weights (quantize_params is a "
                         "serve-engine build step)")
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    if mode == "infer":
        DISPATCH["infer_call"] += 1
        if is_quant:
            out = _fwd_infer(x2d, a, b, bias_a, bias_b, act_mode, impl,
                             interpret)
        else:
            out = _fwd_infer(x2d, a.astype(x.dtype), b.astype(x.dtype),
                             bias_a, bias_b, act_mode, impl, interpret)
    elif bias_a is not None:
        out = _cola_ae2d_bias(x2d, a.astype(x.dtype), b.astype(x.dtype),
                              bias_a, bias_b, act_mode, impl, interpret)
    else:
        out = _cola_ae2d(x2d, a.astype(x.dtype), b.astype(x.dtype),
                         act_mode, impl, interpret)
    return out.reshape(*lead, b.shape[-1])
