"""jit'd wrapper for the fused CoLA auto-encoder with custom VJP.

Forward: the Pallas kernel (or ref off-TPU).  The VJP saves only
(x, z_pre) where z_pre = A·x is r-dimensional — the CoLA-M residency recipe
at kernel level; σ and both grad GEMMs are evaluated from those:

    dz = (g · Bᵀ) ⊙ σ'(z_pre);  dx = dz · Aᵀ;  dA = xᵀ·dz;  dB = σ(z_pre)ᵀ·g

On the Pallas path the forward kernel *emits* z_pre (its VMEM scratch) as a
second output, so training issues exactly one A-GEMM — no recompute — and
the backward runs as two fused kernels (kernel.cola_ae_bwd_dx /
cola_ae_bwd_dw) in which the r-dim ``dz`` never round-trips HBM.  The
unfused XLA math below (`_bwd_unfused`) is kept as the off-TPU/interpret
reference and as the dA/dB fallback for sites whose f32 grad blocks exceed
the VMEM budget (kernel.dw_fits_vmem).

Composition with CoLA-M (core/colam.py): the unfused path tags its r-dim
activation with ``checkpoint_name('cola_r')`` so the ``cola_m`` policy saves
exactly that tensor.  The fused path achieves the same residency *without*
the policy — its VJP residuals are already only (x, z_pre) — so wrapping a
fused block in ``jax.checkpoint(save_only_these_names('cola_r'))`` simply
replays the one fused forward kernel during backward (policies cannot see
inside a custom_vjp); residency is minimal either way.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cola_ae import act as _act
from repro.kernels.cola_ae import ref as _ref


def _canon_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _resolve_impl(impl: str, a, b) -> str:
    """Shape-aware dispatch: sites whose whole weights exceed the kernels'
    VMEM residency (kernel.weights_fit_vmem) take the unfused path.  Pure
    function of (impl, shapes) — forward and backward agree by construction.
    """
    impl = _canon_impl(impl)
    if impl != "pallas":
        return impl
    from repro.kernels.cola_ae import kernel as _k
    d_in, r = a.shape
    d_out = b.shape[1]
    bytes_el = jnp.dtype(a.dtype).itemsize
    return ("pallas"
            if _k.weights_fit_vmem(d_in, r, d_out, bytes_el=bytes_el)
            else "ref")


def _fwd_compute(x2d, a, b, sigma, impl, interpret):
    if _resolve_impl(impl, a, b) == "pallas":
        from repro.kernels.cola_ae import kernel as _k
        return _k.cola_ae_fwd(x2d, a, b, sigma=sigma, interpret=interpret)
    return _ref.cola_ae(x2d, a, b, sigma=sigma)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _cola_ae2d(x2d, a, b, sigma, impl, interpret):
    return _fwd_compute(x2d, a, b, sigma, impl, interpret)


def _fwd2(x2d, a, b, sigma, impl, interpret):
    sigma = _act.canon(sigma)
    if _resolve_impl(impl, a, b) == "pallas":
        from repro.kernels.cola_ae import kernel as _k
        # one kernel, one A-GEMM: z_pre comes out of the VMEM scratch
        out, z_pre = _k.cola_ae_fwd(x2d, a, b, sigma=sigma,
                                    interpret=interpret, return_zpre=True)
    else:
        z_pre = jnp.dot(x2d, a.astype(x2d.dtype)).astype(jnp.float32)
        z = _act.apply_act(z_pre, sigma).astype(x2d.dtype)
        out = jnp.dot(z, b.astype(x2d.dtype))
    return out, (x2d, z_pre, a, b)


def _dz_and_z(sigma, z_pre, g, b, dt):
    """dz = (g·Bᵀ)⊙σ′(z_pre) and z = σ(z_pre), both in dt — the shared
    r-dim backward math of the reference path and the dA/dB fallback."""
    zp32 = z_pre.astype(jnp.float32)
    dzl = jax.lax.dot_general(
        g, b.astype(g.dtype), dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (T, r)
    dz = (dzl * _act.act_grad(zp32, sigma)).astype(dt)
    z = _act.apply_act(zp32, sigma).astype(dt)
    return dz, z


def _bwd_unfused(sigma, res, g):
    """Reference backward: four XLA GEMMs from the (x, z_pre) residuals."""
    x2d, z_pre, a, b = res
    g = g.astype(x2d.dtype)
    dz, z = _dz_and_z(sigma, z_pre, g, b, x2d.dtype)
    dx = jnp.dot(dz, a.T.astype(dz.dtype))
    da = jnp.dot(x2d.T, dz).astype(a.dtype)
    db = jnp.dot(z.T, g).astype(b.dtype)
    return dx, da, db


def _bwd_impl(sigma, impl, interpret, res, g):
    sigma = _act.canon(sigma)
    x2d, z_pre, a, b = res
    if _resolve_impl(impl, a, b) != "pallas":
        return _bwd_unfused(sigma, res, g)
    from repro.kernels.cola_ae import kernel as _k
    g = g.astype(x2d.dtype)
    dx = _k.cola_ae_bwd_dx(g, z_pre, a, b, sigma=sigma, interpret=interpret)
    d_in, r = a.shape
    d_out = b.shape[1]
    if _k.dw_fits_vmem(d_in, r, d_out,
                       bytes_el=jnp.dtype(a.dtype).itemsize):
        da, db = _k.cola_ae_bwd_dw(x2d, g, z_pre, b, sigma=sigma,
                                   interpret=interpret)
    else:
        # grad blocks exceed VMEM: same math from the same r-dim residuals
        dz, z = _dz_and_z(sigma, z_pre, g, b, x2d.dtype)
        da = jnp.dot(x2d.T, dz)
        db = jnp.dot(z.T, g)
    return dx.astype(x2d.dtype), da.astype(a.dtype), db.astype(b.dtype)


_cola_ae2d.defvjp(_fwd2, _bwd_impl)


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma=True, bias_a: Optional[jax.Array] = None,
            bias_b: Optional[jax.Array] = None, impl: str = "auto",
            interpret: bool = False) -> jax.Array:
    """Fused auto-encoder over the last dim of x (any leading dims).

    sigma: bool (legacy; True → silu) or one of act.SIGMA_MODES.
    """
    mode = _act.canon(sigma)
    if bias_a is not None or bias_b is not None:
        # bias sites fall back to the unfused path (rare: qwen2 qkv)
        z = jnp.einsum("...d,dr->...r", x, a.astype(x.dtype))
        if bias_a is not None:
            z = z + bias_a.astype(x.dtype)
        if mode != "none":
            z = _act.apply_act(z.astype(jnp.float32), mode).astype(x.dtype)
        h = jnp.einsum("...r,ro->...o", z, b.astype(x.dtype))
        if bias_b is not None:
            h = h + bias_b.astype(x.dtype)
        return h
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    out = _cola_ae2d(x2d, a.astype(x.dtype), b.astype(x.dtype), mode,
                     impl, interpret)
    return out.reshape(*lead, b.shape[-1])
