"""jit'd wrapper for the fused CoLA auto-encoder with custom VJP.

Forward: the Pallas kernel (or ref off-TPU).  The VJP saves only
(x, z_pre) where z_pre = A·x is r-dimensional — the CoLA-M residency recipe
at kernel level; σ and both grad GEMMs are evaluated from those:

    dz = (g · Bᵀ) ⊙ σ'(z_pre);  dx = dz · Aᵀ;  dA = xᵀ·dz;  dB = σ(z_pre)ᵀ·g

On the Pallas path the forward kernel *emits* z_pre (its VMEM scratch) as a
second output, so training issues exactly one A-GEMM — no recompute — and
the backward runs as two fused kernels (kernel.cola_ae_bwd_dx /
cola_ae_bwd_dw) in which the r-dim ``dz`` never round-trips HBM.  The
unfused XLA math below (`_bwd_unfused`) is kept as the off-TPU/interpret
reference and as the dA/dB fallback for sites whose f32 grad blocks exceed
the VMEM budget (kernel.dw_fits_vmem).

Composition with CoLA-M (core/colam.py): the unfused path tags its r-dim
activation with ``checkpoint_name('cola_r')`` so the ``cola_m`` policy saves
exactly that tensor.  The fused path achieves the same residency *without*
the policy — its VJP residuals are already only (x, z_pre) — so wrapping a
fused block in ``jax.checkpoint(save_only_these_names('cola_r'))`` simply
replays the one fused forward kernel during backward (policies cannot see
inside a custom_vjp); residency is minimal either way.

Tensor parallelism (``cola_ae_sharded``): under a mesh with a nontrivial
'model' axis the fused path no longer falls back — the same kernels run
per-shard inside ``shard_map`` with a collective-aware custom VJP.  The
partitioning is resolved per sharding profile by
``distributed.sharding.cola_ae_partition``:

* ``baseline``  — the rank dim of A/B and of the z_pre residual shard over
                  'model'; one psum at the B-GEMM output in fwd and one at
                  ``dz·Aᵀ`` in bwd,
* ``megatron``  — rank replicated; column-parallel sites (qkv/gate/up)
                  shard B's d_out with a bwd psum of the r-dim ``g·Bᵀ``
                  partial, row-parallel sites (o/down) shard A's d_in with
                  a fwd psum of z_pre between the A-GEMM and σ (the block-
                  exit all-reduce, matching sharding.py's 2/block design) —
                  those fwd A-GEMMs take XLA math because a collective
                  cannot run between the fused kernel's two GEMMs,
* ``fsdp``      — trivially local: kernels per batch shard, no collective.

Because impl resolution happens *inside* the shard_map body, the VMEM
guards (kernel.weights_fit_vmem / dw_fits_vmem) see the per-shard local
shapes: a rank- or output-sharded site can take the fused path even when
the unsharded weights would not fit.
"""
from __future__ import annotations

import collections
import contextlib
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.cola_ae import act as _act
from repro.kernels.cola_ae import ref as _ref

# --------------------------------------------------------------------------
# Dispatch accounting + test override
# --------------------------------------------------------------------------
# Trace-time counters: which path each AE site actually took.  Incremented
# while tracing (once per eager call; once per compile under jit), so tests
# can assert "the fused sharded path dispatched, no silent fallback".
DISPATCH = collections.Counter()


def reset_dispatch() -> None:
    DISPATCH.clear()


_force = threading.local()


@contextlib.contextmanager
def force_impl(impl: Optional[str] = None, interpret: Optional[bool] = None):
    """Override impl/interpret for every cola_ae entry point in scope.

    Lets CPU test harnesses drive the real Pallas kernels in interpret mode
    through code paths (model apply, shard_map bodies) that do not expose
    the ``impl`` argument.
    """
    prev = getattr(_force, "v", (None, None))
    _force.v = (impl, interpret)
    try:
        yield
    finally:
        _force.v = prev


def _apply_force(impl: str, interpret: bool) -> Tuple[str, bool]:
    fi, fint = getattr(_force, "v", (None, None))
    return (fi or impl), (interpret if fint is None else fint)


def _canon_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return impl


def _resolve_impl(impl: str, a, b) -> str:
    """Shape-aware dispatch: sites whose whole weights exceed the kernels'
    VMEM residency (kernel.weights_fit_vmem) take the unfused path.  Pure
    function of (impl, shapes) — forward and backward agree by construction.
    """
    impl = _canon_impl(impl)
    if impl != "pallas":
        return impl
    from repro.kernels.cola_ae import kernel as _k
    d_in, r = a.shape
    d_out = b.shape[1]
    bytes_el = jnp.dtype(a.dtype).itemsize
    return ("pallas"
            if _k.weights_fit_vmem(d_in, r, d_out, bytes_el=bytes_el)
            else "ref")


def _fwd_compute(x2d, a, b, sigma, impl, interpret):
    if _resolve_impl(impl, a, b) == "pallas":
        from repro.kernels.cola_ae import kernel as _k
        return _k.cola_ae_fwd(x2d, a, b, sigma=sigma, interpret=interpret)
    return _ref.cola_ae(x2d, a, b, sigma=sigma)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _cola_ae2d(x2d, a, b, sigma, impl, interpret):
    return _fwd_compute(x2d, a, b, sigma, impl, interpret)


def _fwd_pair(x2d, a, b, sigma, impl, interpret, tag="fwd"):
    """(out, z_pre) with one A-GEMM — the shared training forward of the
    local custom VJP and of the shard_map body (where a/b/x2d are the
    per-device shards, so _resolve_impl budgets against local shapes)."""
    if _resolve_impl(impl, a, b) == "pallas":
        DISPATCH[f"{tag}_pallas"] += 1
        from repro.kernels.cola_ae import kernel as _k
        # one kernel, one A-GEMM: z_pre comes out of the VMEM scratch
        return _k.cola_ae_fwd(x2d, a, b, sigma=sigma,
                              interpret=interpret, return_zpre=True)
    DISPATCH[f"{tag}_ref"] += 1
    z_pre = jnp.dot(x2d, a.astype(x2d.dtype)).astype(jnp.float32)
    z = _act.apply_act(z_pre, sigma).astype(x2d.dtype)
    out = jnp.dot(z, b.astype(x2d.dtype))
    return out, z_pre


def _fwd2(x2d, a, b, sigma, impl, interpret):
    sigma = _act.canon(sigma)
    out, z_pre = _fwd_pair(x2d, a, b, sigma, impl, interpret)
    return out, (x2d, z_pre, a, b)


def _dz_and_z(sigma, z_pre, g, b, dt):
    """dz = (g·Bᵀ)⊙σ′(z_pre) and z = σ(z_pre), both in dt — the shared
    r-dim backward math of the reference path and the dA/dB fallback."""
    zp32 = z_pre.astype(jnp.float32)
    dzl = jax.lax.dot_general(
        g, b.astype(g.dtype), dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)  # (T, r)
    dz = (dzl * _act.act_grad(zp32, sigma)).astype(dt)
    z = _act.apply_act(zp32, sigma).astype(dt)
    return dz, z


def _bwd_unfused(sigma, res, g):
    """Reference backward: four XLA GEMMs from the (x, z_pre) residuals."""
    x2d, z_pre, a, b = res
    g = g.astype(x2d.dtype)
    dz, z = _dz_and_z(sigma, z_pre, g, b, x2d.dtype)
    dx = jnp.dot(dz, a.T.astype(dz.dtype))
    da = jnp.dot(x2d.T, dz).astype(a.dtype)
    db = jnp.dot(z.T, g).astype(b.dtype)
    return dx, da, db


def _bwd_impl(sigma, impl, interpret, res, g):
    sigma = _act.canon(sigma)
    x2d, z_pre, a, b = res
    if _resolve_impl(impl, a, b) != "pallas":
        DISPATCH["bwd_ref"] += 1
        return _bwd_unfused(sigma, res, g)
    DISPATCH["bwd_pallas"] += 1
    from repro.kernels.cola_ae import kernel as _k
    g = g.astype(x2d.dtype)
    dx = _k.cola_ae_bwd_dx(g, z_pre, a, b, sigma=sigma, interpret=interpret)
    d_in, r = a.shape
    d_out = b.shape[1]
    if _k.dw_fits_vmem(d_in, r, d_out,
                       bytes_el=jnp.dtype(a.dtype).itemsize):
        da, db = _k.cola_ae_bwd_dw(x2d, g, z_pre, b, sigma=sigma,
                                   interpret=interpret)
    else:
        # grad blocks exceed VMEM: same math from the same r-dim residuals
        dz, z = _dz_and_z(sigma, z_pre, g, b, x2d.dtype)
        da = jnp.dot(x2d.T, dz)
        db = jnp.dot(z.T, g)
    return dx.astype(x2d.dtype), da.astype(a.dtype), db.astype(b.dtype)


_cola_ae2d.defvjp(_fwd2, _bwd_impl)


# --------------------------------------------------------------------------
# Tensor-parallel fused path: shard_map around the kernels, explicit
# collectives in a custom VJP (see module docstring for the per-profile
# placement).  The nondiff args (mesh, ColaAePartition) are hashable
# statics, so jit caches one lowering per (site shape, partitioning).
# --------------------------------------------------------------------------
def _sh_fwd_res(x, a, b, sigma, impl, interpret, mesh, part):
    from jax.experimental.shard_map import shard_map

    def body(xl, al, bl):
        x2 = xl.reshape(-1, xl.shape[-1])
        if part.in_axes:
            # Row-parallel input (megatron o/down): the partial z_pre must
            # be psummed *between* the A-GEMM and σ — a collective cannot
            # run inside the fused kernel, so this branch is XLA math.  The
            # residual stays the r-dim z_pre; residency is unchanged.
            DISPATCH["sharded_fwd_rowpar_xla"] += 1
            zp = jnp.dot(x2, al.astype(x2.dtype),
                         preferred_element_type=jnp.float32)
            zp = jax.lax.psum(zp.astype(jnp.float32), part.in_axes)
            z = _act.apply_act(zp, sigma).astype(x2.dtype)
            out = jnp.dot(z, bl.astype(x2.dtype))
        else:
            out, zp = _fwd_pair(x2, al, bl, sigma, impl, interpret,
                                tag="sharded_fwd")
        if part.rank_axes:
            # rank-sharded B (baseline): each shard's B-GEMM is a partial
            out = jax.lax.psum(out, part.rank_axes)
        return out.reshape(*xl.shape[:-1], out.shape[-1]), zp

    out, z_pre = shard_map(
        body, mesh, in_specs=(part.x_spec, part.a_spec, part.b_spec),
        out_specs=(part.out_spec, part.zpre_spec), check_rep=False)(x, a, b)
    return out, z_pre


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _cola_ae3d_sh(x, a, b, sigma, impl, interpret, mesh, part):
    out, _ = _sh_fwd_res(x, a, b, sigma, impl, interpret, mesh, part)
    return out


def _sh_fwd(x, a, b, sigma, impl, interpret, mesh, part):
    out, z_pre = _sh_fwd_res(x, a, b, sigma, impl, interpret, mesh, part)
    return out, (x, z_pre, a, b)


def _sh_bwd(sigma, impl, interpret, mesh, part, res, g):
    from jax.experimental.shard_map import shard_map
    x, z_pre, a, b = res

    def body(xl, zpl, al, bl, gl):
        x2 = xl.reshape(-1, xl.shape[-1])
        g2 = gl.reshape(-1, gl.shape[-1]).astype(x2.dtype)
        if part.out_axes:
            # Column-parallel output (megatron qkv/gate/up): g·Bᵀ contracts
            # over the sharded d_out, so the r-dim partial must be psummed
            # before the σ′ product — XLA math, one f32 (T, r) all-reduce.
            DISPATCH["sharded_bwd_colpar_xla"] += 1
            dzl = jax.lax.dot_general(
                g2, bl.astype(g2.dtype),
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
            dzl = jax.lax.psum(dzl, part.out_axes)
            dz = (dzl * _act.act_grad(zpl, sigma)).astype(x2.dtype)
            z = _act.apply_act(zpl, sigma).astype(x2.dtype)
            dx = jnp.dot(dz, al.T.astype(dz.dtype))
            da = jnp.dot(x2.T, dz)
            db = jnp.dot(z.T, g2)
        else:
            # d_out whole per shard: the fused backward kernels apply
            # unchanged to the local (rank- or batch-) shard.
            dx, da, db = _bwd_impl(sigma, impl, interpret,
                                   (x2, zpl, al, bl), g2)
        if part.rank_axes:
            dx = jax.lax.psum(dx, part.rank_axes)  # dz·Aᵀ partials over r
        if part.batch_axes:
            # per-site slice of the data-parallel gradient all-reduce
            da = jax.lax.psum(da, part.batch_axes)
            db = jax.lax.psum(db, part.batch_axes)
        return (dx.reshape(xl.shape).astype(xl.dtype),
                da.astype(al.dtype), db.astype(bl.dtype))

    return shard_map(
        body, mesh,
        in_specs=(part.x_spec, part.zpre_spec, part.a_spec, part.b_spec,
                  part.out_spec),
        out_specs=(part.x_spec, part.a_spec, part.b_spec),
        check_rep=False)(x, z_pre, a, b, g)


_cola_ae3d_sh.defvjp(_sh_fwd, _sh_bwd)


def cola_ae_sharded(x: jax.Array, a: jax.Array, b: jax.Array, *,
                    sigma=True, env=None, in_ax: Optional[str] = None,
                    out_ax: Optional[str] = None, impl: str = "auto",
                    interpret: bool = False) -> jax.Array:
    """Tensor-parallel fused auto-encoder over a (b, s, d_in) activation.

    in_ax/out_ax are the *logical* axis names of the site's weight dims
    (cola_defs convention: a is (in_ax, 'rank'), b is ('rank', out_ax));
    the active MeshEnv's profile decides what they shard over.
    """
    from repro.distributed import sharding as _sh
    env = env or _sh.current_env()
    if env is None:
        raise ValueError("cola_ae_sharded requires an active mesh_env")
    if x.ndim != 3:
        raise ValueError(f"cola_ae_sharded expects (b, s, d) input, "
                         f"got ndim={x.ndim}")
    mode = _act.canon(sigma)
    impl, interpret = _apply_force(impl, interpret)
    part = _sh.cola_ae_partition(env, x.shape, a.shape, b.shape,
                                 in_ax, out_ax)
    DISPATCH["sharded_call"] += 1
    return _cola_ae3d_sh(x, a.astype(x.dtype), b.astype(x.dtype), mode,
                         impl, interpret, env.mesh, part)


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma=True, bias_a: Optional[jax.Array] = None,
            bias_b: Optional[jax.Array] = None, impl: str = "auto",
            interpret: bool = False) -> jax.Array:
    """Fused auto-encoder over the last dim of x (any leading dims).

    sigma: bool (legacy; True → silu) or one of act.SIGMA_MODES.
    """
    mode = _act.canon(sigma)
    impl, interpret = _apply_force(impl, interpret)
    if bias_a is not None or bias_b is not None:
        # bias sites fall back to the unfused path (rare: qwen2 qkv)
        z = jnp.einsum("...d,dr->...r", x, a.astype(x.dtype))
        if bias_a is not None:
            z = z + bias_a.astype(x.dtype)
        if mode != "none":
            z = _act.apply_act(z.astype(jnp.float32), mode).astype(x.dtype)
        h = jnp.einsum("...r,ro->...o", z, b.astype(x.dtype))
        if bias_b is not None:
            h = h + bias_b.astype(x.dtype)
        return h
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    out = _cola_ae2d(x2d, a.astype(x.dtype), b.astype(x.dtype), mode,
                     impl, interpret)
    return out.reshape(*lead, b.shape[-1])
