"""jit'd wrapper for the fused CoLA auto-encoder with custom VJP.

Forward: the Pallas kernel (or ref off-TPU).  Backward saves only
(x, z_pre) where z_pre = A·x is r-dimensional — the CoLA-M residency
recipe at kernel level; σ and both grad GEMMs are recomputed/evaluated
from those:

    dz = (g · Bᵀ) ⊙ σ'(z_pre);  dx = dz · Aᵀ;  dA = xᵀ·dz;  dB = σ(z)ᵀ·g
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.cola_ae import ref as _ref


def _fwd_compute(x2d, a, b, sigma, impl, interpret):
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        from repro.kernels.cola_ae import kernel as _k
        return _k.cola_ae_fwd(x2d, a, b, sigma=sigma, interpret=interpret)
    return _ref.cola_ae(x2d, a, b, sigma=sigma)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _cola_ae2d(x2d, a, b, sigma, impl, interpret):
    return _fwd_compute(x2d, a, b, sigma, impl, interpret)


def _bwd_impl(sigma, impl, interpret, res, g):
    x2d, z_pre, a, b = res
    zp32 = z_pre.astype(jnp.float32)
    if sigma:
        sg = jax.nn.sigmoid(zp32)
        z = (zp32 * sg).astype(x2d.dtype)
        dsig = sg * (1 + zp32 * (1 - sg))
    else:
        z = z_pre
        dsig = jnp.ones_like(zp32)
    g = g.astype(x2d.dtype)
    dzl = jnp.dot(g, b.T.astype(g.dtype)).astype(jnp.float32)  # (T, r)
    dz = (dzl * dsig).astype(x2d.dtype)
    dx = jnp.dot(dz, a.T.astype(dz.dtype))
    da = jnp.dot(x2d.T, dz).astype(a.dtype)
    db = jnp.dot(z.T, g).astype(b.dtype)
    return dx, da, db


def _fwd2(x2d, a, b, sigma, impl, interpret):
    out = _fwd_compute(x2d, a, b, sigma, impl, interpret)
    z_pre = jnp.dot(x2d, a.astype(x2d.dtype))
    return out, (x2d, z_pre, a, b)


_cola_ae2d.defvjp(_fwd2, _bwd_impl)


def cola_ae(x: jax.Array, a: jax.Array, b: jax.Array, *,
            sigma: bool = True, bias_a: Optional[jax.Array] = None,
            bias_b: Optional[jax.Array] = None, impl: str = "auto",
            interpret: bool = False) -> jax.Array:
    """Fused auto-encoder over the last dim of x (any leading dims)."""
    if bias_a is not None or bias_b is not None:
        # bias sites fall back to the unfused path (rare: qwen2 qkv)
        z = jnp.einsum("...d,dr->...r", x, a.astype(x.dtype))
        if bias_a is not None:
            z = z + bias_a.astype(x.dtype)
        if sigma:
            z32 = z.astype(jnp.float32)
            z = (z32 * jax.nn.sigmoid(z32)).astype(x.dtype)
        h = jnp.einsum("...r,ro->...o", z, b.astype(x.dtype))
        if bias_b is not None:
            h = h + bias_b.astype(x.dtype)
        return h
    lead = x.shape[:-1]
    x2d = x.reshape(-1, x.shape[-1])
    out = _cola_ae2d(x2d, a.astype(x.dtype), b.astype(x.dtype), sigma,
                     impl, interpret)
    return out.reshape(*lead, b.shape[-1])
