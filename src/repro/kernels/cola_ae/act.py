"""σ / σ′ for the CoLA auto-encoder, shared by kernel, ref and VJP.

Four modes (the kernel-level generalization of the paper's SiLU):

* ``silu`` — ``z·sigmoid(z)`` (paper default),
* ``gelu`` — exact erf form ``z/2·(1+erf(z/√2))`` (whisper MLP idiom),
* ``relu`` — ``max(z, 0)`` written as ``where(z>0, z, 0)`` so autodiff of
  the ref and the analytic derivative here agree exactly at the tie,
* ``none`` — identity (``fullrank_only`` σ-placement / pure factorization).

Everything is plain jnp/lax so the same functions run inside Pallas kernel
bodies (VPU element-wise) and in the XLA reference path.  All math is done
in float32 — callers pass the f32 pre-activation and cast afterwards.

``canon`` accepts the legacy bool flag (True → silu, False → none).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

SIGMA_MODES = ("silu", "gelu", "relu", "none")

_INV_SQRT2 = float(1.0 / np.sqrt(2.0))
_INV_SQRT2PI = float(1.0 / np.sqrt(2.0 * np.pi))


def canon(sigma) -> str:
    """Normalize a σ spec (bool or str) to one of SIGMA_MODES."""
    if isinstance(sigma, bool):
        return "silu" if sigma else "none"
    if sigma not in SIGMA_MODES:
        raise ValueError(f"unknown sigma mode '{sigma}'; known: {SIGMA_MODES}")
    return sigma


def apply_act(z, mode: str):
    """σ(z); z is expected in float32."""
    if mode == "silu":
        return z * jax.nn.sigmoid(z)
    if mode == "gelu":
        return 0.5 * z * (1.0 + jax.lax.erf(z * _INV_SQRT2))
    if mode == "relu":
        return jnp.where(z > 0, z, jnp.zeros_like(z))
    if mode == "none":
        return z
    raise ValueError(mode)


def act_grad(z, mode: str):
    """dσ/dz evaluated at z (float32)."""
    if mode == "silu":
        s = jax.nn.sigmoid(z)
        return s * (1.0 + z * (1.0 - s))
    if mode == "gelu":
        cdf = 0.5 * (1.0 + jax.lax.erf(z * _INV_SQRT2))
        pdf = _INV_SQRT2PI * jnp.exp(-0.5 * z * z)
        return cdf + z * pdf
    if mode == "relu":
        return (z > 0).astype(z.dtype)
    if mode == "none":
        return jnp.ones_like(z)
    raise ValueError(mode)


def act_pair(z, mode: str):
    """(σ(z), dσ/dz) sharing the transcendental subexpressions — the
    sigmoid (silu) / erf cdf (gelu) is evaluated once for both.  Used by
    kernel bodies and the unfused backward, which need z and dz together."""
    if mode == "silu":
        s = jax.nn.sigmoid(z)
        return z * s, s * (1.0 + z * (1.0 - s))
    if mode == "gelu":
        cdf = 0.5 * (1.0 + jax.lax.erf(z * _INV_SQRT2))
        pdf = _INV_SQRT2PI * jnp.exp(-0.5 * z * z)
        return z * cdf, cdf + z * pdf
    if mode == "relu":
        pos = z > 0
        return jnp.where(pos, z, jnp.zeros_like(z)), pos.astype(z.dtype)
    if mode == "none":
        return z, jnp.ones_like(z)
    raise ValueError(mode)
