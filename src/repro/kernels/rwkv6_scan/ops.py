"""Dispatching wrapper for the RWKV6 WKV recurrence."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.rwkv6_scan import ref as _ref


def wkv6(r, k, v, w, u, init_state=None, *, impl: str = "auto",
         interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.wkv6(r, k, v, w, u, init_state)
    from repro.kernels.rwkv6_scan import kernel as _k
    return _k.wkv6(r, k, v, w, u, init_state, interpret=interpret)
