"""Pure-jnp oracle for the RWKV6 (Finch) WKV recurrence.

Per head (k/v head size dh), with data-dependent per-channel decay w_t:

    y_t = (S_{t-1} + diag(u) · k_t v_t^T)^T r_t
    S_t = diag(w_t) · S_{t-1} + k_t v_t^T

r/k/w: (b, s, h, dh); v: (b, s, h, dh); u: (h, dh);
state S: (b, h, dh_k, dh_v).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def wkv6(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
         u: jax.Array, init_state: Optional[jax.Array] = None,
         unroll: int = 16) -> Tuple[jax.Array, jax.Array]:
    """unroll: steps fused per scan iteration — XLA keeps the (b,h,dh,dh)
    state in registers across unrolled steps instead of round-tripping it
    to HBM every token (16× memory-roofline-term win on rwkv6-7b train_4k,
    EXPERIMENTS.md §Perf iteration 2; the Pallas kernel is the full fix)."""
    b, s, h, dh = r.shape
    S0 = (jnp.zeros((b, h, dh, dh), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    u32 = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = [t.astype(jnp.float32) for t in inp]  # (b,h,dh)
        kv = kt[..., :, None] * vt[..., None, :]               # (b,h,dk,dv)
        out = jnp.einsum("bhkv,bhk->bhv", S + u32[None, :, :, None] * kv, rt)
        S = wt[..., :, None] * S + kv
        return S, out

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    while s % unroll:
        unroll //= 2
    ST, ys = jax.lax.scan(step, S0, xs, unroll=max(unroll, 1))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), ST
