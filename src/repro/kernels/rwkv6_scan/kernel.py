"""Pallas TPU kernel for the RWKV6 (Finch) WKV recurrence.

Grid (b·h, n_seq_chunks): the (dh_k × dh_v) state matrix lives in VMEM
scratch and persists across sequence chunks (TPU iterates the last grid
dim innermost), so the recurrence streams the sequence through VMEM with
one HBM pass over r/k/v/w and one write of y — the memory-optimal
schedule for an attention-free layer.  dh = 64 aligns the outer-product
updates with the VPU/MXU tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sT_ref,
                 state, *, sc: int, n_chunks: int):
    """r/k/v/w_ref: (sc, dh); u_ref: (dh,); s0_ref/sT_ref: (dh, dh);
    y_ref: (sc, dh); state scratch: (dh, dh) f32."""
    cj = pl.program_id(1)

    @pl.when(cj == 0)
    def _init():
        state[...] = s0_ref[...].astype(jnp.float32)

    u = u_ref[...].astype(jnp.float32)          # (dh,)

    def step(t, S):
        rt = r_ref[t, :].astype(jnp.float32)
        kt = k_ref[t, :].astype(jnp.float32)
        vt = v_ref[t, :].astype(jnp.float32)
        wt = w_ref[t, :].astype(jnp.float32)
        kv = kt[:, None] * vt[None, :]           # (dh_k, dh_v)
        yt = jnp.sum((S + u[:, None] * kv) * rt[:, None], axis=0)
        y_ref[t, :] = yt.astype(y_ref.dtype)
        return wt[:, None] * S + kv

    S = jax.lax.fori_loop(0, sc, step, state[...])
    state[...] = S

    @pl.when(cj == n_chunks - 1)
    def _emit():
        sT_ref[...] = S.astype(sT_ref.dtype)


def wkv6(r, k, v, w, u, init_state=None, *, seq_chunk: int = 256,
         interpret: bool = False):
    """r/k/v/w: (b, s, h, dh); u: (h, dh); state: (b, h, dh, dh) f32."""
    b, s, h, dh = r.shape
    sc = min(seq_chunk, s)
    while s % sc:
        sc //= 2
    n_chunks = s // sc
    if init_state is None:
        init_state = jnp.zeros((b, h, dh, dh), jnp.float32)

    def to_bh(x):  # (b, s, h, dh) -> (b*h, s, dh)
        return jnp.moveaxis(x, 2, 1).reshape(b * h, s, dh)

    rt, kt, vt, wt = map(to_bh, (r, k, v, w))
    ut = jnp.broadcast_to(u[None], (b, h, dh)).reshape(b * h, dh)
    s0 = init_state.reshape(b * h, dh, dh)

    grid = (b * h, n_chunks)
    y, sT = pl.pallas_call(
        functools.partial(_wkv6_kernel, sc=sc, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, sc, dh), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((None, sc, dh), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((None, sc, dh), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((None, sc, dh), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((None, dh), lambda bh, cj: (bh, 0)),
            pl.BlockSpec((None, dh, dh), lambda bh, cj: (bh, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, sc, dh), lambda bh, cj: (bh, cj, 0)),
            pl.BlockSpec((None, dh, dh), lambda bh, cj: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, s, dh), r.dtype),
            jax.ShapeDtypeStruct((b * h, dh, dh), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((dh, dh), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, ut, s0)
    y = jnp.moveaxis(y.reshape(b, h, s, dh), 1, 2)
    return y, sT.reshape(b, h, dh, dh)
