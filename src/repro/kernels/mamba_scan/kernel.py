"""Pallas TPU kernel for the Mamba selective scan.

Grid (b, n_channel_blocks, n_seq_chunks): state (bd, N) persists in VMEM
scratch across seq chunks (innermost grid dim).  Channels are independent,
so d_inner blocks parallelize the grid; per-step work is VPU element-wise
(exp/mul/add) plus an (bd × N) outer accumulate — the hardware-natural
layout for N=16 is to keep N on the lane dim.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, h0_ref,
                 y_ref, hT_ref, state, *, sc: int, n_chunks: int):
    """x/dt_ref: (sc, bd); A_ref: (bd, N); B/C_ref: (sc, N); D_ref: (bd,);
    h0/hT_ref: (bd, N); state scratch: (bd, N) f32."""
    cj = pl.program_id(2)

    @pl.when(cj == 0)
    def _init():
        state[...] = h0_ref[...].astype(jnp.float32)

    A = A_ref[...].astype(jnp.float32)
    D = D_ref[...].astype(jnp.float32)

    def step(t, h):
        xt = x_ref[t, :].astype(jnp.float32)      # (bd,)
        dtt = dt_ref[t, :].astype(jnp.float32)    # (bd,)
        Bt = B_ref[t, :].astype(jnp.float32)      # (N,)
        Ct = C_ref[t, :].astype(jnp.float32)      # (N,)
        dA = jnp.exp(dtt[:, None] * A)            # (bd, N)
        h = h * dA + (dtt * xt)[:, None] * Bt[None, :]
        yt = jnp.sum(h * Ct[None, :], axis=1) + D * xt
        y_ref[t, :] = yt.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, sc, step, state[...])
    state[...] = h

    @pl.when(cj == n_chunks - 1)
    def _emit():
        hT_ref[...] = h


def selective_scan(x, dt, A, B, C, D, init_state=None, *,
                   seq_chunk: int = 128, d_block: int = 512,
                   interpret: bool = False):
    """x/dt: (b, s, di); A: (di, N); B/C: (b, s, N); D: (di,)."""
    b, s, di = x.shape
    N = A.shape[-1]
    sc = min(seq_chunk, s)
    while s % sc:
        sc //= 2
    bd = min(d_block, di)
    while di % bd:
        bd //= 2
    n_chunks = s // sc
    if init_state is None:
        init_state = jnp.zeros((b, di, N), jnp.float32)

    grid = (b, di // bd, n_chunks)
    y, hT = pl.pallas_call(
        functools.partial(_scan_kernel, sc=sc, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, sc, bd), lambda bi, dj, cj: (bi, cj, dj)),
            pl.BlockSpec((None, sc, bd), lambda bi, dj, cj: (bi, cj, dj)),
            pl.BlockSpec((bd, N), lambda bi, dj, cj: (dj, 0)),
            pl.BlockSpec((None, sc, N), lambda bi, dj, cj: (bi, cj, 0)),
            pl.BlockSpec((None, sc, N), lambda bi, dj, cj: (bi, cj, 0)),
            pl.BlockSpec((bd,), lambda bi, dj, cj: (dj,)),
            pl.BlockSpec((None, bd, N), lambda bi, dj, cj: (bi, dj, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, sc, bd), lambda bi, dj, cj: (bi, cj, dj)),
            pl.BlockSpec((None, bd, N), lambda bi, dj, cj: (bi, dj, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, s, di), x.dtype),
            jax.ShapeDtypeStruct((b, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((bd, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, B, C, D, init_state)
    return y, hT
