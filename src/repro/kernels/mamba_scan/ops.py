"""Dispatching wrapper for the Mamba selective scan.

``impl='ref'`` (default off-TPU) uses the lax.scan oracle; ``impl='pallas'``
uses the chunked Pallas kernel (interpret mode on CPU for validation).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.kernels.mamba_scan import ref as _ref


def selective_scan(x, dt, A, B, C, D, init_state=None, *,
                   impl: str = "auto", interpret: bool = False
                   ) -> Tuple[jax.Array, jax.Array]:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "ref":
        return _ref.selective_scan(x, dt, A, B, C, D, init_state)
    from repro.kernels.mamba_scan import kernel as _k
    return _k.selective_scan(x, dt, A, B, C, D, init_state,
                             interpret=interpret)
