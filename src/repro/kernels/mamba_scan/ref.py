"""Pure-jnp oracle for the Mamba selective scan.

    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t ⊙ B_t) x_t
    y_t = C_t · h_t + D ⊙ x_t

Shapes: x/dt (b, s, di), A (di, N), B/C (b, s, N), D (di,),
state h (b, di, N).  Implemented as lax.scan over the sequence so the
(b, s, di, N) discretized tensor is never materialized.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, D: jax.Array,
                   init_state: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    b, s, di = x.shape
    N = A.shape[-1]
    h0 = (jnp.zeros((b, di, N), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))
    A32 = A.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, Bt, Ct = inp          # (b,di) (b,di) (b,N) (b,N)
        dtt = dtt.astype(jnp.float32)
        dA = jnp.exp(dtt[..., None] * A32[None])            # (b, di, N)
        dBx = (dtt * xt.astype(jnp.float32))[..., None] * Bt[:, None, :]
        h = h * dA + dBx
        yt = jnp.einsum("bdn,bn->bd", h, Ct.astype(jnp.float32))
        return h, yt

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    unroll = 8  # fuse steps: state stays in registers between tokens
    while s % unroll:
        unroll //= 2
    hT, ys = jax.lax.scan(step, h0, xs, unroll=max(unroll, 1))
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)
    y = y + (D.astype(x.dtype) * x)
    return y, hT
