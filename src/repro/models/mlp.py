"""Feed-forward layers: SwiGLU (llama-family) and GeLU (whisper), routed
through the parameterization factory so each matmul site can be a CoLA
auto-encoder.

σ-placement (paper App. E.1): with ``cola_sigma='both'`` the SwiGLU gate is
kept *on top of* the per-site low-rank σ; with ``lowrank_only`` (paper's
default ≥350M) the original gating nonlinearity is removed and the
element-wise product remains (the paper keeps "residual connections and the
element-wise product of LLaMA's MLP" unchanged, §3.2).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.cola import keep_original_sigma
from repro.distributed.sharding import shard
from repro.models import linear
from repro.models.common import silu


def swiglu_defs(cfg: ModelConfig, d_ff: int = 0, site: str = "mlp") -> Dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    return {
        "gate": linear.linear_defs(cfg, site, d, f, "embed", "ffw",
                                   originally_nonlinear=True),
        "up": linear.linear_defs(cfg, site, d, f, "embed", "ffw"),
        "down": linear.linear_defs(cfg, site, f, d, "ffw", "embed"),
    }


def swiglu_apply(cfg: ModelConfig, params: Dict, x: jax.Array,
                 d_ff: int = 0, site: str = "mlp",
                 mode: str = "train") -> jax.Array:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    g = linear.linear_apply(cfg, params["gate"], x, site, d, f,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="ffw", mode=mode)
    u = linear.linear_apply(cfg, params["up"], x, site, d, f,
                            in_ax="embed", out_ax="ffw", mode=mode)
    g = shard(g, "batch", "seq", "act_ffw")
    u = shard(u, "batch", "seq", "act_ffw")
    if cfg.parameterization != "cola" or keep_original_sigma(cfg):
        g = silu(g)
    h = g * u  # element-wise product kept unchanged (paper §3.2)
    return linear.linear_apply(cfg, params["down"], h, site, f, d,
                               in_ax="ffw", out_ax="embed", mode=mode)


def gelu_mlp_defs(cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    return {
        "fc1": linear.linear_defs(cfg, "mlp", d, f, "embed", "ffw",
                                  bias=True, originally_nonlinear=True),
        "fc2": linear.linear_defs(cfg, "mlp", f, d, "ffw", "embed",
                                  bias=True),
    }


def gelu_mlp_apply(cfg: ModelConfig, params: Dict, x: jax.Array,
                   d_ff: int = 0, mode: str = "train") -> jax.Array:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    h = linear.linear_apply(cfg, params["fc1"], x, "mlp", d, f,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="ffw", mode=mode)
    h = shard(h, "batch", "seq", "act_ffw")
    if cfg.parameterization != "cola" or keep_original_sigma(cfg):
        h = jax.nn.gelu(h)
    return linear.linear_apply(cfg, params["fc2"], h, "mlp", f, d,
                               in_ax="ffw", out_ax="embed", mode=mode)
