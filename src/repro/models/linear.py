"""Parameterization factory: every linear *site* in every model is realized
as one of

* ``dense``   — full-rank baseline ``h = Wx``,
* ``cola``    — the paper: ``h = B·σ(A·x)`` (core/cola.py),
* ``lora``    — ReLoRA baseline: ``h = W0·x + (α/r)·B·A·x`` (W0 frozen),
* ``sltrain`` — SLTrain baseline: ``h = (BA ⊕_I V)·x`` (low-rank + sparse).

A site declares its semantic dims/axes once; the config's
``parameterization`` field decides the realization, so dense/CoLA/baseline
comparisons are config flips, not code forks.

Low-rank-site fallback: when ``min(d_in, d_out) <= 2r`` the site is kept
dense regardless (a bottleneck can't compress an already-narrow projection —
relevant for MLA latent factors and Mamba's dt/x projections).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import cola as cola_mod
from repro.models.common import ParamDef

# Sites: 'attn' | 'mlp' | 'expert' | 'small' (never factorized)


def _rank_for(cfg: ModelConfig, site: str) -> int:
    return cfg.rank_attn if site == "attn" else cfg.rank_mlp


def site_parameterization(cfg: ModelConfig, site: str,
                          d_in: int, d_out: int) -> str:
    if site == "small":
        return "dense"
    p = cfg.parameterization
    if p in ("cola", "lora", "sltrain"):
        r = _rank_for(cfg, site)
        if min(d_in, d_out) <= 2 * r and p == "cola":
            return "dense"  # bottleneck would not compress; keep dense
    return p


def linear_defs(cfg: ModelConfig, site: str, d_in: int, d_out: int,
                in_ax: Optional[str], out_ax: Optional[str],
                bias: bool = False,
                originally_nonlinear: bool = False) -> Dict[str, ParamDef]:
    p = site_parameterization(cfg, site, d_in, d_out)
    if p == "dense":
        defs = {"w": ParamDef((d_in, d_out), (in_ax, out_ax), init="fan_in")}
        if bias:
            defs["bias"] = ParamDef((d_out,), (out_ax,), init="zeros")
        return defs
    if p == "cola":
        r = _rank_for(cfg, site)
        return cola_mod.cola_defs(d_in, d_out, r, in_ax, out_ax, bias=bias)
    if p == "lora":
        r = cfg.lora.rank
        defs = {
            "w0": ParamDef((d_in, d_out), (in_ax, out_ax), init="fan_in"),
            "lora_a": ParamDef((d_in, r), (in_ax, "rank"), init="fan_in"),
            "lora_b": ParamDef((r, d_out), ("rank", out_ax), init="zeros"),
        }
        if bias:
            defs["bias"] = ParamDef((d_out,), (out_ax,), init="zeros")
        return defs
    if p == "sltrain":
        r = cfg.sltrain.rank
        nnz = max(1, int(cfg.sltrain.sparsity * d_in * d_out))
        defs = {
            "sl_a": ParamDef((d_in, r), (in_ax, "rank"), init="fan_in"),
            "sl_b": ParamDef((r, d_out), ("rank", out_ax), init="fan_in"),
            # sparse values; fixed random indices are derived from shapes
            # (deterministic, not trained) — stored flat (nnz,)
            "sl_v": ParamDef((nnz,), (None,), init="normal", scale=0.01),
        }
        if bias:
            defs["bias"] = ParamDef((d_out,), (out_ax,), init="zeros")
        return defs
    raise ValueError(p)


def _sltrain_indices(d_in: int, d_out: int, nnz: int) -> np.ndarray:
    """Deterministic pseudo-random support for S (host-side, hashable)."""
    rng = np.random.RandomState((d_in * 2654435761 + d_out) % (2**31))
    flat = rng.choice(d_in * d_out, size=nnz, replace=False)
    return flat.astype(np.int32)


def linear_apply(cfg: ModelConfig, params: Dict, x: jax.Array, site: str,
                 d_in: int, d_out: int,
                 originally_nonlinear: bool = False,
                 in_ax: Optional[str] = None,
                 out_ax: Optional[str] = None,
                 mode: str = "train") -> jax.Array:
    """Apply a linear site; dispatches on which params exist.

    in_ax/out_ax mirror the logical weight axes the site declared in
    ``linear_defs``; CoLA sites forward them so the fused path can resolve
    its tensor-parallel partitioning (core/cola.py → ops.cola_ae_sharded).
    Bias-carrying CoLA sites (cola_defs bias=True: bias_a pre-σ, bias_b on
    the output) stay fused on every plan.  Call sites that don't thread
    their axes keep the unfused path under a 'model' mesh (counted as
    ``apply_fused_fallback`` — every bundled config threads them).

    mode: 'train' (default) or 'infer' — threaded from the model facade's
    prefill/decode paths down to the CoLA ops planner, where 'infer'
    bypasses the custom VJP (no residuals) and dispatches the GEMV-shaped
    decode kernel below the T threshold (kernels/cola_ae/ops.py).  Dense /
    LoRA / SLTrain sites ignore it.
    """
    dt = x.dtype
    if "w" in params:  # dense
        h = jnp.einsum("...d,do->...o", x, params["w"].astype(dt))
        if "bias" in params:
            h = h + params["bias"].astype(dt)
        return h
    if "a" in params:  # cola
        sigma = cola_mod.sigma_between(cfg, originally_nonlinear)
        weight_axes = ((in_ax, out_ax)
                       if in_ax is not None or out_ax is not None else None)
        return cola_mod.cola_apply(
            params, x, sigma=sigma,
            use_fused=cfg.cola.use_fused_kernel,
            weight_axes=weight_axes, mode=mode)
    if "w0" in params:  # lora — W0 frozen (stop_gradient), per paper Fig. 3a
        w0 = jax.lax.stop_gradient(params["w0"]).astype(dt)
        h = jnp.einsum("...d,do->...o", x, w0)
        scale = cfg.lora.alpha / cfg.lora.rank
        z = jnp.einsum("...d,dr->...r", x, params["lora_a"].astype(dt))
        h = h + scale * jnp.einsum("...r,ro->...o", z,
                                   params["lora_b"].astype(dt))
        if "bias" in params:
            h = h + params["bias"].astype(dt)
        return h
    if "sl_a" in params:  # sltrain: W = BA ⊕ S, reconstructed per step
        w = jnp.einsum("dr,ro->do", params["sl_a"].astype(dt),
                       params["sl_b"].astype(dt))
        nnz = params["sl_v"].shape[0]
        idx = _sltrain_indices(d_in, d_out, nnz)
        w = w.reshape(-1).at[idx].add(params["sl_v"].astype(dt)).reshape(
            d_in, d_out)
        return jnp.einsum("...d,do->...o", x, w)
    raise ValueError(f"unrecognized linear params: {list(params)}")


def trainable_mask(cfg: ModelConfig, params) -> "jax.tree":
    """True for trainable leaves (LoRA freezes w0). Used by the optimizer."""
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree.structure(params)
    out = []
    for path, _ in flat:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        out.append(not (cfg.parameterization == "lora" and "w0" in keys))
    return jax.tree.unflatten(treedef, out)
