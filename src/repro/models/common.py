"""Shared model substrate: parameter definitions, norms, rotary embeddings.

Parameters are declared as trees of :class:`ParamDef` (shape + logical axes +
init recipe).  From one declaration we derive:

* ``init_params``   — materialized arrays (per-path folded rng),
* ``abstract_params`` — ShapeDtypeStructs (dry-run: no allocation),
* ``axes_tree``     — the parallel tree of logical-axes tuples used by the
  sharding rules engine (``distributed/sharding.py``).
"""
from __future__ import annotations

import dataclasses
import zlib
from dataclasses import dataclass
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig


# --------------------------------------------------------------------------
# Parameter declaration
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "fan_in"  # fan_in | normal | zeros | ones | constant
    scale: float = 1.0
    dtype: Optional[str] = None  # None => cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def stack_defs(tree, n: int):
    """Prepend a ('layers', n) scan axis to every ParamDef in the tree."""
    return jax.tree.map(
        lambda d: dataclasses.replace(
            d, shape=(n,) + d.shape, axes=("layers",) + d.axes),
        tree, is_leaf=is_def)


def _materialize(d: ParamDef, key, param_dtype) -> jax.Array:
    dt = jnp.dtype(d.dtype or param_dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "constant":
        return jnp.full(d.shape, d.scale, dt)
    if d.init == "normal":
        return (d.scale * jax.random.normal(key, d.shape)).astype(dt)
    if d.init == "fan_in":
        # truncated-normal-ish scaled by 1/sqrt(fan_in); fan_in is the
        # second-to-last dim for matrices (our convention: W is (in, out)),
        # last dim for vectors.
        fan = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        std = d.scale / np.sqrt(max(fan, 1))
        return (std * jax.random.normal(key, d.shape)).astype(dt)
    raise ValueError(f"unknown init '{d.init}'")


def path_fold(path_str: str) -> int:
    """Stable per-path fold value: CRC32 of the path bytes.  Python's
    ``hash()`` is salted by PYTHONHASHSEED, so two processes would build
    *different* params from the same seed — CRC32 is process-independent,
    which multi-host init and checkpoint parity both require."""
    return zlib.crc32(path_str.encode("utf-8")) & 0x7FFFFFFF


def init_params(defs_tree, rng: jax.Array, param_dtype: str = "float32"):
    """Materialize a ParamDef tree with per-path independent keys."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        defs_tree, is_leaf=is_def)[0]
    treedef = jax.tree.structure(defs_tree, is_leaf=is_def)
    arrays = []
    for path, d in leaves_with_paths:
        key = jax.random.fold_in(
            rng, path_fold(jax.tree_util.keystr(path)))
        arrays.append(_materialize(d, key, param_dtype))
    return jax.tree.unflatten(treedef, arrays)


def abstract_params(defs_tree, param_dtype: str = "float32"):
    """ShapeDtypeStruct tree — used by the dry-run (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype or param_dtype)),
        defs_tree, is_leaf=is_def)


def axes_tree(defs_tree):
    return jax.tree.map(lambda d: d.axes, defs_tree, is_leaf=is_def)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rmsnorm_defs(d: int) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), ("embed",), init="ones")}


def rmsnorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def layernorm_defs(d: int) -> Dict[str, ParamDef]:
    return {"scale": ParamDef((d,), ("embed",), init="ones"),
            "bias": ParamDef((d,), ("embed",), init="zeros")}


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dt)


def groupnorm_heads(x, scale, bias, eps: float = 64e-5):
    """Per-head groupnorm (rwkv6 ln_x). x: (..., h, dh)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(dt)


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE + M-RoPE)
# --------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float64)
                            / head_dim))


def rope_cos_sin(positions: jax.Array, head_dim: int, theta: float):
    """positions: (b, s) int -> cos/sin of shape (b, s, head_dim//2)."""
    inv = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (b, s, hd/2)
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions: jax.Array, head_dim: int, theta: float,
                  sections: Tuple[int, int, int]):
    """M-RoPE (qwen2-vl): positions (3, b, s); sections sum to head_dim//2.

    Section i of the frequency axis uses the i-th position stream
    (temporal / height / width).
    """
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (3, b, s, hd/2)
    sel = np.concatenate([np.full((sec,), i) for i, sec in enumerate(sections)])
    sel = jnp.asarray(sel, jnp.int32)  # (hd/2,)
    ang = jnp.take_along_axis(
        ang, sel[None, None, :, None].transpose(0, 1, 3, 2), axis=0)[0]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (b, s, h, hd); cos/sin: (b, s, hd/2). Half-rotation convention."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    half = x.shape[-1] // 2
    x1, x2 = x32[..., :half], x32[..., half:]
    c = cos[:, :, None, :]
    s = sin[:, :, None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s],
                           axis=-1).astype(dt)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style sinusoidal position table (n, d)."""
    log_timescale = np.log(10000.0) / (d // 2 - 1)
    inv = np.exp(-log_timescale * np.arange(d // 2))
    scaled = np.arange(n)[:, None] * inv[None, :]
    return np.concatenate([np.sin(scaled), np.cos(scaled)], axis=1)


# --------------------------------------------------------------------------
# Embedding / unembedding
# --------------------------------------------------------------------------
def embedding_defs(cfg: ModelConfig) -> Dict[str, ParamDef]:
    return {"table": ParamDef((cfg.padded_vocab, cfg.d_model),
                              ("vocab", "embed"), init="normal", scale=0.02)}


def embed(params, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(params["table"].astype(dtype), tokens, axis=0)


def unembed(params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Return logits over the padded vocab with pad ids masked to -inf."""
    table = params["table"].astype(x.dtype)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    if cfg.padded_vocab != cfg.vocab_size:
        mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
        logits = jnp.where(mask[None, None, :], jnp.finfo(logits.dtype).min,
                           logits)
    return logits


def silu(x):
    return x * jax.nn.sigmoid(x)
