"""Attention: GQA (llama/qwen/internlm/phi/jamba/whisper) and MLA (minicpm3),
with KV caches for decode and sequence-sharded ("flash-decode") semantics for
long contexts.

Decode attention is written so the XLA SPMD partitioner derives the
flash-decode pattern automatically when the KV cache's sequence dim carries a
'kv_seq' (→ 'model') sharding: the softmax max/sum reductions over the
sharded axis become all-reduces of (b, h) scalars per token — i.e. the
partial-softmax + logsumexp-combine schedule, without hand-written
shard_map.  An explicit shard_map variant lives in serve/engine.py for the
perf comparison.

Mixed-phase mask contract (what chunked prefill leans on): query
positions are per-token and may start anywhere — visibility is
``arange(kv_len) <= q_position``, so a prompt slice re-entered at its
true cache positions sees exactly the rows earlier slices wrote and
nothing newer, and K/V written at position p depends only on the token at
p.  Negative positions are the inert encoding: a position-(-1) query is
fully masked (it attends to nothing real) and its K/V write parks in the
sacrificial slot — dense caches' reserved ``max_seq - 1`` column, paged
caches' page-0 rows via ``page_map[b, -1]``.  The serve engine's mixed
dispatches run every non-participating batch row at position -1, which is
why one fused dispatch can hold prefilling and decoding tenants without
any attention-level branching.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import shard
from repro.models import linear
from repro.models.common import ParamDef, apply_rope, rmsnorm, rmsnorm_defs


class KVCache(NamedTuple):
    """Contiguous KV cache for one attention layer.

    k/v: (batch, max_seq, kv_heads, head_dim); for MLA, k holds the latent
    (batch, max_seq, kv_lora_rank) and v holds the rope-key
    (batch, max_seq, qk_rope_head_dim).
    """
    k: jax.Array
    v: jax.Array


# --------------------------------------------------------------------------
# GQA
# --------------------------------------------------------------------------
def gqa_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    bias = cfg.qkv_bias
    return {
        "q": linear.linear_defs(cfg, "attn", d, h * hd, "embed", "heads", bias=bias),
        "k": linear.linear_defs(cfg, "attn", d, kv * hd, "embed", "kv_heads", bias=bias),
        "v": linear.linear_defs(cfg, "attn", d, kv * hd, "embed", "kv_heads", bias=bias),
        "o": linear.linear_defs(cfg, "attn", h * hd, d, "heads", "embed"),
    }


def gqa_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> KVCache:
    hd = cfg.resolved_head_dim
    shape = (batch, max_seq, cfg.num_kv_heads, hd)
    axes = ("batch", "kv_seq", "kv_heads", "head_dim")
    return KVCache(k=ParamDef(shape, axes, init="zeros", dtype="bfloat16"),
                   v=ParamDef(shape, axes, init="zeros", dtype="bfloat16"))


_Q_CHUNK = 512
_KV_CHUNK = 1024
_NEG = -1e30


def _blocked_sdpa(q, k, v, *, causal: bool,
                  q_positions: Optional[jax.Array],
                  q_chunk: int = _Q_CHUNK, kv_chunk: int = _KV_CHUNK):
    """Flash-style double-blocked attention in pure XLA (lax.map over query
    chunks, lax.scan over KV chunks with running (m, l, acc)).  Keeps the
    score tensor O(q_chunk × kv_chunk) so 32k prefill / 4k train cells fit
    HBM; the Pallas kernel (kernels/flash_attn) replaces this on TPU."""
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    hv = v.shape[-1]
    kv_valid = skv
    if skv % kv_chunk:  # ragged KV (e.g. cross-attention): pad + mask
        pad = kv_chunk - skv % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        skv = k.shape[1]
    nq, nk = sq // q_chunk, skv // kv_chunk
    qg = q.reshape(b, sq, kvh, g, hd)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    masked = causal or q_positions is not None or kv_valid != skv

    def q_block(qi):
        qc = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        if q_positions is not None:
            qpos = jax.lax.dynamic_slice_in_dim(
                q_positions, qi * q_chunk, q_chunk, axis=1)  # (b, qc)
        elif causal:
            qpos = jnp.broadcast_to(
                qi * q_chunk + jnp.arange(q_chunk)[None], (b, q_chunk))
        else:  # only padding mask
            qpos = jnp.full((b, q_chunk), kv_valid - 1)

        # flash-style backward: recompute chunk scores instead of saving
        # the (nk, …, q_chunk, kv_chunk) residual stack (checkpointed body).
        @jax.checkpoint
        def kv_step(carry, kj):
            m, l, acc = carry
            kc = jax.lax.dynamic_slice_in_dim(k, kj * kv_chunk, kv_chunk, 1)
            vc = jax.lax.dynamic_slice_in_dim(v, kj * kv_chunk, kv_chunk, 1)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qc, kc).astype(jnp.float32)
            s = s * scale
            if masked:
                kpos = kj * kv_chunk + jnp.arange(kv_chunk)
                ok = ((kpos[None, None, :] <= qpos[:, :, None]) &
                      (kpos[None, None, :] < kv_valid))  # (b, qc, kvc)
                s = jnp.where(ok[:, None, None, :, :], s, _NEG)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            e = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(e, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", e, vc.astype(jnp.float32))
            return (m_new, l, acc), None

        init = (jnp.full((b, kvh, g, q_chunk), _NEG, jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
                jnp.zeros((b, kvh, g, q_chunk, hv), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype)  # (b, kvh, g, qc, hv)

    outs = jax.lax.map(q_block, jnp.arange(nq))  # (nq, b, kvh, g, qc, hv)
    outs = jnp.moveaxis(outs, 0, 3)              # (b, kvh, g, nq, qc, hv)
    outs = outs.reshape(b, kvh, g, sq, hv)
    return jnp.moveaxis(outs, 3, 1).reshape(b, sq, h, hv)


def _sharded_flash(q, k, v, *, causal: bool,
                   q_positions: Optional[jax.Array]):
    """Head-parallel flash attention via shard_map.

    Without this, the SPMD partitioner inserts per-KV-chunk all-gathers
    inside the flash scan (measured: ~2e12 B/step on llama3.2-1b train_4k,
    the dominant roofline term — EXPERIMENTS.md §Perf iteration 1).  Inside
    shard_map every chunk is local: q is sharded over 'model' on heads,
    k/v are replicated (GQA KV heads < mesh axis), and each rank statically
    slices the one KV head its query-head block needs.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import current_env
    from repro.kernels.flash_attn import ops as fops

    env = current_env()
    b, sq, h, hd = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    group = h // kvh
    ms = env.mesh.shape.get("model", 1) if env else 1
    # pad heads to a multiple of the mesh axis (≤50% waste allowed —
    # qwen2's 12→16, llama4's 40→48; whisper's 6→16 falls back)
    h_pad = ((h + ms - 1) // ms) * ms if ms > 1 else h
    can_shard = (env is not None and ms > 1 and h_pad <= 1.5 * h)
    if q_positions is None:
        if causal:
            q_positions = jnp.broadcast_to(jnp.arange(sq)[None], (b, sq))
        else:
            q_positions = jnp.full((b, sq), skv - 1, jnp.int32)
    if not can_shard:
        return fops.flash_attention(q, k, v, causal=causal,
                                    q_positions=q_positions)
    mesh = env.mesh
    h_local = h_pad // ms
    if h_pad != h:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, h_pad - h), (0, 0)))
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                       and b % mesh.shape[a] == 0)
    bspec = batch_axes if batch_axes else None

    def body(ql, kl, vl, qpl):
        rank = jax.lax.axis_index("model")
        # gather this rank's KV head per local query head (general GQA
        # mapping — ranks may straddle KV-group boundaries)
        gids = rank * h_local + jnp.arange(h_local)
        kv_ids = jnp.minimum(gids, h - 1) // group
        ksel = jnp.take(kl, kv_ids, axis=2)
        vsel = jnp.take(vl, kv_ids, axis=2)
        return fops.flash_attention(ql, ksel, vsel, causal=causal,
                                    q_positions=qpl)

    out = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None, "model", None),
                  P(bspec, None, None, None),
                  P(bspec, None, None, None),
                  P(bspec, None)),
        out_specs=P(bspec, None, "model", None),
        check_rep=False,
    )(q, k, v, q_positions.astype(jnp.int32))
    return out[:, :, :h] if h_pad != h else out


def _sdpa(q, k, v, *, causal: bool, q_positions: Optional[jax.Array] = None,
          use_flash: bool = False):
    """q: (b, sq, h, hd); k/v: (b, skv, kv, hd). GQA grouping via reshape.

    q_positions (b, sq): absolute positions of the queries within the KV
    axis — used for cached decode / incremental prefill, where query i may
    attend to cache slots <= q_positions[b, i].  When None and causal, the
    standard lower-triangular mask applies (sq == skv).
    """
    b, sq, h, hd = q.shape
    skv, kv = k.shape[1], k.shape[2]
    group = h // kv
    if sq >= 1024:
        # flash attention (custom_vjp: O(chunk) memory fwd AND bwd),
        # head-parallel under a mesh
        return _sharded_flash(q, k, v, causal=causal,
                              q_positions=q_positions)
    qg = q.reshape(b, sq, kv, group, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    if q_positions is not None:
        # (b, sq, skv): slot s visible to query q iff s <= pos[b, q]
        ok = jnp.arange(skv)[None, None, :] <= q_positions[:, :, None]
        scores = jnp.where(ok[:, None, None, :, :], scores, neg)
    elif causal:
        mask = jnp.arange(skv)[None, :] > jnp.arange(sq)[:, None]
        scores = jnp.where(mask[None, None, None], neg, scores)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(b, sq, h, v.shape[-1])  # v head dim may differ (MLA)


def gqa_apply(cfg: ModelConfig, params: Dict, x: jax.Array, *,
              cos_sin: Optional[Tuple[jax.Array, jax.Array]],
              cache: Optional[KVCache] = None,
              positions: Optional[jax.Array] = None,
              causal: bool = True,
              kv_from: Optional[jax.Array] = None,
              cross_cache: Optional[KVCache] = None,
              mode: str = "train",
              page_map: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """GQA forward.

    cache None   => full (training/prefill-from-scratch) attention.
    cache given  => tokens are written at `positions` and attention runs
                    over the cache (decode / incremental prefill).
    kv_from      => cross-attention source (encoder states); with
                    cross_cache, K/V are precomputed and the projections
                    are skipped.
    mode         => 'train' | 'infer', threaded to every linear site
                    (prefill/decode pass 'infer': no CoLA residuals, and
                    the decode-shaped kernel below the T threshold).
    page_map     => paged-KV serving: an (B, max_seq) int32 logical→
                    physical row map.  The cache leaves are then a flat
                    physical-row *pool* (R, kv, hd) shared across slots;
                    K/V write through the map and the logical (B, max_seq)
                    view is gathered back out for attention.  Positions a
                    slot does not own map to the sacrificial row 0 —
                    always hidden by the visibility mask, exactly like the
                    dense layout's pad-parking slot.

    Left-padded ragged prefill (serve engine): pad queries carry negative
    ``positions``; their K/V writes are redirected to the sacrificial last
    cache slot (dense) or row 0 (paged) and the ``slot <= q_position``
    visibility mask hides both the pad slots and any stale tenant of a
    recycled cache row.
    """
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    h, kv = cfg.num_heads, cfg.num_kv_heads
    b, s, _ = x.shape
    dt = x.dtype

    q = linear.linear_apply(cfg, params["q"], x, "attn", d, h * hd,
                            in_ax="embed", out_ax="heads", mode=mode)
    q = q.reshape(b, s, h, hd)
    if cross_cache is not None:
        k, v = cross_cache.k.astype(dt), cross_cache.v.astype(dt)
        new_cache = None
    else:
        src = x if kv_from is None else kv_from
        sk = src.shape[1]
        k = linear.linear_apply(cfg, params["k"], src, "attn", d, kv * hd,
                                in_ax="embed", out_ax="kv_heads", mode=mode)
        v = linear.linear_apply(cfg, params["v"], src, "attn", d, kv * hd,
                                in_ax="embed", out_ax="kv_heads", mode=mode)
        k = k.reshape(b, sk, kv, hd)
        v = v.reshape(b, sk, kv, hd)
        new_cache = None
    if cos_sin is not None:
        cos, sin = cos_sin
        q = apply_rope(q, cos, sin)
        if cross_cache is None:
            k = apply_rope(k, cos, sin)

    q = shard(q, "batch", "seq", "act_heads", "head_dim")

    q_positions = None
    if cache is not None and cross_cache is None:
        # write new k/v at positions, then attend over the whole cache
        k = k.astype(cache.k.dtype)
        v = v.astype(cache.v.dtype)
        bidx = jnp.arange(b)[:, None]
        if page_map is not None:
            # paged pool: leaves are (R, kv, hd) physical rows shared
            # across slots.  Write through the page table; pad queries and
            # unowned positions land on the sacrificial row 0.
            sidx = jnp.where(positions < 0, page_map.shape[1] - 1,
                             positions)
            phys = page_map[bidx, sidx]                    # (b, s) rows
            ck = cache.k.at[phys].set(k)
            cv = cache.v.at[phys].set(v)
            ck = shard(ck, "null", "kv_heads", "head_dim")
            cv = shard(cv, "null", "kv_heads", "head_dim")
            new_cache = KVCache(ck, cv)
            # gather the logical (b, max_seq) view; masked entries read
            # the sacrificial row, hidden below by the visibility mask
            k, v = ck[page_map].astype(dt), cv[page_map].astype(dt)
        else:
            # left-padded prefill: pad tokens carry negative positions —
            # park their K/V in the sacrificial last slot (the serve
            # engine reserves it) instead of letting negative indices wrap
            # into live slots
            sidx = jnp.where(positions < 0, cache.k.shape[1] - 1,
                             positions)
            ck = cache.k.at[bidx, sidx].set(k)
            cv = cache.v.at[bidx, sidx].set(v)
            ck = shard(ck, "batch", "kv_seq", "kv_heads", "head_dim")
            cv = shard(cv, "batch", "kv_seq", "kv_heads", "head_dim")
            new_cache = KVCache(ck, cv)
            k, v = ck.astype(dt), cv.astype(dt)
        q_positions = positions  # per-query causal visibility over the cache
    out = _sdpa(q, k, v, causal=causal, q_positions=q_positions)
    out = out.reshape(b, s, h * hd)
    out = linear.linear_apply(cfg, params["o"], out, "attn", h * hd, d,
                              in_ax="heads", out_ax="embed", mode=mode)
    return out, new_cache


# --------------------------------------------------------------------------
# MLA (multi-head latent attention — minicpm3/deepseek style)
# --------------------------------------------------------------------------
def mla_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    m = cfg.mla
    h = cfg.num_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        # q: d -> q_lora -> h*(nope+rope)
        "dq": linear.linear_defs(cfg, "small", d, m.q_lora_rank, "embed", "rank"),
        "q_norm": rmsnorm_defs(m.q_lora_rank),
        "uq": linear.linear_defs(cfg, "attn", m.q_lora_rank, h * qd, "rank", "heads"),
        # kv: d -> (kv_lora + rope_dim); latent -> h*(nope + v)
        "dkv": linear.linear_defs(cfg, "small", d,
                                  m.kv_lora_rank + m.qk_rope_head_dim,
                                  "embed", "rank"),
        "kv_norm": rmsnorm_defs(m.kv_lora_rank),
        "ukv": linear.linear_defs(cfg, "attn", m.kv_lora_rank,
                                  h * (m.qk_nope_head_dim + m.v_head_dim),
                                  "rank", "heads"),
        "o": linear.linear_defs(cfg, "attn", h * m.v_head_dim, d,
                                "heads", "embed"),
    }


def mla_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> KVCache:
    m = cfg.mla
    return KVCache(
        k=ParamDef((batch, max_seq, m.kv_lora_rank),
                   ("batch", "kv_seq", "rank"), init="zeros", dtype="bfloat16"),
        v=ParamDef((batch, max_seq, m.qk_rope_head_dim),
                   ("batch", "kv_seq", "head_dim"), init="zeros",
                   dtype="bfloat16"),
    )


def _mla_project_q(cfg, params, x, mode="train"):
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    cq = linear.linear_apply(cfg, params["dq"], x, "small", cfg.d_model,
                             m.q_lora_rank, mode=mode)
    cq = rmsnorm(params["q_norm"], cq, cfg.norm_eps)
    q = linear.linear_apply(cfg, params["uq"], cq, "attn", m.q_lora_rank,
                            h * qd, in_ax="rank",
                            out_ax="heads", mode=mode).reshape(b, s, h, qd)
    return q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]


def _mla_latent(cfg, params, x, mode="train"):
    m = cfg.mla
    ckv = linear.linear_apply(cfg, params["dkv"], x, "small", cfg.d_model,
                              m.kv_lora_rank + m.qk_rope_head_dim,
                              mode=mode)
    latent = rmsnorm(params["kv_norm"], ckv[..., :m.kv_lora_rank],
                     cfg.norm_eps)
    k_rope = ckv[..., m.kv_lora_rank:]  # (b, s, rope_dim), shared by heads
    return latent, k_rope


def mla_apply(cfg: ModelConfig, params: Dict, x: jax.Array, *,
              cos_sin, cache: Optional[KVCache] = None,
              positions: Optional[jax.Array] = None,
              mode: str = "train",
              page_map: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """MLA forward; decode uses the absorbed form over the latent cache.
    ``page_map``: paged-KV serving, same contract as ``gqa_apply`` — the
    latent/k_rope caches become flat physical-row pools."""
    m, h = cfg.mla, cfg.num_heads
    b, s, _ = x.shape
    dt = x.dtype
    cos, sin = cos_sin
    q_nope, q_rope = _mla_project_q(cfg, params, x, mode)
    q_rope = apply_rope(q_rope, cos, sin)
    latent, k_rope = _mla_latent(cfg, params, x, mode)
    k_rope = apply_rope(k_rope[:, :, None, :], cos, sin)  # (b,s,1,rope)

    ukv = params["ukv"]
    if cache is None:
        # train/prefill: expand latent to per-head k_nope, v
        kvd = m.qk_nope_head_dim + m.v_head_dim
        kv = linear.linear_apply(cfg, ukv, latent, "attn", m.kv_lora_rank,
                                 h * kvd, in_ax="rank", out_ax="heads",
                                 mode=mode).reshape(b, s, h, kvd)
        k_nope, v = kv[..., :m.qk_nope_head_dim], kv[..., m.qk_nope_head_dim:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))],
            axis=-1)
        q = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa(q, k, v, causal=True)
        out = out.reshape(b, s, h * m.v_head_dim)
        out = linear.linear_apply(cfg, params["o"], out, "attn",
                                  h * m.v_head_dim, cfg.d_model,
                                  in_ax="heads", out_ax="embed", mode=mode)
        return out, None

    # ---- cached paths -----------------------------------------------------
    bidx = jnp.arange(b)[:, None]
    if page_map is not None:
        # paged pool: leaves are (R, r_kv) / (R, rope) physical rows; pad
        # queries and unowned positions land on the sacrificial row 0
        sidx = jnp.where(positions < 0, page_map.shape[1] - 1, positions)
        phys = page_map[bidx, sidx]
        ck = cache.k.at[phys].set(latent.astype(cache.k.dtype))
        cv = cache.v.at[phys].set(k_rope[:, :, 0, :].astype(cache.v.dtype))
        ck = shard(ck, "null", "rank")
        cv = shard(cv, "null", "head_dim")
        new_cache = KVCache(ck, cv)
        latent_c = ck[page_map].astype(dt)   # (b, S, r_kv)
        krope_c = cv[page_map].astype(dt)    # (b, S, rope)
    else:
        # pad queries (negative positions) park in the sacrificial last slot
        sidx = jnp.where(positions < 0, cache.k.shape[1] - 1, positions)
        ck = cache.k.at[bidx, sidx].set(latent.astype(cache.k.dtype))
        cv = cache.v.at[bidx, sidx].set(
            k_rope[:, :, 0, :].astype(cache.v.dtype))
        ck = shard(ck, "batch", "kv_seq", "rank")
        cv = shard(cv, "batch", "kv_seq", "head_dim")
        new_cache = KVCache(ck, cv)
        latent_c = ck.astype(dt)            # (b, S, r_kv)
        krope_c = cv.astype(dt)             # (b, S, rope)

    if s > 1 or "a" in ukv:
        # Expand path: (a) prefill — the absorbed form would materialize
        # (b, h, s, S) scores; (b) CoLA-parameterized W_ukv — the σ between
        # the factors breaks MLA's absorption identity (DESIGN.md §4), so
        # decode recomputes k/v from the latent cache exactly.
        S = latent_c.shape[1]
        kvd = m.qk_nope_head_dim + m.v_head_dim
        kv_all = linear.linear_apply(cfg, ukv, latent_c, "attn",
                                     m.kv_lora_rank, h * kvd, mode=mode)
        kv_all = kv_all.reshape(b, S, h, kvd)
        k_nope_c = kv_all[..., :m.qk_nope_head_dim]
        v_c = kv_all[..., m.qk_nope_head_dim:]
        k_full = jnp.concatenate(
            [k_nope_c,
             jnp.broadcast_to(krope_c[:, :, None, :],
                              (b, S, h, m.qk_rope_head_dim))], axis=-1)
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _sdpa(q_full, k_full, v_c, causal=False,
                    q_positions=positions)
        out = out.reshape(b, s, h * m.v_head_dim)
        out = linear.linear_apply(cfg, params["o"], out, "attn",
                                  h * m.v_head_dim, cfg.d_model,
                                  in_ax="heads", out_ax="embed", mode=mode)
        return out, new_cache

    # ---- decode: absorbed MLA over the latent cache -----------------------

    # absorb W_uk into q: q_lat = q_nope @ W_uk  (per head)
    w = _ukv_weight(cfg, ukv, dt)       # (r_kv, h, nope+v)
    w_uk = w[..., :m.qk_nope_head_dim]  # (r_kv, h, nope)
    w_uv = w[..., m.qk_nope_head_dim:]  # (r_kv, h, v)
    q_lat = jnp.einsum("bshn,rhn->bshr", q_nope, w_uk)
    scores = (jnp.einsum("bshr,bSr->bhsS", q_lat, latent_c) +
              jnp.einsum("bshn,bSn->bhsS", q_rope, krope_c))
    scores = scores.astype(jnp.float32) / jnp.sqrt(
        m.qk_nope_head_dim + m.qk_rope_head_dim).astype(jnp.float32)
    S = latent_c.shape[1]
    # per-query causal visibility over cache slots
    valid = jnp.arange(S)[None, None, :] <= positions[:, :, None]  # (b,s,S)
    scores = jnp.where(valid[:, None, :, :], scores,
                       jnp.finfo(jnp.float32).min)
    wts = jax.nn.softmax(scores, axis=-1).astype(dt)
    lat_out = jnp.einsum("bhsS,bSr->bshr", wts, latent_c)
    out = jnp.einsum("bshr,rhv->bshv", lat_out, w_uv)
    out = out.reshape(b, s, h * m.v_head_dim)
    out = linear.linear_apply(cfg, params["o"], out, "attn",
                              h * m.v_head_dim, cfg.d_model,
                              in_ax="heads", out_ax="embed", mode=mode)
    return out, new_cache


def _ukv_weight(cfg: ModelConfig, ukv_params: Dict, dt) -> jax.Array:
    """Materialize W_ukv as (r_kv, h, nope+v) for the absorbed decode path.

    For the CoLA parameterization W_ukv = B_ukv·diag(σ')·A… is nonlinear, so
    absorption is only exact for dense sites; for CoLA we reconstruct the
    *linearized* product B·A (σ omitted) — used only in serving where the
    site was trained with σ; the serve engine can alternatively run the
    unabsorbed path.  Dry-run cost realism is preserved either way.
    """
    m, h = cfg.mla, cfg.num_heads
    kvd = m.qk_nope_head_dim + m.v_head_dim
    if "w" in ukv_params:
        w = ukv_params["w"]
    elif "a" in ukv_params:
        w = jnp.einsum("dr,ro->do", ukv_params["a"], ukv_params["b"])
    elif "w0" in ukv_params:
        w = ukv_params["w0"] + (cfg.lora.alpha / cfg.lora.rank) * jnp.einsum(
            "dr,ro->do", ukv_params["lora_a"], ukv_params["lora_b"])
    else:
        w = jnp.einsum("dr,ro->do", ukv_params["sl_a"], ukv_params["sl_b"])
    return w.astype(dt).reshape(m.kv_lora_rank, h, kvd)
