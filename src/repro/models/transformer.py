"""Decoder-stack assembly: scan over repeating layer *periods*.

A period is the repeating unit of the architecture — lcm(block_pattern,
MoE interleave).  Parameters for one period are declared once and stacked
(n_periods, …) so ``lax.scan`` compiles a single period body regardless of
depth (compile-time critical for the 512-device dry-run).  Heterogeneous
layouts (jamba's 7 Mamba + 1 attn, llama4's dense/MoE alternation) unroll
*within* the period body.

CoLA-M: the period body is wrapped with ``jax.checkpoint`` whose policy
saves only the ``'cola_r'``-named low-rank activations (core/colam.py).
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.colam import maybe_remat
from repro.distributed.sharding import shard
from repro.models import attention, mlp, moe, rwkv6, ssm
from repro.models.common import (ParamDef, rmsnorm, rmsnorm_defs,
                                 stack_defs)


def period_length(cfg: ModelConfig) -> int:
    p = len(cfg.block_pattern)
    step = max(1, cfg.moe.interleave_step) if cfg.moe.enabled else 1
    period = math.lcm(p, step)
    assert cfg.num_layers % period == 0, (cfg.num_layers, period)
    return period


def n_periods(cfg: ModelConfig) -> int:
    return cfg.num_layers // period_length(cfg)


# --------------------------------------------------------------------------
# Per-period parameter / cache definitions
# --------------------------------------------------------------------------
def _layer_defs(cfg: ModelConfig, kind: str, is_moe: bool) -> Dict:
    d = cfg.d_model
    defs: Dict[str, Any] = {"ln1": rmsnorm_defs(d)}
    if kind == "attn":
        defs["mixer"] = (attention.mla_defs(cfg) if cfg.attention == "mla"
                         else attention.gqa_defs(cfg))
    elif kind == "mamba":
        defs["mixer"] = ssm.mamba_defs(cfg)
    elif kind == "rwkv6":
        defs["mixer"] = rwkv6.rwkv6_defs(cfg)
    else:
        raise ValueError(kind)
    if kind != "rwkv6":  # rwkv6 defs already include channel-mix (its ffn)
        defs["ln2"] = rmsnorm_defs(d)
        if is_moe:
            defs["ffn"] = moe.moe_defs(cfg)
        else:
            d_ff = (cfg.moe.dense_d_ff or cfg.d_ff) if cfg.moe.enabled \
                else cfg.d_ff
            if cfg.family == "audio":
                defs["ffn"] = mlp.gelu_mlp_defs(cfg, d_ff)
            else:
                defs["ffn"] = mlp.swiglu_defs(cfg, d_ff)
    else:
        defs["ln2"] = rmsnorm_defs(d)
    return defs


def period_defs(cfg: ModelConfig) -> Dict:
    period = period_length(cfg)
    kinds = cfg.layer_kinds()
    return {f"layer{i}": _layer_defs(cfg, kinds[i], cfg.layer_is_moe(i))
            for i in range(period)}


def stacked_block_defs(cfg: ModelConfig) -> Dict:
    return stack_defs(period_defs(cfg), n_periods(cfg))


def period_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    period = period_length(cfg)
    kinds = cfg.layer_kinds()
    out = {}
    for i in range(period):
        if kinds[i] == "attn":
            out[f"layer{i}"] = (
                attention.mla_cache_defs(cfg, batch, max_seq)
                if cfg.attention == "mla"
                else attention.gqa_cache_defs(cfg, batch, max_seq))
        elif kinds[i] == "mamba":
            out[f"layer{i}"] = ssm.mamba_state_defs(cfg, batch)
        elif kinds[i] == "rwkv6":
            out[f"layer{i}"] = rwkv6.rwkv6_state_defs(cfg, batch)
    return out


def stacked_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    return stack_defs(period_cache_defs(cfg, batch, max_seq), n_periods(cfg))


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------
def _zero_aux(cfg: ModelConfig) -> Dict[str, jax.Array]:
    if not cfg.moe.enabled:
        return {}
    return {"moe_aux": jnp.zeros((), jnp.float32),
            "moe_zloss": jnp.zeros((), jnp.float32),
            "moe_drop_frac": jnp.zeros((), jnp.float32)}


def _apply_layer(cfg: ModelConfig, kind: str, is_moe: bool, lp: Dict,
                 x: jax.Array, *, cos_sin, positions, cache, aux_acc,
                 mode: str = "train", page_map=None):
    """One layer: pre-norm mixer + pre-norm ffn, residual adds."""
    new_cache = cache
    h = rmsnorm(lp["ln1"], x, cfg.norm_eps)
    if kind == "attn":
        if cfg.attention == "mla":
            a, new_cache = attention.mla_apply(
                cfg, lp["mixer"], h, cos_sin=cos_sin, cache=cache,
                positions=positions, mode=mode, page_map=page_map)
        else:
            a, new_cache = attention.gqa_apply(
                cfg, lp["mixer"], h, cos_sin=cos_sin, cache=cache,
                positions=positions, mode=mode, page_map=page_map)
        x = x + a
    elif kind == "mamba":
        a, new_cache = ssm.mamba_apply(cfg, lp["mixer"], h, state=cache,
                                       mode=mode)
        x = x + a
    elif kind == "rwkv6":
        tm_out, new_tm, new_wkv = rwkv6.time_mix(cfg, lp["mixer"], h,
                                                 state=cache, mode=mode)
        x = x + tm_out
        h2 = rmsnorm(lp["ln2"], x, cfg.norm_eps)
        cm_out, new_cm = rwkv6.channel_mix(cfg, lp["mixer"], h2,
                                           state=cache, mode=mode)
        x = x + cm_out
        if cache is not None:
            new_cache = rwkv6.RWKVState(tm_x=new_tm.astype(jnp.bfloat16),
                                        cm_x=new_cm.astype(jnp.bfloat16),
                                        wkv=new_wkv)
        return x, new_cache, aux_acc
    # ffn (attn / mamba layers)
    h = rmsnorm(lp["ln2"], x, cfg.norm_eps)
    if is_moe:
        f, aux = moe.moe_apply(cfg, lp["ffn"], h, mode=mode)
        aux_acc = {k: aux_acc[k] + aux[k] for k in aux_acc}
    else:
        d_ff = (cfg.moe.dense_d_ff or cfg.d_ff) if cfg.moe.enabled \
            else cfg.d_ff
        if cfg.family == "audio":
            f = mlp.gelu_mlp_apply(cfg, lp["ffn"], h, d_ff, mode=mode)
        else:
            f = mlp.swiglu_apply(cfg, lp["ffn"], h, d_ff, mode=mode)
    x = x + f
    return x, new_cache, aux_acc


def stack_forward(cfg: ModelConfig, block_params: Dict, x: jax.Array, *,
                  cos_sin=None, positions=None, caches: Optional[Dict] = None,
                  training: bool = False, mode: str = "train",
                  page_map=None
                  ) -> Tuple[jax.Array, Optional[Dict], Dict]:
    """Run the full decoder stack.  block_params/caches are period-stacked.

    mode: 'train' | 'infer', threaded to every linear site.  The serve
    paths (Model.prefill / Model.decode_step) pass 'infer' so CoLA sites
    skip residual saving and decode batches dispatch the GEMV kernel.

    positions: per-token cache positions; they need not start at 0 or be
    contiguous across calls — chunked prefill re-enters the stack with
    each prompt slice at its true positions, and negative positions mark
    inert rows (fully masked queries, K/V parked in the sacrificial
    slot; see models/attention.py).

    page_map: paged-KV serving (loop-invariant across periods — it closes
    over the scan body rather than riding the carry); attention cache
    leaves are then flat physical-row pools, see attention.gqa_apply."""
    period = period_length(cfg)
    kinds = cfg.layer_kinds()
    has_cache = caches is not None

    def body(carry, xs):
        xc, aux_acc = carry
        if has_cache:
            pparams, pcache = xs
        else:
            pparams, pcache = xs, {}
        new_pcache = {}
        for i in range(period):
            lp = pparams[f"layer{i}"]
            cache_i = pcache.get(f"layer{i}") if has_cache else None
            xc, nc, aux_acc = _apply_layer(
                cfg, kinds[i], cfg.layer_is_moe(i), lp, xc,
                cos_sin=cos_sin, positions=positions, cache=cache_i,
                aux_acc=aux_acc, mode=mode, page_map=page_map)
            if has_cache and f"layer{i}" in pcache:
                new_pcache[f"layer{i}"] = nc
        # seq-sharded carry (Megatron-SP): the saved per-block residual
        # stack lives sequence-sharded over 'model'; blocks all-gather at
        # entry.  Keeps CoLA-M's (periods, b, s, d) saves 1/|model| sized.
        xc = shard(xc, "batch", "seq_save", "embed")
        return (xc, aux_acc), new_pcache

    if training and not has_cache:
        body = maybe_remat(body, cfg.remat)

    xs = (block_params, caches) if has_cache else block_params
    (x, aux), new_caches = jax.lax.scan(body, (x, _zero_aux(cfg)), xs)
    return x, (new_caches if has_cache else None), aux
