"""Mamba block (for jamba's hybrid layout).

CoLA applies to the two big projections (in_proj: d → 2·d_inner and
out_proj: d_inner → d); the small x/dt projections, depthwise conv and the
selective scan are kept exact (they are not "full-size linear layers" in the
paper's sense — DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.kernels.mamba_scan import ops as scan_ops
from repro.models import linear
from repro.models.common import ParamDef, silu


class MambaState(NamedTuple):
    conv: jax.Array  # (b, d_conv-1, d_inner)
    ssm: jax.Array   # (b, d_inner, d_state) f32


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or int(np.ceil(cfg.d_model / 16))
    return d_inner, m.d_state, m.d_conv, dt_rank


def mamba_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    di, N, dc, dtr = _dims(cfg)
    return {
        "in_proj": linear.linear_defs(cfg, "mlp", d, 2 * di, "embed", "ffw"),
        "conv_w": ParamDef((dc, di), ("conv", "ffw"), init="fan_in"),
        "conv_b": ParamDef((di,), ("ffw",), init="zeros"),
        "x_proj": linear.linear_defs(cfg, "small", di, dtr + 2 * N,
                                     "ffw", "rank"),
        "dt_proj": linear.linear_defs(cfg, "small", dtr, di, "rank", "ffw"),
        # softplus^{-1}(0.01) ≈ -4.6: start with slow dynamics
        "dt_bias": ParamDef((di,), ("ffw",), init="constant", scale=-4.6),
        "A_log": ParamDef((di, N), ("ffw", "state"), init="constant",
                          scale=0.0),  # overwritten below via transform
        "D": ParamDef((di,), ("ffw",), init="ones"),
        "out_proj": linear.linear_defs(cfg, "mlp", di, d, "ffw", "embed"),
    }


def _a_log_init(di: int, N: int) -> jax.Array:
    # S4D-real init: A = -(1..N) per channel
    return jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32),
                                    (di, N)))


def fix_mamba_init(params: Dict, cfg: ModelConfig) -> Dict:
    """Post-init transform: A_log gets the S4D-real spectrum."""
    di, N, _, _ = _dims(cfg)
    params = dict(params)
    params["A_log"] = _a_log_init(di, N).astype(params["A_log"].dtype)
    return params


def mamba_state_defs(cfg: ModelConfig, batch: int) -> MambaState:
    di, N, dc, _ = _dims(cfg)
    return MambaState(
        conv=ParamDef((batch, dc - 1, di), ("batch", "conv", "ffw"),
                      init="zeros", dtype="bfloat16"),
        ssm=ParamDef((batch, di, N), ("batch", "ffw", "state"),
                     init="zeros", dtype="float32"),
    )


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 prev: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d. x: (b, s, di); w: (dc, di)."""
    dc = w.shape[0]
    pad = (jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
           if prev is None else prev.astype(x.dtype))
    xp = jnp.concatenate([pad, x], axis=1)                   # (b, s+dc-1, di)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(dc))
    new_prev = xp[:, -(dc - 1):, :] if dc > 1 else pad[:, :0]
    return y + b[None, None, :], new_prev


def mamba_apply(cfg: ModelConfig, params: Dict, x: jax.Array, *,
                state: Optional[MambaState] = None,
                mode: str = "train"
                ) -> Tuple[jax.Array, Optional[MambaState]]:
    d = cfg.d_model
    di, N, dc, dtr = _dims(cfg)
    b, s, _ = x.shape
    xz = linear.linear_apply(cfg, params["in_proj"], x, "mlp", d, 2 * di,
                             in_ax="embed", out_ax="ffw", mode=mode)
    xin, z = jnp.split(xz, 2, axis=-1)

    prev_conv = state.conv if state is not None else None
    xc, new_conv = _causal_conv(xin, params["conv_w"].astype(x.dtype),
                                params["conv_b"].astype(x.dtype), prev_conv)
    xc = silu(xc)

    dbc = linear.linear_apply(cfg, params["x_proj"], xc, "small", di,
                              dtr + 2 * N, mode=mode)
    dt, B, C = jnp.split(dbc, [dtr, dtr + N], axis=-1)
    dt = linear.linear_apply(cfg, params["dt_proj"], dt, "small", dtr, di,
                             mode=mode)
    dt = jax.nn.softplus(dt.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    init = state.ssm if state is not None else None
    y, ssm = scan_ops.selective_scan(xc, dt.astype(xc.dtype), A, B, C,
                                     params["D"], init)
    y = y * silu(z)
    out = linear.linear_apply(cfg, params["out_proj"], y, "mlp", di, d,
                              in_ax="ffw", out_ax="embed", mode=mode)
    new_state = (MambaState(conv=new_conv.astype(jnp.bfloat16), ssm=ssm)
                 if state is not None else None)
    return out, new_state
