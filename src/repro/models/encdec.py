"""Encoder-decoder assembly (whisper-tiny backbone).

The conv/mel frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (batch, frames, d_model).  Encoder = non-causal attention
blocks; decoder = causal self-attention + cross-attention + GeLU MLP, all
projection sites CoLA-parameterized.  Sinusoidal absolute positions.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.colam import maybe_remat
from repro.models import attention, linear, mlp
from repro.models.common import (ParamDef, rmsnorm, rmsnorm_defs,
                                 sinusoidal_positions, stack_defs)


class CrossCache(NamedTuple):
    """Per-decoder-layer precomputed cross-attention K/V (from encoder)."""
    k: jax.Array  # (b, enc_seq, kv, hd)
    v: jax.Array


def _enc_layer_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": rmsnorm_defs(cfg.d_model),
        "attn": attention.gqa_defs(cfg),
        "ln2": rmsnorm_defs(cfg.d_model),
        "ffn": mlp.gelu_mlp_defs(cfg),
    }


def _dec_layer_defs(cfg: ModelConfig) -> Dict:
    return {
        "ln1": rmsnorm_defs(cfg.d_model),
        "self_attn": attention.gqa_defs(cfg),
        "ln_x": rmsnorm_defs(cfg.d_model),
        "cross_attn": attention.gqa_defs(cfg),
        "ln2": rmsnorm_defs(cfg.d_model),
        "ffn": mlp.gelu_mlp_defs(cfg),
    }


def encdec_block_defs(cfg: ModelConfig) -> Dict:
    return {
        "encoder": stack_defs(_enc_layer_defs(cfg), cfg.num_encoder_layers),
        "decoder": stack_defs(_dec_layer_defs(cfg), cfg.num_layers),
        "ln_enc": rmsnorm_defs(cfg.d_model),
    }


def encdec_cache_defs(cfg: ModelConfig, batch: int, max_seq: int) -> Dict:
    hd = cfg.resolved_head_dim
    kv = cfg.num_kv_heads
    self_c = attention.gqa_cache_defs(cfg, batch, max_seq)
    cross_c = CrossCache(
        k=ParamDef((batch, cfg.encoder_seq_len, kv, hd),
                   ("batch", "seq", "kv_heads", "head_dim"),
                   init="zeros", dtype="bfloat16"),
        v=ParamDef((batch, cfg.encoder_seq_len, kv, hd),
                   ("batch", "seq", "kv_heads", "head_dim"),
                   init="zeros", dtype="bfloat16"),
    )
    return stack_defs({"self": self_c, "cross": cross_c}, cfg.num_layers)


def encode(cfg: ModelConfig, params: Dict, frames: jax.Array,
           training: bool = False, mode: str = "train") -> jax.Array:
    """frames: (b, enc_seq, d) — precomputed frame embeddings (stub)."""
    pos = jnp.asarray(sinusoidal_positions(frames.shape[1], cfg.d_model),
                      frames.dtype)
    x = frames + pos[None]

    def body(carry, lp):
        xc = carry
        h = rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        a, _ = attention.gqa_apply(cfg, lp["attn"], h, cos_sin=None,
                                   causal=False, mode=mode)
        xc = xc + a
        h = rmsnorm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + mlp.gelu_mlp_apply(cfg, lp["ffn"], h, mode=mode)
        return xc, None

    if training:
        body = maybe_remat(body, cfg.remat)
    x, _ = jax.lax.scan(body, x, params["encoder"])
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def build_cross_caches(cfg: ModelConfig, params: Dict,
                       enc_out: jax.Array,
                       mode: str = "train") -> CrossCache:
    """Precompute per-layer cross K/V from encoder output (stacked (L,…))."""
    b, se, _ = enc_out.shape
    hd, kv = cfg.resolved_head_dim, cfg.num_kv_heads

    def per_layer(lp):
        k = linear.linear_apply(cfg, lp["cross_attn"]["k"], enc_out, "attn",
                                cfg.d_model, kv * hd, in_ax="embed",
                                out_ax="kv_heads",
                                mode=mode).reshape(b, se, kv, hd)
        v = linear.linear_apply(cfg, lp["cross_attn"]["v"], enc_out, "attn",
                                cfg.d_model, kv * hd, in_ax="embed",
                                out_ax="kv_heads",
                                mode=mode).reshape(b, se, kv, hd)
        return CrossCache(k.astype(jnp.bfloat16), v.astype(jnp.bfloat16))

    return jax.lax.map(per_layer, params["decoder"])


def decode_stack(cfg: ModelConfig, params: Dict, x: jax.Array, *,
                 enc_out: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None,
                 caches: Optional[Dict] = None,
                 training: bool = False,
                 mode: str = "train") -> Tuple[jax.Array, Optional[Dict]]:
    """Decoder stack.  Either enc_out (train/prefill, cross-attn computed on
    the fly) or caches['cross'] (decode) must be provided."""
    pos = jnp.asarray(sinusoidal_positions(cfg.max_seq_len, cfg.d_model),
                      x.dtype)
    if positions is not None:
        x = x + pos[positions]
    else:
        x = x + pos[None, :x.shape[1]]
    has_cache = caches is not None

    def body(carry, xs):
        xc = carry
        lp, pc = xs if has_cache else (xs, None)
        h = rmsnorm(lp["ln1"], xc, cfg.norm_eps)
        a, new_self = attention.gqa_apply(
            cfg, lp["self_attn"], h, cos_sin=None,
            cache=(pc["self"] if has_cache else None), positions=positions,
            mode=mode)
        xc = xc + a
        h = rmsnorm(lp["ln_x"], xc, cfg.norm_eps)
        if has_cache:
            a, _ = attention.gqa_apply(cfg, lp["cross_attn"], h,
                                       cos_sin=None, causal=False,
                                       cross_cache=pc["cross"], mode=mode)
        else:
            a, _ = attention.gqa_apply(cfg, lp["cross_attn"], h,
                                       cos_sin=None, causal=False,
                                       kv_from=enc_out, mode=mode)
        xc = xc + a
        h = rmsnorm(lp["ln2"], xc, cfg.norm_eps)
        xc = xc + mlp.gelu_mlp_apply(cfg, lp["ffn"], h, mode=mode)
        new_pc = ({"self": new_self, "cross": pc["cross"]}
                  if has_cache else None)
        return xc, new_pc

    if training and not has_cache:
        body = maybe_remat(body, cfg.remat)
    xs = (params["decoder"], caches) if has_cache else params["decoder"]
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, (new_caches if has_cache else None)
