"""Mixture-of-Experts with sort-based (matmul-free) dispatch and expert
parallelism over the 'model' mesh axis.

Design (DESIGN.md §5): tokens stay data-sharded; within each data shard we
route, sort by expert id, clamp to capacity and scatter into an
(E_local, C, d) buffer; each 'model' rank computes only its expert slice and
partial outputs are psum-combined over 'model' — one all-reduce per MoE
layer, never a quadratic one-hot dispatch einsum.  Expert weights are stored
FSDP-sharded; the shard_map boundary all-gathers them to EP layout at use
time (ZeRO-3 semantics, inserted automatically by SPMD resharding).

Experts themselves are CoLA auto-encoders when ``parameterization='cola'``
(beyond-paper: the paper lists MoE as future work).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.config import ModelConfig
from repro.core.cola import keep_original_sigma
from repro.distributed.sharding import current_env
from repro.models import linear
from repro.models.common import ParamDef, axes_tree, silu


def moe_defs(cfg: ModelConfig, d_ff: int = 0) -> Dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    E = cfg.moe.num_experts
    per_expert = {
        "gate": linear.linear_defs(cfg, "expert", d, f, "embed", "ffw",
                                   originally_nonlinear=True),
        "up": linear.linear_defs(cfg, "expert", d, f, "embed", "ffw"),
        "down": linear.linear_defs(cfg, "expert", f, d, "ffw", "embed"),
    }
    experts = jax.tree.map(
        lambda p: dataclasses.replace(p, shape=(E,) + p.shape,
                                      axes=("expert",) + p.axes),
        per_expert, is_leaf=lambda x: isinstance(x, ParamDef))
    defs = {
        "router": ParamDef((d, E), ("embed", "expert"), init="fan_in"),
        "experts": experts,
    }
    if cfg.moe.shared_expert_d_ff:
        from repro.models.mlp import swiglu_defs
        defs["shared"] = swiglu_defs(cfg, cfg.moe.shared_expert_d_ff,
                                     site="mlp")
    return defs


def _expert_ffn(cfg: ModelConfig, eparams: Dict, x: jax.Array,
                d: int, f: int, mode: str = "train") -> jax.Array:
    """SwiGLU for a single expert; x: (C, d). No shard() calls inside."""
    g = linear.linear_apply(cfg, eparams["gate"], x, "expert", d, f,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="ffw", mode=mode)
    u = linear.linear_apply(cfg, eparams["up"], x, "expert", d, f,
                            in_ax="embed", out_ax="ffw", mode=mode)
    if cfg.parameterization != "cola" or keep_original_sigma(cfg):
        g = silu(g)
    return linear.linear_apply(cfg, eparams["down"], g * u, "expert", f, d,
                               in_ax="ffw", out_ax="embed", mode=mode)


def _capacity(cfg: ModelConfig, tokens: int) -> int:
    E, k, cf = cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.capacity_factor
    return max(1, int(np.ceil(tokens * k * cf / E)))


def _moe_core(cfg: ModelConfig, params: Dict, x: jax.Array, d_ff: int, *,
              ep_axis: Optional[str], ep_rank, ep_size: int,
              mode: str = "train"
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Route + dispatch + expert compute for local tokens x: (b, s, d).

    ``params['experts']`` leaves hold the LOCAL expert slice (E/ep_size, …)
    when ep_size > 1 (sliced by the shard_map in_specs), the full table
    otherwise.
    """
    b, s, d = x.shape
    f = d_ff or cfg.d_ff
    E, k = cfg.moe.num_experts, cfg.moe.top_k
    T = b * s
    xt = x.reshape(T, d)

    logits = (xt.astype(jnp.float32) @
              params["router"].astype(jnp.float32))            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)                       # (T, k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)

    # ---- positions within each expert (sort-based, matmul-free) ----------
    flat_e = eidx.reshape(-1)                                   # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.bincount(flat_e, length=E)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
        pos_sorted.astype(jnp.int32))

    C = _capacity(cfg, T)
    E_local = E // ep_size
    if ep_size > 1:
        e_lo = ep_rank * E_local
        is_local = (flat_e >= e_lo) & (flat_e < e_lo + E_local)
    else:
        e_lo = 0
        is_local = jnp.ones_like(flat_e, dtype=bool)
    keep = (pos < C) & is_local
    slot = jnp.where(keep, (flat_e - e_lo) * C + pos, E_local * C)

    tok_of = jnp.arange(T * k) // k
    buf = jnp.zeros((E_local * C, d), x.dtype).at[slot].add(
        xt[tok_of], mode="drop").reshape(E_local, C, d)

    # ---- expert compute (vmap over local experts) -------------------------
    eparams = jax.tree.map(lambda w: w.astype(x.dtype), params["experts"])
    out_buf = jax.vmap(lambda ep, xb: _expert_ffn(cfg, ep, xb, d, f, mode))(
        eparams, buf)                                           # (E_l, C, d)

    # ---- combine ----------------------------------------------------------
    flat_out = jnp.concatenate(
        [out_buf.reshape(E_local * C, d), jnp.zeros((1, d), x.dtype)], 0)
    y_k = flat_out[slot] * keep[:, None].astype(x.dtype)
    y_k = y_k * gates.reshape(-1)[:, None].astype(x.dtype)
    y = jnp.sum(y_k.reshape(T, k, d), axis=1)
    if ep_axis is not None and ep_size > 1:
        y = jax.lax.psum(y, ep_axis)  # partial outputs from each EP rank

    # ---- aux losses (Switch/GShard) ---------------------------------------
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(eidx, E, dtype=jnp.float32).sum(1), axis=0)
    aux = {
        "moe_aux": cfg.moe.aux_loss * E * jnp.sum(me * ce / k),
        "moe_zloss": cfg.moe.router_z_loss * jnp.mean(
            jnp.square(jax.nn.logsumexp(logits, axis=-1))),
        "moe_drop_frac": 1.0 - jnp.mean(jnp.where(pos < C, 1.0, 0.0)),
    }
    return y.reshape(b, s, d), aux


def moe_apply(cfg: ModelConfig, params: Dict, x: jax.Array,
              d_ff: int = 0, mode: str = "train"
              ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """MoE FFN; shard_map EP when a mesh is active, plain local core else."""
    env = current_env()
    if env is None or int(np.prod(list(env.mesh.shape.values()))) == 1:
        y, aux = _moe_core(cfg, params, x, d_ff, ep_axis=None, ep_rank=0,
                           ep_size=1, mode=mode)
    else:
        mesh = env.mesh
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape
                           and x.shape[0] % mesh.shape[a] == 0)
        model = "model" if "model" in mesh.shape else None
        E = cfg.moe.num_experts
        ep_size = (mesh.shape[model]
                   if model and E % mesh.shape[model] == 0 else 1)
        x_spec = P(batch_axes if batch_axes else None, None, None)

        def pin(axes_tuple):
            if ep_size > 1 and axes_tuple and axes_tuple[0] == "expert":
                return P(model, *([None] * (len(axes_tuple) - 1)))
            return P(*([None] * len(axes_tuple)))

        params_axes = axes_tree(moe_defs(cfg, d_ff))
        params_axes.pop("shared", None)
        in_params_spec = jax.tree.map(
            pin, params_axes,
            is_leaf=lambda t: isinstance(t, tuple) and all(
                a is None or isinstance(a, str) for a in t))
        p_wo_shared = {kk: vv for kk, vv in params.items() if kk != "shared"}

        def body(pp, xl):
            if ep_size > 1:
                rank = jax.lax.axis_index(model)
                yy, aux = _moe_core(cfg, pp, xl, d_ff, ep_axis=model,
                                    ep_rank=rank, ep_size=ep_size,
                                    mode=mode)
            else:
                # no EP: tokens & weights replicated over 'model'; every
                # model rank computes the identical full-expert output.
                yy, aux = _moe_core(cfg, pp, xl, d_ff, ep_axis=None,
                                    ep_rank=0, ep_size=1, mode=mode)
            if batch_axes:
                aux = {kk: jax.lax.pmean(vv, batch_axes)
                       for kk, vv in aux.items()}
            return yy, aux

        y, aux = shard_map(
            body, mesh=mesh,
            in_specs=(in_params_spec, x_spec),
            out_specs=(x_spec, P()),
            check_rep=False,
        )(p_wo_shared, x)
    if "shared" in params:
        from repro.models.mlp import swiglu_apply
        y = y + swiglu_apply(cfg, params["shared"], x,
                             cfg.moe.shared_expert_d_ff, site="mlp",
                             mode=mode)
    return y, aux
