"""Model facade: one object per architecture with init / apply / prefill /
decode_step / cache & input specs — everything the launcher, trainer, serve
engine and dry-run need.

Batch dict conventions
----------------------
train (LM):    {"tokens": (B,S) i32, "labels": (B,S) i32}
train (vlm):   {"inputs_embeds": (B,S,d) bf16, "position_ids": (3,B,S) i32,
                "labels": (B,S) i32}
train (audio): {"frames": (B,enc,d) bf16, "tokens": (B,S), "labels": (B,S)}
prefill:       same minus labels
decode:        {"tokens": (B,1)} + positions (B,1) + caches
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeSpec
from repro.models import common, encdec, ssm, transformer
from repro.models.common import (ParamDef, abstract_params, axes_tree,
                                 embed, embedding_defs, init_params, rmsnorm,
                                 rmsnorm_defs, unembed)


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- parameter definitions -------------------------------------------
    def defs(self) -> Dict:
        cfg = self.cfg
        d = {"embed": embedding_defs(cfg), "ln_f": rmsnorm_defs(cfg.d_model)}
        if cfg.is_encoder_decoder:
            d["blocks"] = encdec.encdec_block_defs(cfg)
        else:
            d["blocks"] = transformer.stacked_block_defs(cfg)
        if not cfg.tie_embeddings:
            d["head"] = {"w": ParamDef((cfg.d_model, cfg.padded_vocab),
                                       ("embed", "vocab"), init="normal",
                                       scale=0.02)}
        return d

    def init(self, rng: jax.Array) -> Dict:
        params = init_params(self.defs(), rng, self.cfg.param_dtype)
        params = self._post_init(params)
        return params

    def _post_init(self, params: Dict) -> Dict:
        # Mamba A_log needs its S4D spectrum (can't be expressed as ParamDef)
        if "mamba" in self.cfg.layer_kinds():
            blocks = dict(params["blocks"])
            for key, sub in blocks.items():
                if key.startswith("layer") and "A_log" in sub.get("mixer", {}):
                    mixer = dict(sub["mixer"])
                    di, N = mixer["A_log"].shape[-2:]
                    a = jnp.log(jnp.broadcast_to(
                        jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)))
                    mixer["A_log"] = jnp.broadcast_to(
                        a, mixer["A_log"].shape).astype(mixer["A_log"].dtype)
                    sub = dict(sub)
                    sub["mixer"] = mixer
                    blocks[key] = sub
            params = dict(params)
            params["blocks"] = blocks
        return params

    def abstract(self) -> Dict:
        return abstract_params(self.defs(), self.cfg.param_dtype)

    def axes(self) -> Dict:
        return axes_tree(self.defs())

    # ---- caches ------------------------------------------------------------
    def cache_defs(self, batch: int, max_seq: int) -> Dict:
        cfg = self.cfg
        if cfg.is_encoder_decoder:
            return encdec.encdec_cache_defs(cfg, batch, max_seq)
        return transformer.stacked_cache_defs(cfg, batch, max_seq)

    def init_caches(self, batch: int, max_seq: int) -> Dict:
        return init_params(self.cache_defs(batch, max_seq),
                           jax.random.PRNGKey(0), "bfloat16")

    def cache_axes(self, batch: int, max_seq: int) -> Dict:
        return axes_tree(self.cache_defs(batch, max_seq))

    def abstract_caches(self, batch: int, max_seq: int) -> Dict:
        return abstract_params(self.cache_defs(batch, max_seq), "bfloat16")

    # ---- rope --------------------------------------------------------------
    def _cos_sin(self, positions: Optional[jax.Array],
                 batch: Dict) -> Optional[Tuple[jax.Array, jax.Array]]:
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        if cfg.attention == "mla":
            hd = cfg.mla.qk_rope_head_dim
        if cfg.rope == "none" or cfg.attention == "none":
            return None
        if cfg.rope == "mrope":
            pos3 = batch.get("position_ids")
            if pos3 is None:
                pos3 = jnp.broadcast_to(positions[None], (3,) + positions.shape)
            return common.mrope_cos_sin(pos3, hd, cfg.rope_theta,
                                        cfg.mrope_sections)
        return common.rope_cos_sin(positions, hd, cfg.rope_theta)

    # ---- forward ------------------------------------------------------------
    def _embed_inputs(self, params: Dict, batch: Dict, dtype) -> jax.Array:
        if "inputs_embeds" in batch:
            return batch["inputs_embeds"].astype(dtype)
        return embed(params["embed"], batch["tokens"], dtype)

    def _logits(self, params: Dict, x: jax.Array) -> jax.Array:
        from repro.distributed.sharding import shard
        cfg = self.cfg
        x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
        if cfg.tie_embeddings:
            logits = unembed(params["embed"], x, cfg)
            return shard(logits, "batch", "seq", "vocab")
        logits = jnp.einsum("bsd,dv->bsv", x,
                            params["head"]["w"].astype(x.dtype))
        logits = shard(logits, "batch", "seq", "vocab")
        if cfg.padded_vocab != cfg.vocab_size:
            mask = jnp.arange(cfg.padded_vocab) >= cfg.vocab_size
            logits = jnp.where(mask[None, None], jnp.finfo(logits.dtype).min,
                               logits)
        return logits

    def hidden(self, params: Dict, batch: Dict, *, training: bool = False
               ) -> Tuple[jax.Array, Dict]:
        """Final hidden states (pre-unembed).  Returns (x, aux)."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if cfg.is_encoder_decoder:
            enc = encdec.encode(cfg, params["blocks"],
                                batch["frames"].astype(dtype), training)
            x = embed(params["embed"], batch["tokens"], dtype)
            x, _ = encdec.decode_stack(cfg, params["blocks"], x, enc_out=enc,
                                       training=training)
            return x, {}
        x = self._embed_inputs(params, batch, dtype)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cos_sin = self._cos_sin(positions, batch)
        from repro.distributed.sharding import shard
        x = shard(x, "batch", "seq", "embed")
        x, _, aux = transformer.stack_forward(cfg, params["blocks"], x,
                                              cos_sin=cos_sin,
                                              positions=positions,
                                              training=training)
        return x, aux

    def unembed_matrix(self, params: Dict) -> jax.Array:
        """(d, padded_vocab) output projection (tied or separate head)."""
        if self.cfg.tie_embeddings:
            return params["embed"]["table"].T
        return params["head"]["w"]

    def final_norm(self, params: Dict, x: jax.Array) -> jax.Array:
        return rmsnorm(params["ln_f"], x, self.cfg.norm_eps)

    def apply(self, params: Dict, batch: Dict, *, training: bool = False
              ) -> Tuple[jax.Array, Dict]:
        """Full-sequence forward (train / eval).  Returns (logits, aux)."""
        x, aux = self.hidden(params, batch, training=training)
        return self._logits(params, x), aux

    # ---- serving -------------------------------------------------------------
    def prefill(self, params: Dict, batch: Dict, caches: Dict,
                positions: Optional[jax.Array] = None,
                page_map: Optional[jax.Array] = None,
                all_logits: bool = False
                ) -> Tuple[jax.Array, Dict]:
        """Write the prompt into caches; returns (last-token logits, caches).

        Runs with mode='infer': CoLA sites take the fused no-residual
        forward (no z_pre saved — there is no backward to feed).  The
        serve engine passes left-padded ragged prompts with per-row
        ``positions``; pad columns carry negative positions, which mask
        their attention rows and park their K/V writes in the sacrificial
        last cache slot (see attention.gqa_apply).  ``page_map``: paged-KV
        serving — attention caches are flat physical-row pools and K/V
        route through the (B, max_seq) logical→physical map.

        ``positions`` need not start at 0: chunked prefill (the overlap
        serve engine) re-enters with each prompt slice at its true cache
        positions and the attention mask lets every chunk token see all
        previously cached positions — the cache K/V written is
        byte-identical to a single monolithic prefill of the same prompt.
        ``all_logits=True`` returns the full (B, S, V) logits instead of
        the last column (the mixed dispatch samples only rows whose prompt
        ends inside the chunk; left-padding keeps those in column -1).
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        if cfg.is_encoder_decoder:
            enc = encdec.encode(cfg, params["blocks"],
                                batch["frames"].astype(dtype), mode="infer")
            cross = encdec.build_cross_caches(cfg, params["blocks"], enc,
                                              mode="infer")
            caches = {"self": caches["self"], "cross": cross}
            x = embed(params["embed"], batch["tokens"], dtype)
            b, s = x.shape[:2]
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
            x, new_caches = encdec.decode_stack(
                cfg, params["blocks"], x, positions=positions, caches=caches,
                mode="infer")
            if not all_logits:
                x = x[:, -1:]
            return self._logits(params, x), new_caches
        x = self._embed_inputs(params, batch, dtype)
        b, s = x.shape[:2]
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        cos_sin = self._cos_sin(positions, batch)
        x, new_caches, _ = transformer.stack_forward(
            cfg, params["blocks"], x, cos_sin=cos_sin, positions=positions,
            caches=caches, mode="infer", page_map=page_map)
        if not all_logits:
            x = x[:, -1:]
        return self._logits(params, x), new_caches

    def decode_step(self, params: Dict, tokens: jax.Array, caches: Dict,
                    positions: jax.Array,
                    page_map: Optional[jax.Array] = None
                    ) -> Tuple[jax.Array, Dict]:
        """One decode step.  tokens/positions: (B, 1).

        mode='infer' end to end: at T = B×1 every CoLA site lands below
        ops.DECODE_T_MAX and dispatches the GEMV-shaped ``cola_ae_decode``
        kernel — never the training-shaped token-tile grids (under a TP
        mesh: the sharded decode / decode_split bodies).  ``page_map``:
        paged-KV serving, same contract as ``prefill``.
        """
        cfg = self.cfg
        dtype = jnp.dtype(cfg.dtype)
        x = embed(params["embed"], tokens, dtype)
        if cfg.is_encoder_decoder:
            x, new_caches = encdec.decode_stack(
                cfg, params["blocks"], x, positions=positions, caches=caches,
                mode="infer")
            return self._logits(params, x), new_caches
        cos_sin = self._cos_sin(positions, {})
        x, new_caches, _ = transformer.stack_forward(
            cfg, params["blocks"], x, cos_sin=cos_sin, positions=positions,
            caches=caches, mode="infer", page_map=page_map)
        return self._logits(params, x), new_caches

    # ---- dry-run input specs ---------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
        """ShapeDtypeStruct stand-ins for every model input of a cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        bf16 = jnp.bfloat16
        d = cfg.d_model
        if shape.kind == "train":
            if cfg.family == "vlm":
                return {"inputs_embeds": jax.ShapeDtypeStruct((B, S, d), bf16),
                        "position_ids": jax.ShapeDtypeStruct((3, B, S), i32),
                        "labels": jax.ShapeDtypeStruct((B, S), i32)}
            if cfg.family == "audio":
                return {"frames": jax.ShapeDtypeStruct(
                            (B, cfg.encoder_seq_len, d), bf16),
                        "tokens": jax.ShapeDtypeStruct((B, S), i32),
                        "labels": jax.ShapeDtypeStruct((B, S), i32)}
            return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if shape.kind == "prefill":
            spec = self.input_specs(ShapeSpec(shape.name, S, B, "train"))
            spec.pop("labels")
            return spec
        # decode: one new token over a cache of length S
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
