"""RWKV6 "Finch" block: time-mix (data-dependent decay WKV recurrence) +
channel-mix.  [arXiv:2404.05892]

CoLA applies to the r/k/v/g/o time-mix projections and the channel-mix
W_k/W_v/W_r (all d×d or d×d_ff linear sites).  The data-dependent ddlerp
and decay LoRAs (time_maa_w1/w2, decay_w1/w2) are *native* low-rank paths in
RWKV6 and are kept exact — a designed synergy the paper's thesis predicts
(DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels.rwkv6_scan import ops as wkv_ops
from repro.models import linear
from repro.models.common import ParamDef, groupnorm_heads, silu

TM_EXTRA = 32     # ddlerp LoRA dim (official rwkv6 uses 32)
DECAY_EXTRA = 64  # decay LoRA dim


class RWKVState(NamedTuple):
    tm_x: jax.Array   # (b, d)  last token (time-mix shift)
    cm_x: jax.Array   # (b, d)  last token (channel-mix shift)
    wkv: jax.Array    # (b, h, dh, dh) f32 recurrence state


def rwkv6_defs(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    h = cfg.num_heads
    dh = cfg.resolved_head_dim
    ff = cfg.d_ff
    return {
        # time-mix ----------------------------------------------------------
        "maa_x": ParamDef((d,), ("embed",), init="zeros"),
        "maa_wkvrg": ParamDef((5, d), ("null", "embed"), init="zeros"),
        "maa_w1": ParamDef((d, 5 * TM_EXTRA), ("embed", "rank"),
                           init="fan_in", scale=0.1),
        "maa_w2": ParamDef((5, TM_EXTRA, d), ("null", "rank", "embed"),
                           init="fan_in", scale=0.1),
        "decay": ParamDef((d,), ("embed",), init="constant", scale=-6.0),
        "decay_w1": ParamDef((d, DECAY_EXTRA), ("embed", "rank"),
                             init="fan_in", scale=0.1),
        "decay_w2": ParamDef((DECAY_EXTRA, d), ("rank", "embed"),
                             init="fan_in", scale=0.1),
        "faaaa": ParamDef((h, dh), ("heads", "head_dim"), init="normal",
                          scale=0.02),
        "r": linear.linear_defs(cfg, "attn", d, d, "embed", "heads"),
        "k": linear.linear_defs(cfg, "attn", d, d, "embed", "heads"),
        "v": linear.linear_defs(cfg, "attn", d, d, "embed", "heads"),
        "g": linear.linear_defs(cfg, "attn", d, d, "embed", "heads",
                                originally_nonlinear=True),
        "o": linear.linear_defs(cfg, "attn", d, d, "heads", "embed"),
        "ln_x_scale": ParamDef((d,), ("embed",), init="ones"),
        "ln_x_bias": ParamDef((d,), ("embed",), init="zeros"),
        # channel-mix --------------------------------------------------------
        "cm_maa_k": ParamDef((d,), ("embed",), init="zeros"),
        "cm_maa_r": ParamDef((d,), ("embed",), init="zeros"),
        "cm_k": linear.linear_defs(cfg, "mlp", d, ff, "embed", "ffw",
                                   originally_nonlinear=True),
        "cm_v": linear.linear_defs(cfg, "mlp", ff, d, "ffw", "embed"),
        "cm_r": linear.linear_defs(cfg, "attn", d, d, "embed", "heads",
                                   originally_nonlinear=True),
    }


def rwkv6_state_defs(cfg: ModelConfig, batch: int) -> RWKVState:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    return RWKVState(
        tm_x=ParamDef((batch, d), ("batch", "embed"), init="zeros",
                      dtype="bfloat16"),
        cm_x=ParamDef((batch, d), ("batch", "embed"), init="zeros",
                      dtype="bfloat16"),
        wkv=ParamDef((batch, h, dh, dh), ("batch", "heads", "head_dim",
                                          "head_dim"),
                     init="zeros", dtype="float32"),
    )


def _shift(x: jax.Array, prev: Optional[jax.Array]) -> jax.Array:
    """Token shift: y_t = x_{t-1}; position 0 uses `prev` (or zeros)."""
    first = (jnp.zeros_like(x[:, :1]) if prev is None
             else prev[:, None, :].astype(x.dtype))
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def time_mix(cfg: ModelConfig, p: Dict, x: jax.Array, *,
             state: Optional[RWKVState] = None, mode: str = "train"
             ) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    d, h, dh = cfg.d_model, cfg.num_heads, cfg.resolved_head_dim
    b, s, _ = x.shape
    dt = x.dtype
    prev = state.tm_x if state is not None else None
    xs = _shift(x, prev)
    xx = xs - x
    # ddlerp: data-dependent interpolation coefficients (Finch)
    xxx = x + xx * p["maa_x"].astype(dt)
    B = jnp.tanh(jnp.einsum("bsd,de->bse", xxx, p["maa_w1"].astype(dt)))
    B = B.reshape(b, s, 5, TM_EXTRA)
    mixes = jnp.einsum("bsfe,fed->bsfd", B, p["maa_w2"].astype(dt))
    mixes = mixes + p["maa_wkvrg"].astype(dt)[None, None]
    xw, xk, xv, xr, xg = [x + xx * mixes[:, :, i] for i in range(5)]

    # data-dependent decay
    ww = jnp.einsum("bsd,de->bse", jnp.tanh(
        jnp.einsum("bsd,de->bse", xw, p["decay_w1"].astype(dt))),
        p["decay_w2"].astype(dt))
    w = p["decay"].astype(jnp.float32) + ww.astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w))                                 # (b, s, d)

    r = linear.linear_apply(cfg, p["r"], xr, "attn", d, d,
                            in_ax="embed", out_ax="heads", mode=mode)
    k = linear.linear_apply(cfg, p["k"], xk, "attn", d, d,
                            in_ax="embed", out_ax="heads", mode=mode)
    v = linear.linear_apply(cfg, p["v"], xv, "attn", d, d,
                            in_ax="embed", out_ax="heads", mode=mode)
    g = linear.linear_apply(cfg, p["g"], xg, "attn", d, d,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="heads", mode=mode)

    rh = r.reshape(b, s, h, dh)
    kh = k.reshape(b, s, h, dh)
    vh = v.reshape(b, s, h, dh)
    wh = w.reshape(b, s, h, dh)
    init = state.wkv if state is not None else None
    y, wkv_state = wkv_ops.wkv6(rh, kh, vh, wh.astype(rh.dtype),
                                p["faaaa"], init)
    y = groupnorm_heads(y, p["ln_x_scale"].astype(jnp.float32)
                        .reshape(h, dh), p["ln_x_bias"].astype(jnp.float32)
                        .reshape(h, dh))
    y = y.reshape(b, s, d) * silu(g)
    out = linear.linear_apply(cfg, p["o"], y, "attn", d, d,
                              in_ax="heads", out_ax="embed", mode=mode)
    new_tm_x = x[:, -1, :] if state is not None else None
    return out, new_tm_x, (wkv_state if state is not None else None)


def channel_mix(cfg: ModelConfig, p: Dict, x: jax.Array, *,
                state: Optional[RWKVState] = None, mode: str = "train"
                ) -> Tuple[jax.Array, Optional[jax.Array]]:
    d, ff = cfg.d_model, cfg.d_ff
    dt = x.dtype
    prev = state.cm_x if state is not None else None
    xs = _shift(x, prev)
    xx = xs - x
    xk = x + xx * p["cm_maa_k"].astype(dt)
    xr = x + xx * p["cm_maa_r"].astype(dt)
    k = linear.linear_apply(cfg, p["cm_k"], xk, "mlp", d, ff,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="ffw", mode=mode)
    k = jnp.square(jax.nn.relu(k))
    kv = linear.linear_apply(cfg, p["cm_v"], k, "mlp", ff, d,
                             in_ax="ffw", out_ax="embed", mode=mode)
    r = linear.linear_apply(cfg, p["cm_r"], xr, "attn", d, d,
                            originally_nonlinear=True,
                            in_ax="embed", out_ax="heads", mode=mode)
    out = jax.nn.sigmoid(r) * kv
    new_cm_x = x[:, -1, :] if state is not None else None
    return out, new_cm_x
