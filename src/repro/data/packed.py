"""Packed-corpus reader: a flat binary file of token ids (uint16/uint32)
memory-mapped and sliced into fixed-length sequences.

Layout: ``<path>.bin`` (token ids) + ``<path>.meta.json``
({"dtype": "uint16"|"uint32", "num_tokens": N}).  This is the on-disk
format real runs would use (tokenized C4); ``repro.data.packed.write_corpus``
creates it (used by tests and by examples with synthetic text).
Deterministic: batch = f(step, shard).
"""
from __future__ import annotations

import json
import os
from typing import Dict

import numpy as np


def write_corpus(path: str, tokens: np.ndarray) -> None:
    dtype = "uint16" if tokens.max() < 2**16 else "uint32"
    tokens.astype(dtype).tofile(path + ".bin")
    with open(path + ".meta.json", "w") as f:
        json.dump({"dtype": dtype, "num_tokens": int(tokens.size)}, f)


class PackedCorpus:
    def __init__(self, path: str, seed: int = 0):
        with open(path + ".meta.json") as f:
            meta = json.load(f)
        self.tokens = np.memmap(path + ".bin", dtype=meta["dtype"],
                                mode="r", shape=(meta["num_tokens"],))
        self.seed = seed

    def batch(self, step: int, batch: int, seq_len: int,
              shard: int = 0, num_shards: int = 1) -> Dict[str, np.ndarray]:
        s1 = seq_len + 1
        n_seq = self.tokens.shape[0] // s1
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131) % (2**31))
        order = rng.permutation(n_seq)
        base = (step * batch * num_shards + shard * batch) % n_seq
        idx = order[(base + np.arange(batch)) % n_seq]
        rows = np.stack([self.tokens[i * s1:(i + 1) * s1] for i in idx])
        rows = rows.astype(np.int32)
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
