"""Sharded, checkpointable data pipeline.

State is one integer (the step): every batch is a pure function of
(source seed, step, data shard), so resume-after-preemption replays exactly
and multi-host sharding is index arithmetic — the pattern MaxText/grain use
for deterministic input pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import numpy as np

from repro.config import ModelConfig, TrainConfig
from repro.data.packed import PackedCorpus
from repro.data.synthetic import MarkovZipf


@dataclasses.dataclass
class DataPipeline:
    source: object
    batch: int
    seq_len: int
    shard: int = 0
    num_shards: int = 1
    # recovery skip: batches are drawn at ``step + offset``, so advancing
    # the offset skips a data window without touching the LR-schedule step
    # (train/guard.py bumps it when rolling back past a poisoned batch).
    # Rides along in checkpoint extra.json so resume replays identically.
    offset: int = 0

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        step = step + self.offset
        if isinstance(self.source, PackedCorpus):
            return self.source.batch(step, self.batch, self.seq_len,
                                     self.shard, self.num_shards)
        return self.source.batch(step, self.batch, self.seq_len, self.shard)

    def skip_window(self, n: int) -> int:
        """Advance the data offset by ``n`` batches; returns the new
        offset."""
        self.offset += int(n)
        return self.offset

    # checkpointable state -------------------------------------------------
    def state(self, step: int) -> Dict:
        return {"step": step, "shard": self.shard,
                "num_shards": self.num_shards, "offset": self.offset}

    @staticmethod
    def resume_step(state: Dict) -> int:
        return int(state["step"])

    def resume(self, state: Dict) -> int:
        """Restore checkpointed pipeline state; returns the resume step."""
        self.offset = int(state.get("offset", 0))
        return int(state["step"])


def make_pipeline(mc: ModelConfig, tc: TrainConfig, *, shard: int = 0,
                  num_shards: int = 1) -> DataPipeline:
    if tc.data.startswith("packed:"):
        src = PackedCorpus(tc.data.split(":", 1)[1], seed=tc.seed)
    elif tc.data.startswith("markov:"):
        # "markov:<p>" — synthetic corpus with explicit transition
        # determinism (benchmarks/throughput_table.py trains its
        # speculative-decoding model on a high-p corpus so the self-draft
        # has structure to predict)
        src = MarkovZipf(mc.vocab_size, seed=tc.seed,
                         markov_p=float(tc.data.split(":", 1)[1]))
    else:
        src = MarkovZipf(mc.vocab_size, seed=tc.seed)
    per_shard = tc.global_batch // num_shards
    return DataPipeline(src, per_shard, tc.seq_len, shard, num_shards)
