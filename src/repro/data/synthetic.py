"""Deterministic Markov-Zipf synthetic LM corpus.

The container is offline (no C4), so pre-training comparisons run on a
synthetic stream with C4-like statistics: a Zipf(1.1) unigram marginal mixed
with an order-1 Markov chain (a fixed permutation successor function applied
with prob. ``markov_p``).  The chain gives models structure to learn, so
validation loss separates full-rank vs CoLA vs baselines *relatively*, which
is what the paper's Table 5 analogue needs (DESIGN.md §8.3).

Batches are a pure function of (seed, step, shard) — checkpoint/resume and
multi-host sharding need no iterator state beyond the integer step.
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


class MarkovZipf:
    def __init__(self, vocab_size: int, seed: int = 0, alpha: float = 1.1,
                 markov_p: float = 0.7):
        self.vocab = vocab_size
        self.seed = seed
        self.markov_p = markov_p
        rng = np.random.RandomState(seed)
        ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
        probs = ranks ** (-alpha)
        self.probs = probs / probs.sum()
        self.successor = rng.permutation(vocab_size)

    def batch(self, step: int, batch: int, seq_len: int,
              shard: int = 0) -> Dict[str, np.ndarray]:
        """(batch, seq_len+1) tokens -> {'tokens','labels'} of (b, s)."""
        rng = np.random.RandomState(
            (self.seed * 1_000_003 + step * 131 + shard * 7919) % (2**31))
        s1 = seq_len + 1
        zipf_draws = rng.choice(self.vocab, size=(batch, s1), p=self.probs)
        use_markov = rng.random_sample((batch, s1)) < self.markov_p
        toks = np.empty((batch, s1), np.int64)
        toks[:, 0] = zipf_draws[:, 0]
        for t in range(1, s1):
            toks[:, t] = np.where(use_markov[:, t],
                                  self.successor[toks[:, t - 1]],
                                  zipf_draws[:, t])
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}
