"""Deterministic fault-injection harness for chaos testing.

Every fault-tolerance claim in this tree is backed by a test that *injects
the fault* and watches the system recover — not by prose.  This module is
the injection side: small, deterministic fault objects that plug into the
hook points the production code exposes, so the chaos suite
(tests/test_chaos.py) can replay the same failure on every run, on CPU.

Injection points
----------------
* **Train loop** (``train(hooks=...)``): :func:`train_hooks` builds a
  ``before_step`` hook from a list of step faults —
  :class:`CrashAt` (raise ``SimulatedCrash`` — a hard process death),
  :class:`SigtermAt` (``os.kill(getpid(), SIGTERM)`` — a preemption
  notice, delivered mid-step), :class:`DelayAt` (straggling step),
  :class:`PoisonStateAt` (NaN into one param leaf — how *any* upstream
  NaN, a poisoned batch or a bad kernel, manifests to the jitted step:
  the loss/grad-norm go non-finite inside the very next dispatch),
  :class:`ScaleStateAt` (finite loss spike: params blown up by a factor).
* **Checkpoint writer** (``CheckpointManager.fault_hook``):
  :func:`kill_mid_write` dies after ``state.npz`` hits disk but before the
  manifest/rename ("power cut mid-write"); byte-level corruption of
  checkpoints already on disk via :func:`corrupt_checkpoint` /
  :func:`truncate_checkpoint`.
* **Serve engine** (``ServeEngine.fault_hook``): :class:`ServeFaults`
  poisons a chosen slot's logits with NaN on a chosen dispatch (the mask
  is applied *inside* the jitted chunk) and/or delays a chosen dispatch
  on the host (a stalled device, for the stall watchdog).

A note on "NaN-poisoned batch": the LM batches here are integer token
ids, which can never carry a NaN through the embedding lookup — so the
batch-poisoning fault is realized at the state boundary
(:class:`PoisonStateAt`), which produces the identical observable — a
non-finite loss/grad inside the jitted step — and therefore drives the
identical guard → rollback → skip-window recovery path.
"""
from __future__ import annotations

import dataclasses
import os
import signal
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np


class SimulatedCrash(RuntimeError):
    """Stands in for a hard process death in chaos tests (raised from a
    hook so the 'process' dies at a deterministic point)."""


# --------------------------------------------------------------------------
# Train-loop step faults (before_step hook)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CrashAt:
    """Raise SimulatedCrash when step ``step`` is about to run."""
    step: int
    fired: bool = False

    def __call__(self, s: int, state):
        if s == self.step and not self.fired:
            self.fired = True
            raise SimulatedCrash(f"injected crash before step {s}")


@dataclasses.dataclass
class SigtermAt:
    """Deliver SIGTERM to this process before step ``step`` (preemption:
    the loop's handler checkpoints and exits cleanly after the step)."""
    step: int
    fired: bool = False

    def __call__(self, s: int, state):
        if s == self.step and not self.fired:
            self.fired = True
            os.kill(os.getpid(), signal.SIGTERM)


@dataclasses.dataclass
class DelayAt:
    """Sleep ``seconds`` before step ``step`` (artificial straggler)."""
    step: int
    seconds: float

    def __call__(self, s: int, state):
        if s == self.step:
            time.sleep(self.seconds)


def _poison_first_leaf(state, value):
    """Replace the first (largest-ndim preference not needed) float param
    leaf with ``value`` — deterministic: tree order is canonical."""
    leaves, treedef = jax.tree_util.tree_flatten(state.params)
    for n, leaf in enumerate(leaves):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            leaves[n] = jnp.full_like(leaf, value)
            break
    return state._replace(
        params=jax.tree_util.tree_unflatten(treedef, leaves))


@dataclasses.dataclass
class PoisonStateAt:
    """NaN one param leaf before step ``step`` — the canonical way any
    upstream NaN (bad batch, bad kernel, optimizer blow-up) reaches the
    jitted step: its loss and grad-norm go non-finite in one dispatch."""
    step: int
    fired: bool = False

    def __call__(self, s: int, state):
        if s == self.step and not self.fired:
            self.fired = True
            return _poison_first_leaf(state, jnp.nan)


@dataclasses.dataclass
class ScaleStateAt:
    """Multiply all params by ``factor`` before step ``step`` — a *finite*
    divergence (loss spike) for the EWMA detector; the in-jit NaN guard
    alone cannot catch this."""
    step: int
    factor: float = 50.0
    fired: bool = False

    def __call__(self, s: int, state):
        if s == self.step and not self.fired:
            self.fired = True
            scaled = jax.tree.map(
                lambda p: (p * self.factor).astype(p.dtype)
                if jnp.issubdtype(jnp.asarray(p).dtype, jnp.floating)
                else p,
                state.params)
            return state._replace(params=scaled)


def train_hooks(*faults) -> Dict:
    """Compose step faults into a ``hooks`` dict for ``train()``.  Each
    fault is called as ``fault(step, state)`` and may return a replacement
    state (or None)."""
    def before_step(s: int, state):
        for f in faults:
            maybe = f(s, state)
            if maybe is not None:
                state = maybe
        return state
    return {"before_step": before_step}


# --------------------------------------------------------------------------
# Checkpoint faults
# --------------------------------------------------------------------------
def kill_mid_write(mgr, at_step: int, stage: str = "post_state") -> None:
    """Arm ``mgr`` to die mid-write of checkpoint ``at_step``: the fault
    fires after ``state.npz`` is on disk but before the manifest/rename
    (``stage='post_state'``), or with everything written but the rename
    pending (``stage='pre_rename'``).  Either way the atomic-rename
    contract means the previous checkpoint stays restorable and
    ``latest_good_step()`` never sees the partial one."""
    def hook(st: str, step: int):
        if st == stage and step == at_step:
            mgr.fault_hook = None  # one-shot
            raise SimulatedCrash(
                f"injected writer death at {st} of step {step}")
    mgr.fault_hook = hook


def _checkpoint_file(ckpt_dir: str, step: int, name: str = "state.npz"
                     ) -> str:
    return os.path.join(ckpt_dir, f"step_{step}", name)


def corrupt_checkpoint(ckpt_dir: str, step: int, *, offset: int = 1024,
                       nbytes: int = 64, name: str = "state.npz") -> str:
    """XOR-flip ``nbytes`` bytes of a checkpoint file in place (bit rot /
    torn write).  Returns the corrupted path."""
    path = _checkpoint_file(ckpt_dir, step, name)
    size = os.path.getsize(path)
    offset = min(offset, max(size - nbytes, 0))
    with open(path, "r+b") as f:
        f.seek(offset)
        chunk = bytearray(f.read(nbytes))
        f.seek(offset)
        f.write(bytes(b ^ 0xFF for b in chunk))
    return path


def truncate_checkpoint(ckpt_dir: str, step: int, *, keep_frac: float = 0.5,
                        name: str = "state.npz") -> str:
    """Truncate a checkpoint file to ``keep_frac`` of its size (crash
    while flushing).  Returns the truncated path."""
    path = _checkpoint_file(ckpt_dir, step, name)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(max(int(size * keep_frac), 1))
    return path


# --------------------------------------------------------------------------
# Serve-engine faults (ServeEngine.fault_hook protocol)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ServeFaults:
    """Chaos hook for ``ServeEngine``: called as ``hook(kind, idx)`` with
    ``kind in ('prefill', 'decode')`` and the dispatch index; returns
    ``{'poison': (B,) bool mask, 'delay_s': float}`` (both optional).

    ``poison_decode`` maps decode-dispatch index -> slot ids whose logits
    are NaN'd *inside* the jitted chunk (one-shot per entry);
    ``poison_prefill`` does the same for admission prefills;
    ``delay_decode`` maps decode-dispatch index -> host seconds (a stalled
    device, for the stall watchdog)."""
    max_batch: int
    poison_decode: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    poison_prefill: Dict[int, Sequence[int]] = dataclasses.field(
        default_factory=dict)
    delay_decode: Dict[int, float] = dataclasses.field(default_factory=dict)
    log: List[dict] = dataclasses.field(default_factory=list)

    def __call__(self, kind: str, idx: int) -> Optional[Dict]:
        act: Dict = {}
        table = (self.poison_decode if kind == "decode"
                 else self.poison_prefill)
        slots = table.pop(idx, None)  # one-shot
        if slots is not None:
            mask = np.zeros((self.max_batch,), bool)
            mask[list(slots)] = True
            act["poison"] = mask
        if kind == "decode" and idx in self.delay_decode:
            act["delay_s"] = self.delay_decode.pop(idx)
        if act:
            self.log.append({"kind": kind, "idx": idx, **act})
        return act or None
