"""Deterministic chaos-engineering utilities (repro.testing.faults)."""
