"""phi3.5-moe-42b-a6.6b [moe]: 32L d=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16e top-2.  [hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.config import ColaConfig, MoEConfig, ModelConfig, register


@register("phi3.5-moe-42b-a6.6b")
def phi35_moe():
    return ModelConfig(
        name="phi3.5-moe-42b-a6.6b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6400,
        vocab_size=32064,
        max_seq_len=131072,
        attention="gqa",
        rope="rope",
        moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25,
                      interleave_step=1),
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
    )
