"""qwen2-vl-2b [vlm]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 —
M-RoPE, dynamic resolution.  [arXiv:2409.12191]

Backbone-only per spec: the vision tower is a STUB — ``input_specs()``
provides precomputed patch embeddings plus 3D (temporal/height/width)
M-RoPE position ids.
"""
from repro.config import ColaConfig, ModelConfig, register


@register("qwen2-vl-2b")
def qwen2_vl():
    return ModelConfig(
        name="qwen2-vl-2b",
        family="vlm",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        max_seq_len=32768,
        attention="gqa",
        rope="mrope",
        rope_theta=1e6,
        qkv_bias=True,
        tie_embeddings=True,
        mrope_sections=(16, 24, 24),
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
        notes="vision tower stubbed: inputs are patch embeddings + 3D pos ids",
    )
