"""Architecture registry — importing this package registers every config."""
from repro.configs import (  # noqa: F401
    paper_llama,
    jamba_v01_52b,
    rwkv6_7b,
    internlm2_20b,
    llama3_2_1b,
    minicpm3_4b,
    qwen2_1_5b,
    llama4_maverick_400b_a17b,
    phi3_5_moe_42b_a6_6b,
    whisper_tiny,
    qwen2_vl_2b,
)

# Canonical ids of the 10 assigned architectures (dry-run sweep order).
ASSIGNED = [
    "jamba-v0.1-52b",
    "rwkv6-7b",
    "internlm2-20b",
    "llama3.2-1b",
    "minicpm3-4b",
    "qwen2-1.5b",
    "llama4-maverick-400b-a17b",
    "phi3.5-moe-42b-a6.6b",
    "whisper-tiny",
    "qwen2-vl-2b",
]
