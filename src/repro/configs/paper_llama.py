"""The paper's own LLaMA family (Table 5): 60M / 130M / 350M / 1B / 7B.

Dims follow the GaLore/SLTrain setup the paper inherits (Zhao et al. 2024,
Table 2 therein).  CoLA ranks r follow paper Table 5 exactly
(r/d = 128/512, 256/768, 256/1024, 512/2048, 1024/4096).
"""
from repro.config import ColaConfig, ModelConfig, register


def _llama(name, L, d, heads, dff, r, vocab=32000, seq=1024, kv=None):
    return ModelConfig(
        name=name,
        family="dense",
        num_layers=L,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv or heads,
        d_ff=dff,
        vocab_size=vocab,
        max_seq_len=seq,
        attention="gqa",
        rope="rope",
        parameterization="cola",
        cola=ColaConfig(rank_attn=r, rank_mlp=r,
                        sigma="both" if d < 1024 else "lowrank_only"),
        block_pattern=("attn",),
        notes="paper Table 5 config",
    )


@register("llama-60m")
def llama_60m():
    return _llama("llama-60m", 8, 512, 8, 1376, 128)


@register("llama-130m")
def llama_130m():
    return _llama("llama-130m", 12, 768, 12, 2048, 256)


@register("llama-350m")
def llama_350m():
    return _llama("llama-350m", 24, 1024, 16, 2736, 256)


@register("llama-1b")
def llama_1b():
    return _llama("llama-1b", 24, 2048, 32, 5461, 512)


@register("llama-7b")
def llama_7b():
    return _llama("llama-7b", 32, 4096, 32, 11008, 1024, seq=2048)
