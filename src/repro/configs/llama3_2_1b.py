"""llama3.2-1b [dense]: 16L d=2048 32H (GQA kv=8) d_ff=8192 vocab=128256.
[hf:meta-llama/Llama-3.2-1B]
"""
from repro.config import ColaConfig, ModelConfig, register


@register("llama3.2-1b")
def llama32_1b():
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        num_layers=16,
        d_model=2048,
        num_heads=32,
        num_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        max_seq_len=131072,
        attention="gqa",
        rope="rope",
        rope_theta=5e5,
        tie_embeddings=True,
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
        notes="closest to the paper's own LLaMA family; primary hillclimb cell",
    )
