"""qwen2-1.5b [dense]: 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
QKV bias.  [arXiv:2407.10671]
"""
from repro.config import ColaConfig, ModelConfig, register


@register("qwen2-1.5b")
def qwen2():
    return ModelConfig(
        name="qwen2-1.5b",
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        d_ff=8960,
        vocab_size=151936,
        max_seq_len=32768,
        attention="gqa",
        rope="rope",
        rope_theta=1e6,
        qkv_bias=True,
        tie_embeddings=True,
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
    )
