"""internlm2-20b [dense]: 48L d=6144 48H (GQA kv=8) d_ff=16384 vocab=92544.
[arXiv:2403.17297]
"""
from repro.config import ColaConfig, ModelConfig, register


@register("internlm2-20b")
def internlm2():
    return ModelConfig(
        name="internlm2-20b",
        family="dense",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        d_ff=16384,
        vocab_size=92544,
        max_seq_len=32768,
        attention="gqa",
        rope="rope",
        rope_theta=1e6,
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
    )
