"""minicpm3-4b [dense]: 62L d=2560 40H (kv=40) d_ff=6400 vocab=73448 — MLA.
[hf:openbmb/MiniCPM3-4B]

MLA (multi-head latent attention): KV compressed into a 256-dim latent +
32-dim rope key; CoLA applies to the dense factors of the latent projections
and the MLP.  vocab 73448 pads to 73472 for 16-way sharding.
"""
from repro.config import ColaConfig, MLAConfig, ModelConfig, register


@register("minicpm3-4b")
def minicpm3():
    return ModelConfig(
        name="minicpm3-4b",
        family="dense",
        num_layers=62,
        d_model=2560,
        num_heads=40,
        num_kv_heads=40,
        head_dim=64,
        d_ff=6400,
        vocab_size=73448,
        max_seq_len=32768,
        attention="mla",
        mla=MLAConfig(kv_lora_rank=256, q_lora_rank=768,
                      qk_rope_head_dim=32, qk_nope_head_dim=64,
                      v_head_dim=64),
        rope="rope",
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
    )
