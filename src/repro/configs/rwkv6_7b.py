"""rwkv6-7b [ssm]: 32L d=4096 attention-free, d_ff=14336 vocab=65536.
Finch — data-dependent decay.  [arXiv:2404.05892]

All time-mix (r/k/v/g/w/o) and channel-mix projections are CoLA
auto-encoders; the WKV6 recurrence itself is a Pallas kernel
(kernels/rwkv6_scan).
"""
from repro.config import ColaConfig, ModelConfig, register


@register("rwkv6-7b")
def rwkv6():
    return ModelConfig(
        name="rwkv6-7b",
        family="ssm",
        num_layers=32,
        d_model=4096,
        num_heads=64,          # rwkv6 head_size=64 -> 64 heads at d=4096
        num_kv_heads=64,
        head_dim=64,
        d_ff=14336,
        vocab_size=65536,
        max_seq_len=524288,
        attention="none",
        rope="none",
        block_pattern=("rwkv6",),
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
        notes="attention-free; O(1)-state decode; long_500k applicable",
    )
