"""whisper-tiny [audio]: 4L d=384 6H d_ff=1536 vocab=51865 — enc-dec,
conv frontend STUB.  [arXiv:2212.04356]

Per spec, the modality frontend is a stub: ``input_specs()`` provides
precomputed frame embeddings of shape (batch, frames, d_model).  4 encoder +
4 decoder layers.  Full attention => long_500k skipped (DESIGN.md).
CoLA rank = 96 < 128: MXU tile padding loss is quantified in the roofline.
"""
from repro.config import ColaConfig, ModelConfig, register


@register("whisper-tiny")
def whisper_tiny():
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        num_layers=4,
        num_encoder_layers=4,
        is_encoder_decoder=True,
        encoder_seq_len=1500,
        d_model=384,
        num_heads=6,
        num_kv_heads=6,
        d_ff=1536,
        vocab_size=51865,
        max_seq_len=448,
        attention="gqa",
        rope="none",  # whisper uses learned/sinusoidal abs positions
        parameterization="cola",
        cola=ColaConfig(sigma="both"),  # tiny model: paper Table 10 regime
        notes="conv frontend stubbed: inputs are frame embeddings",
    )
