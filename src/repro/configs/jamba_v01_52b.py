"""jamba-v0.1-52b [hybrid]: 32L d=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
MoE 16e top-2, Mamba:attn 1:7 interleave.  [arXiv:2403.19887]

Jamba layout: each period of 8 layers has 1 attention layer (index 3 within
the period) and 7 Mamba layers; MoE replaces the MLP on every 2nd layer
(e_step=2).  CoLA is applied to attention projections, expert FFN factors and
Mamba in/out projections (DESIGN.md §Arch-applicability).
"""
from repro.config import ColaConfig, MambaConfig, MoEConfig, ModelConfig, register

_PERIOD = ("mamba", "mamba", "mamba", "attn",
           "mamba", "mamba", "mamba", "mamba")


@register("jamba-v0.1-52b")
def jamba():
    return ModelConfig(
        name="jamba-v0.1-52b",
        family="hybrid",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        d_ff=14336,
        vocab_size=65536,
        max_seq_len=524288,
        attention="gqa",
        rope="none",  # jamba uses no positional embeddings (mamba provides order)
        block_pattern=_PERIOD,
        moe=MoEConfig(num_experts=16, top_k=2, capacity_factor=1.25,
                      interleave_step=2, dense_d_ff=14336),
        mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
        notes="hybrid Mamba+attn 1:7, MoE every 2nd layer",
    )
