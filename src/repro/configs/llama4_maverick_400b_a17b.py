"""llama4-maverick-400b-a17b [moe]: 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128e top-1, early fusion.  [hf:meta-llama/Llama-4-*]

Maverick interleaves dense and MoE layers (interleave_moe_layer_step=2) and
adds a shared expert on MoE layers; routed/shared expert d_ff=8192, dense
layers use d_ff=16384.  That layout reproduces the ~400B-total / ~17B-active
budget.  Experts are CoLA auto-encoders (beyond-paper: the paper lists MoE as
future work) sharded expert-parallel over the 'model' mesh axis.
"""
from repro.config import ColaConfig, MoEConfig, ModelConfig, register


@register("llama4-maverick-400b-a17b")
def llama4_maverick():
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        num_layers=48,
        d_model=5120,
        num_heads=40,
        num_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        max_seq_len=131072,
        attention="gqa",
        rope="rope",
        rope_theta=5e5,
        moe=MoEConfig(num_experts=128, top_k=1, capacity_factor=1.25,
                      interleave_step=2, dense_d_ff=16384,
                      shared_expert_d_ff=8192),
        parameterization="cola",
        cola=ColaConfig(sigma="lowrank_only"),
        notes="early-fusion multimodal in the original; text backbone here",
    )
