"""Loop-aware HLO cost analysis.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of
trip count (verified empirically: an 8-iteration scan of a matmul reports
1/8 of the true FLOPs), which would poison every roofline term for
scan-over-layers models.  This module re-derives costs from
``compiled.as_text()`` with loop scaling:

* parse every computation into a symbol table (instr name → shape),
* FLOPs from ``dot`` ops (2 × result_elems × contracted size),
* HBM bytes from top-level materializing ops (operands + results of
  fusion/dot/copy/dynamic-slice/… — each fusion is one kernel: reads its
  operands, writes its result; fused interiors are free),
* collective bytes with ring-factor per kind,
* a call graph (fusion ``calls=``, ``to_apply=``, while ``body=`` scaled by
  ``backend_config known_trip_count``) aggregated from ENTRY.

Validated against unrolled references in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import json
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_RESULT_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s*([a-z][\w\-]*)\(")
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*->")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]+(\d+)')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_FACTORS = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
            "all-to-all": 1.0, "collective-permute": 1.0}

# top-level ops that materialize HBM traffic (operands read + result write)
_MATERIALIZING = {
    "fusion", "dot", "copy", "convert", "transpose", "reduce", "broadcast",
    "dynamic-slice", "dynamic-update-slice", "slice", "concatenate", "pad",
    "select", "add", "multiply", "subtract", "divide", "exponential", "sort",
    "scatter", "gather", "iota", "reshape", "reverse", "rng-bit-generator",
    "compare", "convolution", "reduce-window", "select-and-scatter", "tanh",
    "custom-call",
} | set(_COLLECTIVES) | {c + "-start" for c in _COLLECTIVES}

_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "after-all", "domain", "partition-id", "replica-id",
         "opt-barrier", "optimization-barrier"}


def _shape_list(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_list(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # name -> type str


def parse_computations(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and line.endswith("{"):
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1))
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry = cur.name
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        # strip /*index=N*/ comments inside tuple types — their '=' breaks
        # the lazy type match for >5-element tuples (while-loop carries)
        if "/*" in line:
            line = re.sub(r"/\*.*?\*/", "", line)
        m = _RESULT_RE.match(line)
        if not m:
            continue
        name, type_str, op = m.groups()
        cur.instrs.append(Instr(name, type_str, op, line))
        cur.shapes[name] = type_str
    if entry is None:  # fall back: last computation
        entry = list(comps)[-1] if comps else ""
    return comps, entry


def _dot_flops(instr: Instr, comp: Computation) -> float:
    # result elements
    res_elems = 0
    for _, dims in _shape_list(instr.type_str):
        n = 1
        for d in dims:
            n *= d
        res_elems += n
    # contracted size from lhs operand shape + contracting dims
    after = instr.line.split("(", 1)[1]
    ops = _OPERAND_RE.findall(after)
    if not ops:
        return 0.0
    lhs_type = comp.shapes.get(ops[0], "")
    shapes = _shape_list(lhs_type)
    if not shapes:
        return 2.0 * res_elems  # unknown contraction; count as GEMV-ish
    lhs_dims = shapes[0][1]
    mc = _CONTRACT_RE.search(instr.line)
    contracted = 1
    if mc:
        for idx in mc.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contracted *= lhs_dims[int(idx)]
    return 2.0 * res_elems * contracted


def _operand_bytes(instr: Instr, comp: Computation) -> int:
    after = instr.line.split("(", 1)[1]
    # cut at the closing paren of the operand list (metadata follows)
    depth, end = 1, len(after)
    for i, ch in enumerate(after):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    total = 0
    for op_name in _OPERAND_RE.findall(after[:end]):
        t = comp.shapes.get(op_name)
        if t:
            total += _shape_bytes(t)
    return total


_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, other: "Cost"):
        self.flops += other.flops
        self.bytes += other.bytes
        self.coll_bytes += other.coll_bytes
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v
        return self

    def scaled(self, t: float) -> "Cost":
        return Cost(self.flops * t, self.bytes * t, self.coll_bytes * t,
                    {k: v * t for k, v in self.coll_by_kind.items()},
                    {k: v * t for k, v in self.coll_counts.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps, self.entry = parse_computations(hlo_text)
        self._memo: Dict[str, Cost] = {}

    def total(self) -> Cost:
        return self._comp_cost(self.entry)

    def _comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        comp = self.comps.get(name)
        if comp is None:
            return self._memo[name]
        total = Cost()
        for instr in comp.instrs:
            total += self._instr_cost(instr, comp)
        self._memo[name] = total
        return total

    def _instr_cost(self, instr: Instr, comp: Computation) -> Cost:
        c = Cost()
        op = instr.op
        if op in _FREE:
            return c
        # --- collectives --------------------------------------------------
        kind = op[:-6] if op.endswith("-start") else op
        if kind in _COLLECTIVES:
            if kind == "reduce-scatter":
                b = _operand_bytes(instr, comp)
            else:
                b = _shape_bytes(instr.type_str)
                if b == 0:
                    b = _operand_bytes(instr, comp)
            moved = _FACTORS[kind] * b
            c.coll_bytes += moved
            c.coll_by_kind[kind] = c.coll_by_kind.get(kind, 0.0) + moved
            c.coll_counts[kind] = c.coll_counts.get(kind, 0.0) + 1
            c.bytes += _shape_bytes(instr.type_str) + _operand_bytes(
                instr, comp)
            return c
        # --- control flow ---------------------------------------------------
        if op == "while":
            m = _TRIP_RE.search(instr.line)
            trip = float(m.group(1)) if m else 1.0
            mb = _BODY_RE.search(instr.line)
            if mb:
                c += self._comp_cost(mb.group(1)).scaled(trip)
            return c
        if op == "conditional":
            mb = _BRANCHES_RE.search(instr.line)
            if mb:
                branches = _OPERAND_RE.findall(mb.group(1))
                costs = [self._comp_cost(b) for b in branches]
                if costs:
                    c += max(costs, key=lambda x: x.flops + x.bytes)
            return c
        if op in ("call", "async-start"):
            mt = _TO_APPLY_RE.search(instr.line) or _CALLS_RE.search(
                instr.line)
            if mt:
                c += self._comp_cost(mt.group(1))
            return c
        # --- dot -------------------------------------------------------------
        if op == "dot":
            c.flops += _dot_flops(instr, comp)
            c.bytes += (_shape_bytes(instr.type_str)
                        + _operand_bytes(instr, comp))
            return c
        if op == "fusion":
            # one kernel: reads operands, writes result; recurse for dots
            mc = _CALLS_RE.search(instr.line)
            if mc:
                inner = self._comp_cost(mc.group(1))
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] = c.coll_by_kind.get(k, 0.0) + v
            c.bytes += (_shape_bytes(instr.type_str)
                        + _operand_bytes(instr, comp))
            return c
        if op in ("reduce", "scatter", "sort", "map", "select-and-scatter",
                  "reduce-window", "custom-call"):
            mt = _TO_APPLY_RE.search(instr.line)
            if mt:
                c += self._comp_cost(mt.group(1))
            c.bytes += (_shape_bytes(instr.type_str)
                        + _operand_bytes(instr, comp))
            return c
        if op in _MATERIALIZING:
            c.bytes += (_shape_bytes(instr.type_str)
                        + _operand_bytes(instr, comp))
        return c


def analyze(hlo_text: str) -> Dict[str, float]:
    """Loop-aware totals (per device, post-SPMD module)."""
    cost = HloCostModel(hlo_text).total()
    out = {"flops": cost.flops, "bytes": cost.bytes,
           "bytes_total": cost.coll_bytes}
    for k, v in cost.coll_by_kind.items():
        out[f"bytes_{k}"] = v
    for k, v in cost.coll_counts.items():
        out[f"count_{k}"] = v
    return out


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Back-compat wrapper: loop-aware collective bytes."""
    full = analyze(hlo_text)
    return {k: v for k, v in full.items()
            if k.startswith(("bytes_", "count_"))} | {
            "bytes_total": full["bytes_total"]}
