"""Three-term roofline from the compiled dry-run artifact (TPU v5e targets).

    compute_s    = HLO_FLOPs_per_chip / peak_bf16
    memory_s     = HLO_bytes_per_chip / hbm_bw
    collective_s = collective_bytes_per_chip / ici_bw

``compiled.cost_analysis()`` on a post-SPMD executable reports *per-device*
flops/bytes (validated in tests/test_roofline.py against a hand-computed
sharded matmul); collective bytes come from analysis/hlo.py.

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per training step —
3 matmul passes (fwd + 2 bwd) × 2 MAC.  The ratio MODEL_FLOPS/HLO_FLOPs
exposes remat/redundancy waste (CoLA-M recompute shows up here, as the
paper's Table 4 predicts).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional

from repro.analysis.hlo import collective_bytes
from repro.config import ModelConfig, ShapeSpec
from repro.launch.mesh import V5E


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    peak_mem_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float
    model_flops_ratio: float
    coll_detail: Dict[str, float]
    variant: str = "baseline"

    def to_json(self) -> Dict:
        return dataclasses.asdict(self)

    @property
    def step_s(self) -> float:
        """Roofline step time = max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction: model_flops-time / roofline step time."""
        ideal = (self.model_flops / self.n_chips) / V5E["peak_bf16_flops"]
        return ideal / self.step_s if self.step_s > 0 else 0.0


# --------------------------------------------------------------------------
# Parameter / FLOP counting for MODEL_FLOPS
# --------------------------------------------------------------------------
def count_params(cfg: ModelConfig, active_only: bool = False) -> float:
    """Approximate parameter count from config arithmetic (matmul sites
    only — embeddings excluded per Kaplan et al. convention)."""
    import jax
    from repro.models.model import build_model
    from repro.models.common import ParamDef, is_def

    model = build_model(cfg)
    defs = model.defs()
    total = 0.0
    expert_total = 0.0
    for path, d in jax.tree_util.tree_flatten_with_path(
            defs, is_leaf=is_def)[0]:
        keys = [str(getattr(p, "key", "")) for p in path]
        n = 1
        for s in d.shape:
            n *= s
        if "embed" in keys[:1] or "head" in keys[:1]:
            continue
        if "experts" in keys:
            expert_total += n
        else:
            total += n
    if active_only and cfg.moe.enabled:
        frac = cfg.moe.top_k / cfg.moe.num_experts
        return total + expert_total * frac
    return total + expert_total


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N_active·tokens for a train step; 2·N_active·tokens for fwd-only."""
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


# --------------------------------------------------------------------------
def build_roofline(*, arch: str, shape: ShapeSpec, mesh_name: str,
                   n_chips: int, cost: Dict, hlo_text: str,
                   peak_mem: float, cfg: ModelConfig,
                   variant: str = "baseline") -> Roofline:
    # loop-aware HLO analysis (XLA's cost_analysis counts while bodies once;
    # analysis/hlo.py rescales by known_trip_count — see its docstring)
    from repro.analysis.hlo import analyze
    full = analyze(hlo_text)
    coll = full
    flops = float(full["flops"])
    byts = float(full["bytes"])
    compute_s = flops / V5E["peak_bf16_flops"]
    memory_s = byts / V5E["hbm_bw"]
    coll_s = coll["bytes_total"] / V5E["ici_bw"]
    bound = max((("compute", compute_s), ("memory", memory_s),
                 ("collective", coll_s)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    ratio = (mf / n_chips) / flops if flops else 0.0
    detail = {k: v for k, v in coll.items()
              if k.startswith(("bytes_", "count_"))}
    detail["bytes_total"] = coll["bytes_total"]
    return Roofline(
        arch=arch, shape=shape.name, mesh=mesh_name, n_chips=n_chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=coll["bytes_total"],
        peak_mem_per_chip=peak_mem,
        compute_s=compute_s, memory_s=memory_s, collective_s=coll_s,
        bound=bound, model_flops=mf, model_flops_ratio=ratio,
        coll_detail=detail, variant=variant)


def format_table(rows) -> str:
    hdr = (f"{'arch':26s} {'shape':12s} {'mesh':6s} {'var':10s} "
           f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} "
           f"{'bound':>10s} {'MF_ratio':>8s} {'roofl%':>7s} {'mem_GB':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:26s} {r.shape:12s} {r.mesh:6s} {r.variant:10s} "
            f"{r.compute_s:10.4g} {r.memory_s:10.4g} {r.collective_s:10.4g} "
            f"{r.bound:>10s} {r.model_flops_ratio:8.3f} "
            f"{100*r.roofline_fraction:6.1f}% "
            f"{r.peak_mem_per_chip/1e9:7.2f}")
    return "\n".join(lines)
