import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede any jax-importing module: jax locks the device count at
# first initialization.  Everything below this line may import jax.
import argparse
import dataclasses
import json
import time
import traceback
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import roofline as roofline_mod
from repro.config import (LM_SHAPES, ModelConfig, ShapeSpec, TrainConfig,
                          applicable_shapes, get_config)
from repro.distributed.sharding import (mesh_env, named_sharding_tree,
                                        param_sharding_tree)
from repro.launch.mesh import make_production_mesh
from repro.models.model import build_model
from repro.train import step as step_mod

"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh)
cell on placeholder meshes — 256-chip single-pod (16,16) and 512-chip
two-pod (2,16,16) — and record memory_analysis / cost_analysis /
collective-bytes for the roofline (EXPERIMENTS.md §Dry-run / §Roofline).

Variants (the §Perf hillclimb lever):
  --profile  baseline|megatron|fsdp   sharding rules (DESIGN.md §5)
  --remat    none|full|cola_m|dots    activation checkpointing policy
  --param    cola|dense|lora|sltrain  parameterization
"""


def _batch_axes_for(name: str):
    if name in ("tokens", "labels"):
        return ("batch", "seq")
    if name in ("inputs_embeds", "frames"):
        return ("batch", "seq", "embed")
    if name == "position_ids":
        return ("null", "batch", "seq")
    raise KeyError(name)


def _mesh(mesh_name: str):
    return make_production_mesh(multi_pod=(mesh_name == "pod2"))


def lower_cell(cfg: ModelConfig, shape: ShapeSpec, mesh_name: str,
               profile: str) -> Tuple["jax.stages.Lowered", ModelConfig]:
    """Build + lower the step function for one cell under the mesh env."""
    model = build_model(cfg)
    if shape.kind == "train":
        tc = TrainConfig(steps=1000, global_batch=shape.global_batch,
                         seq_len=shape.seq_len)
        train_step = step_mod.build_train_step(model, tc)
        state_abs = step_mod.abstract_train_state(model, tc)
        state_axes = step_mod.train_state_axes(model, tc)
        batch_abs = model.input_specs(shape)
        state_sh = param_sharding_tree(state_axes, state_abs)
        batch_sh = named_sharding_tree(
            {k: _batch_axes_for(k) for k in batch_abs}, batch_abs)
        fn = jax.jit(train_step, in_shardings=(state_sh, batch_sh),
                     out_shardings=(state_sh, None), donate_argnums=0)
        return fn.lower(state_abs, batch_abs)

    params_abs = model.abstract()
    params_sh = param_sharding_tree(model.axes(), params_abs)
    if shape.kind == "prefill":
        batch_abs = model.input_specs(shape)
        caches_abs = model.abstract_caches(shape.global_batch, shape.seq_len)
        caches_sh = named_sharding_tree(
            model.cache_axes(shape.global_batch, shape.seq_len), caches_abs)
        batch_sh = named_sharding_tree(
            {k: _batch_axes_for(k) for k in batch_abs}, batch_abs)
        fn = jax.jit(model.prefill,
                     in_shardings=(params_sh, batch_sh, caches_sh),
                     donate_argnums=2)
        return fn.lower(params_abs, batch_abs, caches_abs)

    # decode: one token over a cache of length seq_len
    B = shape.global_batch
    tokens_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    caches_abs = model.abstract_caches(B, shape.seq_len)
    caches_sh = named_sharding_tree(
        model.cache_axes(B, shape.seq_len), caches_abs)
    tok_sh = named_sharding_tree({"t": ("batch", "seq")},
                                 {"t": tokens_abs})["t"]
    fn = jax.jit(model.decode_step,
                 in_shardings=(params_sh, tok_sh, caches_sh, tok_sh),
                 donate_argnums=2)
    return fn.lower(params_abs, tokens_abs, caches_abs, pos_abs)


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             profile: str = "baseline", remat: str = "cola_m",
             param: str = "cola", variant: str = "baseline",
             with_roofline: bool = True, verbose: bool = True) -> Dict:
    cfg = get_config(arch).with_overrides(parameterization=param, remat=remat)
    shape = LM_SHAPES[shape_name]
    if cfg.max_seq_len < shape.seq_len:
        cfg = cfg.with_overrides(max_seq_len=shape.seq_len)
    mesh = _mesh(mesh_name)
    n_chips = mesh.devices.size
    t0 = time.time()
    with mesh_env(mesh, profile):
        lowered = lower_cell(cfg, shape, mesh_name, profile)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if verbose:
            print(f"  memory_analysis: {mem}")
            print(f"  cost_analysis: flops={cost.get('flops', 0):.4g} "
                  f"bytes={cost.get('bytes accessed', 0):.4g}")
        mem_rec = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            try:
                mem_rec[attr] = int(getattr(mem, attr))
            except Exception:
                pass
        peak = (mem_rec.get("argument_size_in_bytes", 0)
                - mem_rec.get("alias_size_in_bytes", 0)
                + mem_rec.get("output_size_in_bytes", 0)
                + mem_rec.get("temp_size_in_bytes", 0))
        rec = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "variant": variant, "profile": profile, "remat": remat,
            "param": param, "n_chips": int(n_chips),
            "lower_s": t_lower, "compile_s": t_compile,
            "cost": {k: float(v) for k, v in cost.items()
                     if isinstance(v, (int, float))},
            "memory": mem_rec,
            "peak_bytes_per_chip": int(peak),
        }
        if with_roofline:
            hlo = compiled.as_text()
            rl = roofline_mod.build_roofline(
                arch=arch, shape=shape, mesh_name=mesh_name,
                n_chips=n_chips, cost=cost, hlo_text=hlo, peak_mem=peak,
                cfg=cfg, variant=variant)
            rec["roofline"] = rl.to_json()
            rec["roofline"]["step_s"] = rl.step_s
            rec["roofline"]["roofline_fraction"] = rl.roofline_fraction
            del hlo
    return rec


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all' (assigned 10)")
    ap.add_argument("--shapes", default="all")
    ap.add_argument("--mesh", default="pod1,pod2")
    ap.add_argument("--profile", default="baseline")
    ap.add_argument("--remat", default="cola_m")
    ap.add_argument("--param", default="cola")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    if args.arch == "all":
        from repro.configs import ASSIGNED
        archs = ASSIGNED
    else:
        archs = args.arch.split(",")
    meshes = args.mesh.split(",")
    outdir = os.path.join(args.out, args.variant)
    os.makedirs(outdir, exist_ok=True)

    n_ok = n_fail = n_skip = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = ([s.name for s in applicable_shapes(cfg)]
                  if args.shapes == "all" else args.shapes.split(","))
        for shape_name in shapes:
            if (shape_name == "long_500k" and not cfg.sub_quadratic()):
                print(f"[skip] {arch} × long_500k (full attention — "
                      f"DESIGN.md §Arch-applicability)")
                continue
            for mesh_name in meshes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = os.path.join(outdir, tag + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached] {tag}")
                    n_skip += 1
                    continue
                print(f"[cell] {tag} (variant={args.variant})", flush=True)
                try:
                    rec = run_cell(arch, shape_name, mesh_name,
                                   profile=args.profile, remat=args.remat,
                                   param=args.param, variant=args.variant)
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                    rl = rec.get("roofline", {})
                    print(f"  ok: compile={rec['compile_s']:.1f}s "
                          f"peak={rec['peak_bytes_per_chip']/1e9:.2f}GB/chip "
                          f"bound={rl.get('bound','-')} "
                          f"roofline={100*rl.get('roofline_fraction',0):.1f}%",
                          flush=True)
                    n_ok += 1
                except Exception as e:
                    n_fail += 1
                    print(f"  FAIL: {type(e).__name__}: {e}")
                    with open(os.path.join(outdir, tag + ".err"), "w") as f:
                        f.write(traceback.format_exc())
    print(f"dry-run complete: ok={n_ok} cached={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
