"""Serving launcher: drives the continuous-batching scheduler with a
synthetic ragged request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 16 --min-prompt 4 --max-prompt 24 --new-tokens 16 \
        --slots 4 --decode-block 8

Each request draws a prompt length uniformly from [min-prompt, max-prompt]
and a generation budget from [1, new-tokens]; the scheduler left-pads the
ragged admissions, recycles slots on EOS/length, and decodes k tokens per
device dispatch through the jitted ``lax.scan`` loop.

Tensor-parallel serving: ``--profile baseline|megatron`` builds a
(data, model) mesh over the visible devices (virtual CPU devices work —
set XLA_FLAGS=--xla_force_host_platform_device_count=8) and enables the
fused sharded CoLA kernels, so every decode dispatch runs the per-shard
decode / decode_split Pallas bodies with the profile's collectives.
Paged KV is on by default for attention-only architectures
(``--dense-cache`` restores the dense (B, max_seq) slot layout).

``--speculate`` switches decode to speculative rounds: a low-rank
self-draft (``--draft-alpha`` rank truncation and/or ``--draft-depth``
period truncation — views into the same weights, zero extra weight HBM)
proposes ``--spec-window - 1`` tokens, the full model verifies the whole
window in one dispatch, and the greedy output stream stays bit-identical
to a ``--no-speculate`` run.

``--weight-dtype int8|int4`` quantizes the CoLA A/B factors once at
engine build and streams packed q-blocks + f32 scales through the decode
kernels (dequantized in-VMEM, f32 accumulation unchanged) — roughly 2×/4×
fewer weight-stream bytes per token.  Composes with ``--profile`` (the
q/scale arrays are sharded, scales commute) and ``--speculate`` (the
draft gathers q codes, sharing scales).  A ``quantized:`` line reports
the quant decode counters so CI can assert no silent bf16 fallback.
"""
from __future__ import annotations

import argparse
import contextlib
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--param", default=None,
                    help="parameterization override (cola|dense|...)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16,
                    help="max generation budget per request")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching slot count (decode batch)")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="tokens decoded per device dispatch")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="prompt tokens consumed per mixed dispatch while "
                         "other slots keep decoding (default: the prompt "
                         "bucket, 16); smaller chunks bound the decode "
                         "stall an admission can cause")
    ap.add_argument("--no-overlap", action="store_true",
                    help="escape hatch: restore the admit-then-decode "
                         "engine (each admission prefills its whole "
                         "prompt in one dispatch, fencing the decode "
                         "stream).  Greedy streams are bit-identical "
                         "either way; use this to isolate overlap when "
                         "debugging latency or dispatch-count drift")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="treat this token id as EOS (early slot recycle)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline (expired "
                         "requests finish with finish_reason='timeout')")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-queue bound; overflow requests finish "
                         "with finish_reason='rejected'")
    ap.add_argument("--assert-timeout", action="store_true",
                    help="append one request with a 0-second deadline and "
                         "exit nonzero unless it reports "
                         "finish_reason='timeout' (CI guardrail smoke)")
    ap.add_argument("--profile", default="none",
                    choices=("none", "baseline", "megatron"),
                    help="tensor-parallel sharding profile; builds a "
                         "(data, model) mesh over the visible devices and "
                         "enables the fused sharded CoLA kernels")
    ap.add_argument("--page-size", type=int, default=16,
                    help="paged-KV tokens per page")
    ap.add_argument("--dense-cache", action="store_true",
                    help="disable paged KV (dense (B, max_seq) slot caches)")
    spec = ap.add_mutually_exclusive_group()
    spec.add_argument("--speculate", action="store_true",
                      help="speculative decoding: a truncated-rank/-depth "
                           "self-draft (views into the same weights) "
                           "drafts, the full model verifies the window in "
                           "one dispatch; greedy streams stay bit-"
                           "identical to plain decode")
    spec.add_argument("--no-speculate", action="store_true",
                      help="explicit plain decode (CI parity runs)")
    ap.add_argument("--draft-alpha", type=float, default=None,
                    help="rank-energy level for the draft's per-site rank "
                         "truncation (default 0.95 when --speculate sets "
                         "no depth)")
    ap.add_argument("--draft-depth", type=int, default=None,
                    help="depth truncation: keep every p-th period "
                         "(stride) or the first ceil(n/p) (prefix)")
    ap.add_argument("--draft-depth-mode", default="stride",
                    choices=("stride", "prefix"))
    ap.add_argument("--spec-window", type=int, default=4,
                    help="verified positions per speculative round "
                         "(draft proposes spec-window - 1)")
    ap.add_argument("--weight-dtype", default="bf16",
                    choices=("bf16", "int8", "int4"),
                    help="quantize the CoLA A/B factors at engine build "
                         "and stream int8 / nibble-packed int4 q-blocks "
                         "+ f32 scales through the decode kernels "
                         "(dequantized in-VMEM; KV caches unaffected)")
    args = ap.parse_args()

    import dataclasses

    import jax
    import numpy as np
    from repro.config import get_config
    from repro.serve.engine import make_engine
    from repro.serve.scheduler import Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.param:
        cfg = cfg.with_overrides(parameterization=args.param)
    mesh = None
    if args.profile != "none":
        n = jax.device_count()
        model = next(m for m in (8, 4, 2, 1) if n % m == 0)
        mesh = jax.make_mesh((n // model, model), ("data", "model"))
        # TP serving routes CoLA sites through the fused sharded kernels
        cfg = cfg.with_overrides(cola=dataclasses.replace(
            cfg.cola, use_fused_kernel=True))
        print(f"profile={args.profile} mesh=(data={n // model}, "
              f"model={model}) over {n} devices")
    max_seq = args.max_prompt + args.new_tokens + 1  # +1: pad-parking slot
    eng = make_engine(cfg, max_batch=args.slots, max_seq=max_seq,
                      seed=args.seed, decode_block=args.decode_block,
                      prefill_chunk=args.prefill_chunk,
                      overlap=not args.no_overlap,
                      mesh=mesh,
                      profile=args.profile if mesh is not None
                      else "baseline",
                      paged=False if args.dense_cache else None,
                      page_size=args.page_size,
                      speculate=args.speculate,
                      draft_alpha=args.draft_alpha,
                      draft_depth=args.draft_depth,
                      draft_depth_mode=args.draft_depth_mode,
                      spec_window=args.spec_window,
                      weight_dtype=args.weight_dtype)
    eng.max_queue = args.max_queue
    if eng.speculating:
        d = eng.draft_plan.describe()
        ranks = [r for _, r in sorted(d["site_ranks"].items())]
        print(f"speculate: window={args.spec_window} alpha={d['alpha']} "
              f"depth={d['depth']}({d['depth_mode']}) "
              f"keep_periods={len(d['keep_periods'])}/{d['n_periods']} "
              f"site ranks (full,draft)={ranks}")

    rng = np.random.RandomState(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.randint(args.min_prompt, args.max_prompt + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.randint(1, cfg.vocab_size, (plen,)).astype(np.int32),
            max_new_tokens=int(rng.randint(1, args.new_tokens + 1)),
            temperature=args.temperature,
            eos_id=args.eos_id,
            deadline_s=args.deadline_s))
    if args.assert_timeout:
        # a request that is already past its deadline at submit must come
        # back as a typed timeout response, never an exception
        reqs.append(Request(
            uid=len(reqs),
            prompt=rng.randint(1, cfg.vocab_size,
                               (args.min_prompt,)).astype(np.int32),
            max_new_tokens=args.new_tokens, deadline_s=0.0))

    force = contextlib.nullcontext()
    if (mesh is not None or args.weight_dtype != "bf16") \
            and jax.default_backend() != "tpu":
        # the point of --profile is the sharded kernel path, and quantized
        # streaming is Pallas-only (no ref math, no silent fallback);
        # off-TPU both mean interpret-mode Pallas (as in the parity tests)
        from repro.kernels.cola_ae import ops as _ops
        force = _ops.force_impl("pallas", True)

    t0 = time.perf_counter()
    with force:
        resps = eng.serve(
            reqs, rng=jax.random.PRNGKey(args.seed)
            if args.temperature > 0 else None)
    wall = time.perf_counter() - t0

    stats = eng.stats()
    n_tok = sum(len(r.tokens) for r in resps)
    by_reason = {}
    for r in resps:
        by_reason[r.finish_reason] = by_reason.get(r.finish_reason, 0) + 1
    print(f"served {len(resps)} requests / {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / wall:.1f} tok/s incl. compile)  finish={by_reason}")
    print(f"dispatches: {stats['prefill_dispatches']} prefill + "
          f"{stats['decode_dispatches']} decode "
          f"({stats['decode_steps']} steps scanned, "
          f"k<={args.decode_block})")
    print(f"overlap: {'on' if eng.overlap else 'off'} "
          f"prefill_chunk={eng.prefill_chunk} "
          f"mixed_dispatches={stats['mixed_dispatches']} "
          f"prefill_chunks={stats['prefill_chunks']}")
    if "ttft_p50_s" in stats:
        print(f"ttft p50={stats['ttft_p50_s']*1e3:.2f}ms "
              f"p95={stats['ttft_p95_s']*1e3:.2f}ms "
              f"p99={stats['ttft_p99_s']*1e3:.2f}ms (arrival -> first "
              f"token, incl. queue wait)")
    if "itl_p50_s" in stats:
        print(f"inter-token p50={stats['itl_p50_s']*1e3:.2f}ms "
              f"p95={stats['itl_p95_s']*1e3:.2f}ms "
              f"p99={stats['itl_p99_s']*1e3:.2f}ms (per-request arrival "
              f"gaps; tail = cross-dispatch stalls)")
    if "peak_pages" in stats:
        hbm = eng.cache_hbm_bytes()
        print(f"paged KV: page_size={stats['page_size']} "
              f"peak_pages={stats['peak_pages']} "
              f"cache HBM {hbm['paged_bytes'] / 1e6:.2f}MB peak vs "
              f"{hbm['dense_bytes'] / 1e6:.2f}MB dense")
    if "per_token_p50_s" in stats:
        print(f"per-token latency p50={stats['per_token_p50_s']*1e3:.2f}ms "
              f"p95={stats['per_token_p95_s']*1e3:.2f}ms (steady-state)")
    if eng.speculating:
        print(f"speculative: rounds={stats['spec_rounds']} "
              f"drafted={stats['spec_drafted']} "
              f"accepted={stats['spec_accepted']} "
              f"rejected={stats['spec_rejected']} "
              f"acceptance={stats['spec_acceptance_rate']:.3f} "
              f"mean_emitted={stats['spec_mean_emitted']:.2f}/round")
    if args.weight_dtype != "bf16":
        from repro.kernels.cola_ae import ops as _ops
        n_q = sum(v for k, v in _ops.DISPATCH.items()
                  if "quant_" in k and (k.endswith("_decode")
                                        or k.endswith("_decode_split")))
        n_bare = sum(
            v for k, v in _ops.DISPATCH.items()
            if "quant" not in k and (k.endswith("infer_decode")
                                     or k.endswith("infer_decode_split")))
        print(f"quantized: weight_dtype={args.weight_dtype} "
              f"quant_infer_decode={n_q} bare_bf16_decode={n_bare}")
    print(f"guardrails: timeouts={stats['timeouts']} "
          f"rejected={stats['rejected']} quarantines={stats['quarantines']} "
          f"stalls={stats['stalls']}")
    r0 = resps[0]
    print(f"first request: prompt_len={r0.prompt_len} "
          f"reason={r0.finish_reason} tokens={r0.tokens[:12].tolist()}")
    if args.assert_timeout:
        last = resps[-1]
        assert last.finish_reason == "timeout", (
            f"deadline-exceeded request reported "
            f"finish_reason={last.finish_reason!r}, want 'timeout'")
        print(f"assert-timeout OK: uid={last.uid} finished "
              f"'{last.finish_reason}' with {len(last.tokens)} tokens")


if __name__ == "__main__":
    main()
