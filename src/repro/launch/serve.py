"""Serving launcher: batched generation through the KV-cache engine.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 32
"""
from __future__ import annotations

import argparse

import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--param", default=None)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    from repro.config import get_config
    from repro.serve.engine import make_engine

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.param:
        cfg = cfg.with_overrides(parameterization=args.param)
    max_seq = args.prompt_len + args.new_tokens
    eng = make_engine(cfg, max_batch=args.batch, max_seq=max_seq,
                      seed=args.seed)
    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(1, cfg.vocab_size,
                          (args.batch, args.prompt_len)).astype(np.int32)
    toks, stats = eng.generate(
        prompts, args.new_tokens, temperature=args.temperature,
        rng=jax.random.PRNGKey(args.seed) if args.temperature > 0 else None)
    print(f"generated {toks.shape} tokens")
    print(f"prefill: {stats['prefill_s']*1e3:.1f} ms   "
          f"decode: {stats['decode_tok_per_s']:.1f} tok/s")
    print("first row:", toks[0][:16].tolist())


if __name__ == "__main__":
    main()
