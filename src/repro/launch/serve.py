"""Serving launcher: drives the continuous-batching scheduler with a
synthetic ragged request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
        --requests 16 --min-prompt 4 --max-prompt 24 --new-tokens 16 \
        --slots 4 --decode-block 8

Each request draws a prompt length uniformly from [min-prompt, max-prompt]
and a generation budget from [1, new-tokens]; the scheduler left-pads the
ragged admissions, recycles slots on EOS/length, and decodes k tokens per
device dispatch through the jitted ``lax.scan`` loop.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--param", default=None,
                    help="parameterization override (cola|dense|...)")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--min-prompt", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=16,
                    help="max generation budget per request")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching slot count (decode batch)")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="tokens decoded per device dispatch")
    ap.add_argument("--eos-id", type=int, default=None,
                    help="treat this token id as EOS (early slot recycle)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline (expired "
                         "requests finish with finish_reason='timeout')")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-queue bound; overflow requests finish "
                         "with finish_reason='rejected'")
    ap.add_argument("--assert-timeout", action="store_true",
                    help="append one request with a 0-second deadline and "
                         "exit nonzero unless it reports "
                         "finish_reason='timeout' (CI guardrail smoke)")
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.config import get_config
    from repro.serve.engine import make_engine
    from repro.serve.scheduler import Request

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    if args.param:
        cfg = cfg.with_overrides(parameterization=args.param)
    max_seq = args.max_prompt + args.new_tokens + 1  # +1: pad-parking slot
    eng = make_engine(cfg, max_batch=args.slots, max_seq=max_seq,
                      seed=args.seed, decode_block=args.decode_block)
    eng.max_queue = args.max_queue

    rng = np.random.RandomState(args.seed)
    reqs = []
    for uid in range(args.requests):
        plen = int(rng.randint(args.min_prompt, args.max_prompt + 1))
        reqs.append(Request(
            uid=uid,
            prompt=rng.randint(1, cfg.vocab_size, (plen,)).astype(np.int32),
            max_new_tokens=int(rng.randint(1, args.new_tokens + 1)),
            temperature=args.temperature,
            eos_id=args.eos_id,
            deadline_s=args.deadline_s))
    if args.assert_timeout:
        # a request that is already past its deadline at submit must come
        # back as a typed timeout response, never an exception
        reqs.append(Request(
            uid=len(reqs),
            prompt=rng.randint(1, cfg.vocab_size,
                               (args.min_prompt,)).astype(np.int32),
            max_new_tokens=args.new_tokens, deadline_s=0.0))

    t0 = time.perf_counter()
    resps = eng.serve(
        reqs, rng=jax.random.PRNGKey(args.seed)
        if args.temperature > 0 else None)
    wall = time.perf_counter() - t0

    stats = eng.stats()
    n_tok = sum(len(r.tokens) for r in resps)
    by_reason = {}
    for r in resps:
        by_reason[r.finish_reason] = by_reason.get(r.finish_reason, 0) + 1
    print(f"served {len(resps)} requests / {n_tok} tokens in {wall:.2f}s "
          f"({n_tok / wall:.1f} tok/s incl. compile)  finish={by_reason}")
    print(f"dispatches: {stats['prefill_dispatches']} prefill + "
          f"{stats['decode_dispatches']} decode "
          f"(k={args.decode_block} tokens each)")
    if "per_token_p50_s" in stats:
        print(f"per-token latency p50={stats['per_token_p50_s']*1e3:.2f}ms "
              f"p95={stats['per_token_p95_s']*1e3:.2f}ms (steady-state)")
    print(f"guardrails: timeouts={stats['timeouts']} "
          f"rejected={stats['rejected']} quarantines={stats['quarantines']} "
          f"stalls={stats['stalls']}")
    r0 = resps[0]
    print(f"first request: prompt_len={r0.prompt_len} "
          f"reason={r0.finish_reason} tokens={r0.tokens[:12].tolist()}")
    if args.assert_timeout:
        last = resps[-1]
        assert last.finish_reason == "timeout", (
            f"deadline-exceeded request reported "
            f"finish_reason={last.finish_reason!r}, want 'timeout'")
        print(f"assert-timeout OK: uid={last.uid} finished "
              f"'{last.finish_reason}' with {len(last.tokens)} tokens")


if __name__ == "__main__":
    main()
