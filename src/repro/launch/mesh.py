"""Production mesh shapes (TPU v5e).

single-pod: (16, 16) = ('data', 'model') — 256 chips
multi-pod : (2, 16, 16) = ('pod', 'data', 'model') — 512 chips

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialization).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    return jax.make_mesh(shape, axes)


# v5e hardware constants used by the roofline (per chip)
V5E = {
    "peak_bf16_flops": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "hbm_bytes": 16e9,           # capacity
    "ici_bw": 50e9,              # B/s per link direction (~3D torus link)
}
