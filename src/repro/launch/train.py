"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama-60m \
        --steps 200 --batch 8 --seq 256 --param cola --remat cola_m

On a real fleet this runs under `jax.distributed.initialize()` with the
production mesh; on CPU it runs single-device (or a forced-device test mesh
via --devices N --mesh dxm).
"""
from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--param", default=None,
                    help="dense|cola|lora|sltrain (default: config's)")
    ap.add_argument("--remat", default=None, help="none|full|cola_m|dots")
    ap.add_argument("--fused", action="store_true",
                    help="train through the fused Pallas CoLA-AE path "
                         "(fwd+bwd kernels; TPU). Composes with --mesh/"
                         "--profile: under a 'model' axis the kernels run "
                         "per-shard via shard_map with a collective-aware "
                         "VJP (no unfused fallback)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--galore-rank", type=int, default=0)
    ap.add_argument("--grad-compression", default="none")
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--checkpoint-every", type=int, default=0)
    ap.add_argument("--eval-every", type=int, default=0)
    ap.add_argument("--log", default="")
    ap.add_argument("--data", default="synthetic")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--devices", type=int, default=0,
                    help="force N host devices (CPU mesh testing)")
    ap.add_argument("--mesh", default="",
                    help="e.g. 2x4 => ('data','model') mesh on 8 devices")
    ap.add_argument("--profile", default="megatron",
                    help="sharding profile: baseline|megatron|fsdp")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import dataclasses

    import jax  # after XLA_FLAGS
    from repro.config import TrainConfig, get_config
    from repro.distributed.sharding import mesh_env
    from repro.train.loop import train

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    over = {}
    if args.param:
        over["parameterization"] = args.param
    if args.remat:
        over["remat"] = args.remat
    if args.fused:
        over["cola"] = dataclasses.replace(cfg.cola, use_fused_kernel=True)
    if over:
        cfg = cfg.with_overrides(**over)

    tc = TrainConfig(
        steps=args.steps, global_batch=args.batch, seq_len=args.seq,
        learning_rate=args.lr, optimizer=args.optimizer,
        galore_rank=args.galore_rank, grad_compression=args.grad_compression,
        microbatch=args.microbatch, checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every, eval_every=args.eval_every,
        data=args.data, seed=args.seed)

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        axes = ("data", "model")[:len(dims)] if len(dims) <= 2 else \
            ("pod", "data", "model")
        mesh = jax.make_mesh(dims, axes)
        with mesh_env(mesh, args.profile):
            out = train(cfg, tc, log_path=args.log or None)
    else:
        out = train(cfg, tc, log_path=args.log or None)
    print({k: v for k, v in out.items() if k != "state"})


if __name__ == "__main__":
    main()
