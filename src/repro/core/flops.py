"""Analytical compute models — paper Tables 2 & 3 (and Appendix B), exact.

Per decoder layer, token batch n, width d, FFN width d_ff, rank r:

    C_full    = 24nd² + 12n²d + 18ndd_ff
    C_CoLA    = 48ndr + 12n²d + 18nr(d + d_ff)
    C_LoRA    = 16nd² + 12n²d + 12ndd_ff + 48ndr + 18nr(d+d_ff)
    C_SLTrain = C_full + 24d²r + 18dd_ff r
    C_GaLore  = C_full + 16d²r + 12dd_ff r

plus CoLA-M's recompute (Table 4): C_CoLA-M = C_CoLA + 18.5ndr + 4n²d and
vanilla GCP: C_full + 23nd² + 4n²d.

These are the *paper's own* accounting conventions (forward+backward with
the 2× backward rule, lower-order terms dropped).  benchmarks/flops_table.py
validates CoLA/full against the loop-aware HLO measurement.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.config import ModelConfig


@dataclass(frozen=True)
class LayerDims:
    n: int        # tokens per sequence (paper's token batch)
    d: int
    d_ff: int
    r: int

    @classmethod
    def from_config(cls, cfg: ModelConfig, n: int) -> "LayerDims":
        return cls(n=n, d=cfg.d_model, d_ff=cfg.d_ff, r=cfg.rank_attn)


def full_rank(dims: LayerDims) -> float:
    n, d, dff = dims.n, dims.d, dims.d_ff
    return 24 * n * d**2 + 12 * n**2 * d + 18 * n * d * dff


def cola(dims: LayerDims) -> float:
    n, d, dff, r = dims.n, dims.d, dims.d_ff, dims.r
    return 48 * n * d * r + 12 * n**2 * d + 18 * n * r * (d + dff)


def cola_m(dims: LayerDims) -> float:
    n, d, r = dims.n, dims.d, dims.r
    return cola(dims) + 18.5 * n * d * r + 4 * n**2 * d


def lora(dims: LayerDims) -> float:
    n, d, dff, r = dims.n, dims.d, dims.d_ff, dims.r
    return (16 * n * d**2 + 12 * n**2 * d + 12 * n * d * dff
            + 48 * n * d * r + 18 * n * r * (d + dff))


def sltrain(dims: LayerDims) -> float:
    d, dff, r = dims.d, dims.d_ff, dims.r
    return full_rank(dims) + 24 * d**2 * r + 18 * d * dff * r


def galore(dims: LayerDims) -> float:
    d, dff, r = dims.d, dims.d_ff, dims.r
    return full_rank(dims) + 16 * d**2 * r + 12 * d * dff * r


def vanilla_gcp(dims: LayerDims) -> float:
    n, d = dims.n, dims.d
    return full_rank(dims) + 23 * n * d**2 + 4 * n**2 * d


METHODS = {
    "full_rank": full_rank,
    "cola": cola,
    "cola_m": cola_m,
    "lora": lora,
    "relora": lora,
    "sltrain": sltrain,
    "galore": galore,
    "vanilla_gcp": vanilla_gcp,
}


def per_layer(method: str, dims: LayerDims) -> float:
    return METHODS[method](dims)


def model_total(method: str, cfg: ModelConfig, n: int,
                n_seqs: int = 1) -> float:
    """Whole-model FLOPs (layers × per-layer × sequences); embeddings
    excluded per the paper's convention."""
    dims = LayerDims.from_config(cfg, n)
    return per_layer(method, dims) * cfg.num_layers * n_seqs


def crossover_rank(cfg: ModelConfig) -> float:
    """Rank below which CoLA beats full-rank: r < (24d+18d_ff)·d /
    (48d + 18(d+d_ff)) — paper's r < 0.62d for d_ff ≈ 2.5d."""
    d, dff = cfg.d_model, cfg.d_ff
    return (24 * d + 18 * dff) * d / (48 * d + 18 * (d + dff))
