# The paper's primary contribution: CoLA auto-encoder layers (cola.py),
# CoLA-M remat policies (colam.py), analytical compute/memory models
# (flops.py / memory.py), and activation effective-rank analysis
# (rank_analysis.py).
from repro.core.cola import COLA_R_NAME, cola_apply, cola_defs  # noqa: F401
from repro.core.colam import maybe_remat, remat_policy  # noqa: F401
