"""Analytical activation-memory models — paper Table 4 / Appendix C, exact.

Per decoder layer, token batch n, width d, heads h, rank r (elements, not
bytes; the paper's convention):

    M_full        = 20nd + 2n²h
    M_vanilla_GCP = nd
    M_CoLA        = M_full + 14nr − 2.5nd      (σ removed at scale)
    M_CoLA-M      = 2nd + 7nr

Re-compute costs are in core/flops.py (cola_m / vanilla_gcp).
benchmarks/memory_table.py compares these against the dry-run's
measured per-device residual sizes.
"""
from __future__ import annotations

from repro.config import ModelConfig


def full_rank(n: int, d: int, h: int) -> float:
    return 20 * n * d + 2 * n**2 * h


def vanilla_gcp(n: int, d: int, h: int) -> float:
    return float(n * d)


def cola(n: int, d: int, h: int, r: int) -> float:
    return full_rank(n, d, h) + 14 * n * r - 2.5 * n * d


def cola_m(n: int, d: int, h: int, r: int) -> float:
    return 2 * n * d + 7 * n * r


def model_totals(cfg: ModelConfig, n: int) -> dict:
    d, h, r = cfg.d_model, cfg.num_heads, cfg.rank_attn
    L = cfg.num_layers
    return {
        "full_rank": L * full_rank(n, d, h),
        "vanilla_gcp": L * vanilla_gcp(n, d, h),
        "cola": L * cola(n, d, h, r),
        "cola_m": L * cola_m(n, d, h, r),
    }


def recompute_reduction_vs_gcp(cfg: ModelConfig, n: int) -> float:
    """Paper Fig. 7's headline: CoLA-M re-computes ~4.6× less than GCP."""
    from repro.core import flops
    dims = flops.LayerDims.from_config(cfg, n)
    gcp_re = 23 * n * cfg.d_model**2 + 4 * n**2 * cfg.d_model
    colam_re = 18.5 * n * cfg.d_model * dims.r + 4 * n**2 * cfg.d_model
    return gcp_re / colam_re
