"""CoLA-M: memory-efficient training via low-rank-activation checkpointing.

The paper (§4, Table 4) saves only the r-dimensional bottleneck activations
(7 per decoder block: q,k,v,o + gate,up,down) plus block inputs/outputs, and
recomputes the up-projections and attention SDP during backward:

    M_CoLA-M = 2nd + 7nr        C_CoLA-M = C_CoLA + 18.5ndr + 4n²d

In JAX this is exactly ``jax.checkpoint`` with a ``save_only_these_names``
policy over the ``'cola_r'`` names emitted by ``core.cola.cola_apply`` —
block in/outputs are scan carries (always live), every r-dim tensor is
saved, everything else (SDP included) is rematerialized.  Gradients are
bitwise-identical to the unrematerialized program (tested in
tests/test_colam.py).

Policies:
    none    — save everything (paper's "CoLA" row: max memory, no recompute)
    full    — vanilla GCP: save nothing inside the block (paper's baseline)
    cola_m  — save only low-rank activations (the paper's contribution)
    dots    — XLA heuristic (save matmul outputs); beyond-paper comparison

Composition with the fused Pallas path (cola.use_fused_kernel): the fused
AE's custom VJP saves exactly (x, z_pre) — z_pre is the same r-dim,
``cola_r``-named tensor this policy keeps on the unfused path — so the
kernel provides CoLA-M residency at AE sites *without* remat.  This holds
**identically for both fused plans**: the monolithic kernel emits z_pre
from its VMEM scratch, the two-stage pipeline materializes the same
(post-psum, post-bias_a) z_pre between stage A and stage B, and either way
the VJP residuals are only (x, z_pre) — the policy needs no plan
awareness.  Remat policies cannot look inside a custom_vjp: under ``full``
the fused forward (one or two kernels, per plan) is replayed once during
backward (the CoLA-M compute trade); under ``cola_m`` the policy still
governs everything outside the AE sites (SDP, norms, element-wise
products).

Composition with tensor parallelism: ``--fused`` composes with meshes
carrying a 'model' axis — the kernels run per-shard inside shard_map with
a collective-aware custom VJP (kernels/cola_ae/ops.py) that places
collectives *between* stages, and the z_pre residual is itself sharded
(rank dim over 'model' under the ``baseline`` profile), so the CoLA-M
residency recipe survives sharding at 1/|model| footprint per device.
Collective counts per AE site, fwd+bwd: ``baseline`` 2 full-width psums
(out; dx — a psum_scatter when the seq entry rides the same axes);
``megatron`` 1 r-dim f32 psum (z_pre between stage A and stage B at
row-parallel o/down — the 2-per-block exits — or g·Bᵀ between bwd_dzl and
σ′ at column-parallel qkv/gate/up in bwd), plus the explicit sequence-
parallel entry all-gathers where the profile seq-shards the residual
stream; ``fsdp`` 0.  All are verified against the unfused sharded
reference in tests/test_sharded_fused.py.

Non-interaction with inference (``mode='infer'``): the serving paths
(Model.prefill / Model.decode_step → linear_apply → cola_apply →
kernels/cola_ae/ops.py) bypass the custom VJP entirely — no (x, z_pre)
residual is ever created, prefill rides the fused no-residual forward and
decode dispatches the GEMV-shaped ``cola_ae_decode`` plan below the T
threshold.  With nothing saved there is nothing for a remat policy to
keep or recompute: these policies wrap only the training scan body
(transformer.stack_forward with ``training=True`` and no caches), so the
decode subsystem and CoLA-M compose trivially — by never meeting.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core.cola import COLA_R_NAME


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "cola_m":
        return jax.checkpoint_policies.save_only_these_names(COLA_R_NAME)
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(f"unknown remat policy '{name}'")


def maybe_remat(fn: Callable, policy_name: str) -> Callable:
    """Wrap a block function with jax.checkpoint per the named policy."""
    if policy_name == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(policy_name),
                          prevent_cse=True)
