"""CoLA-M: memory-efficient training via low-rank-activation checkpointing.

The paper (§4, Table 4) saves only the r-dimensional bottleneck activations
(7 per decoder block: q,k,v,o + gate,up,down) plus block inputs/outputs, and
recomputes the up-projections and attention SDP during backward:

    M_CoLA-M = 2nd + 7nr        C_CoLA-M = C_CoLA + 18.5ndr + 4n²d

In JAX this is exactly ``jax.checkpoint`` with a ``save_only_these_names``
policy over the ``'cola_r'`` names emitted by ``core.cola.cola_apply`` —
block in/outputs are scan carries (always live), every r-dim tensor is
saved, everything else (SDP included) is rematerialized.  Gradients are
bitwise-identical to the unrematerialized program (tested in
tests/test_colam.py).

Policies:
    none    — save everything (paper's "CoLA" row: max memory, no recompute)
    full    — vanilla GCP: save nothing inside the block (paper's baseline)
    cola_m  — save only low-rank activations (the paper's contribution)
    dots    — XLA heuristic (save matmul outputs); beyond-paper comparison

Composition with the fused Pallas path (cola.use_fused_kernel): the fused
AE's custom VJP already saves exactly (x, z_pre) — z_pre is the same
r-dim, ``cola_r``-named tensor this policy keeps on the unfused path — so
the kernel provides CoLA-M residency at AE sites *without* remat.  Remat
policies cannot look inside a custom_vjp: under ``full`` the fused forward
kernel is replayed once during backward (the CoLA-M compute trade, one
kernel launch); under ``cola_m`` the policy still governs everything
outside the AE sites (SDP, norms, element-wise products).

Composition with tensor parallelism: ``--fused`` now also composes with
meshes carrying a 'model' axis — the kernels run per-shard inside
shard_map with a collective-aware custom VJP (kernels/cola_ae/ops.py), and
the z_pre residual is itself sharded (rank dim over 'model' under the
``baseline`` profile), so the CoLA-M residency recipe survives sharding at
1/|model| footprint per device.  Collective counts per AE site, fwd+bwd:
``baseline`` 2 full-width psums (out, dx); ``megatron`` 1 r-dim f32 psum
(z_pre at row-parallel o/down in fwd — the 2-per-block exits — or g·Bᵀ at
column-parallel qkv/gate/up in bwd); ``fsdp`` 0.  All three are verified
against the unfused sharded reference in tests/test_sharded_fused.py.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax

from repro.core.cola import COLA_R_NAME


def remat_policy(name: str):
    if name == "none":
        return None
    if name == "full":
        return jax.checkpoint_policies.nothing_saveable
    if name == "cola_m":
        return jax.checkpoint_policies.save_only_these_names(COLA_R_NAME)
    if name == "dots":
        return jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    raise ValueError(f"unknown remat policy '{name}'")


def maybe_remat(fn: Callable, policy_name: str) -> Callable:
    """Wrap a block function with jax.checkpoint per the named policy."""
    if policy_name == "none":
        return fn
    return jax.checkpoint(fn, policy=remat_policy(policy_name),
                          prevent_cse=True)
