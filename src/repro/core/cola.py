"""CoLA: the bottleneck auto-encoder layer (paper Eq. 3).

``h = B · σ(A · x)`` with ``A ∈ R^{d_in×r}`` (stored (in, out) convention),
``B ∈ R^{r×d_out}`` and σ = SiLU.  The r-dimensional pre-activation is
tagged with ``checkpoint_name('cola_r')`` so CoLA-M (core/colam.py) can save
*only* the low-rank activations and recompute everything else — the paper's
Table-4 memory recipe expressed as an XLA remat policy.

σ placement follows paper Appendix E.1 (Table 10):
* ``lowrank_only`` — σ between A and B everywhere (default for ≥350M),
* ``both``         — additionally keep the original nonlinearity (the MLP's
                     SwiGLU gate) on top — handled by the MLP module,
* ``reduced``      — σ between A and B only at sites that were originally
                     followed by a nonlinearity,
* ``fullrank_only``— no σ between A and B (pure factorization control).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.ad_checkpoint import checkpoint_name

from repro.config import ModelConfig
from repro.distributed.sharding import shard
from repro.models.common import ParamDef, silu

# Name used by the CoLA-M remat policy.
COLA_R_NAME = "cola_r"


def cola_defs(d_in: int, d_out: int, rank: int,
              in_ax: Optional[str], out_ax: Optional[str],
              bias: bool = False) -> Dict[str, ParamDef]:
    """ParamDefs for one auto-encoder site.

    Init: A, B ~ N(0, 1/fan_in) — factorized layers need smaller init than
    the dense site they replace (Khodak et al. 2021); 1/sqrt(fan_in) on both
    factors gives the product W=BA spectral scale ~1/sqrt(d_in·r)·r which
    tracks the dense 1/sqrt(d_in) for r = d/4.
    """
    defs = {
        "a": ParamDef((d_in, rank), (in_ax, "rank"), init="fan_in"),
        "b": ParamDef((rank, d_out), ("rank", out_ax), init="fan_in"),
    }
    if bias:
        defs["bias_a"] = ParamDef((rank,), ("rank",), init="zeros")
        defs["bias_b"] = ParamDef((d_out,), (out_ax,), init="zeros")
    return defs


def cola_apply(params, x: jax.Array, *, sigma: bool = True,
               act_axes: Optional[Tuple[Optional[str], ...]] = None,
               use_fused: bool = False,
               weight_axes: Optional[Tuple[Optional[str],
                                           Optional[str]]] = None,
               mode: str = "train") -> jax.Array:
    """Apply ``B·σ(A·x)`` over the last dim of x.

    act_axes: logical axes of the low-rank activation (defaults to
    (batch, seq, rank)); drives TP sharding of the bottleneck.

    use_fused: route through the fused Pallas fwd+bwd path
    (kernels/cola_ae/ops.py).  Its custom VJP saves only (x, z_pre) — the
    same r-dim tensor the ``cola_m`` remat policy keeps via the
    ``cola_r`` name below — so kernel-level residency makes the policy a
    no-op at AE sites while the rest of the block still benefits from it.
    The ops planner picks the monolithic kernel (biases folded into its
    body) or the two-stage pipeline per site.

    weight_axes: the site's (in_ax, out_ax) logical weight axes, as passed
    to ``cola_defs``.  Under a mesh with a nontrivial 'model' axis the
    fused path runs the kernels per-shard inside shard_map with explicit
    collectives between stages (ops.cola_ae_sharded) — the partitioning is
    resolved from these names, so --fused composes with tensor parallelism
    at every site kind, bias-carrying and row-parallel included.  Only
    sites that don't thread their axes still take the unfused sharded
    path below (counted as ``apply_fused_fallback``).

    mode: 'train' | 'infer', threaded from linear_apply.  'infer' (the
    model facade's prefill/decode paths) drops the custom VJP entirely —
    no (x, z_pre) residual exists, so inference never interacts with the
    remat policy — and adds the decode plan: T ≤ ops.DECODE_T_MAX
    dispatches the GEMV-shaped ``cola_ae_decode`` single launch.  The
    unfused path below is mode-agnostic (no residuals beyond autodiff's,
    and none when not differentiated).
    """
    if use_fused and x.ndim == 3:
        from repro.kernels.cola_ae import ops as cola_ops
        env = _model_parallel_env()
        if env is None:
            # Fused Pallas path (TPU): keeps the r-dim intermediate in VMEM
            # in forward AND backward.
            cola_ops.DISPATCH["apply_fused_local"] += 1
            return cola_ops.cola_ae(x, params["a"], params["b"], sigma=sigma,
                                    bias_a=params.get("bias_a"),
                                    bias_b=params.get("bias_b"), mode=mode)
        if weight_axes is not None:
            cola_ops.DISPATCH["apply_fused_sharded"] += 1
            return cola_ops.cola_ae_sharded(
                x, params["a"], params["b"], sigma=sigma, env=env,
                bias_a=params.get("bias_a"), bias_b=params.get("bias_b"),
                in_ax=weight_axes[0], out_ax=weight_axes[1], mode=mode)
        cola_ops.DISPATCH["apply_fused_fallback"] += 1
    from repro.kernels.cola_ae import quant as _quant
    if isinstance(params["a"], _quant.QuantFactor):
        raise TypeError(
            "quantized CoLA factors reached the unfused einsum path — "
            "quantized weight streaming requires the fused kernels "
            "(cola.use_fused_kernel=True and 3-D activations; "
            "serve.make_engine(weight_dtype=...) sets this up)")
    a = params["a"].astype(x.dtype)
    b = params["b"].astype(x.dtype)
    z = jnp.einsum("...d,dr->...r", x, a)
    if "bias_a" in params:
        z = z + params["bias_a"].astype(x.dtype)
    if act_axes is None and z.ndim == 3:
        act_axes = ("batch", "seq", "act_rank")
    if act_axes is not None and len(act_axes) == z.ndim:
        z = shard(z, *act_axes)
    if sigma:
        z = silu(z)
    # The low-rank activation: the only tensor CoLA-M saves inside a block.
    z = checkpoint_name(z, COLA_R_NAME)
    h = jnp.einsum("...r,ro->...o", z, b)
    if "bias_b" in params:
        h = h + params["bias_b"].astype(x.dtype)
    return h


def _model_parallel_env():
    """The active MeshEnv when it has a >1 'model' axis, else None — the
    dispatch pivot between the local fused path and the shard_map'd one."""
    from repro.distributed.sharding import current_env
    env = current_env()
    if env is not None and env.mesh.shape.get("model", 1) > 1:
        return env
    return None


def sigma_between(cfg: ModelConfig, originally_nonlinear: bool) -> bool:
    """Whether σ sits between A and B at this site (paper App. E.1)."""
    mode = cfg.cola.sigma
    if mode in ("lowrank_only", "both"):
        return True
    if mode == "reduced":
        return originally_nonlinear
    if mode == "fullrank_only":
        return False
    raise ValueError(mode)


def keep_original_sigma(cfg: ModelConfig) -> bool:
    """Whether the original nonlinearity (e.g. SwiGLU gate) is kept."""
    return cfg.cola.sigma in ("both", "fullrank_only")
