"""Activation effective-rank analysis — paper §3.1 Eq. (1) and Fig. 2.

    r(α) = min{ k : Σ_{i≤k} σ_i² / Σ_i σ_i² ≥ α }

``collect_activation_spectra`` runs a model over a batch with hooks on the
MLP/attention inputs and reports per-layer effective ranks — the
motivating-observation experiment (examples/rank_analysis_demo.py
reproduces Fig. 2's shape on a trained tiny model).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def effective_rank(x: jax.Array, alpha: float = 0.95) -> int:
    """x: (tokens, features) activation matrix."""
    x32 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    s = np.linalg.svd(x32, compute_uv=False)
    energy = np.cumsum(s**2)
    total = energy[-1]
    if total <= 0:
        return 0
    return int(np.searchsorted(energy / total, alpha) + 1)


def singular_spectrum(x: jax.Array) -> np.ndarray:
    x32 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    return np.linalg.svd(x32, compute_uv=False)


def collect_activation_spectra(model, params, batch, alpha: float = 0.95
                               ) -> List[Dict]:
    """Per-layer effective rank of the residual stream entering each block.

    Uses the scan-over-periods structure: re-runs the stack capturing the
    carry at each period boundary (cheap at analysis scale).
    """
    from repro.models import transformer
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    x = model._embed_inputs(params, batch, dtype)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos_sin = model._cos_sin(positions, batch)

    period = transformer.period_length(cfg)
    kinds = cfg.layer_kinds()
    results = []
    block_params = params["blocks"]
    n_per = transformer.n_periods(cfg)
    for p in range(n_per):
        pparams = jax.tree.map(lambda w: w[p], block_params)
        results.append({
            "layer": p * period,
            "dim": cfg.d_model,
            "effective_rank": effective_rank(x, alpha),
        })
        aux = transformer._zero_aux(cfg)
        for i in range(period):
            x, _, aux = transformer._apply_layer(
                cfg, kinds[i], cfg.layer_is_moe(p * period + i),
                pparams[f"layer{i}"], x, cos_sin=cos_sin,
                positions=positions, cache=None, aux_acc=aux)
    results.append({"layer": cfg.num_layers, "dim": cfg.d_model,
                    "effective_rank": effective_rank(x, alpha)})
    return results
