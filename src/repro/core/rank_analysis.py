"""Activation effective-rank analysis — paper §3.1 Eq. (1) and Fig. 2.

    r(α) = min{ k : Σ_{i≤k} σ_i² / Σ_i σ_i² ≥ α }

``collect_activation_spectra`` runs a model over a batch with hooks on the
MLP/attention inputs and reports per-layer effective ranks — the
motivating-observation experiment (examples/rank_analysis_demo.py
reproduces Fig. 2's shape on a trained tiny model).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


def rank_at(spectrum: np.ndarray, alpha: float) -> int:
    """Eq. (1) on a precomputed spectrum: the smallest k whose leading
    σ²-energy share reaches ``alpha``.  Clamped to [1, len(spectrum)]
    (float round-off can leave the normalized tail just under 1.0)."""
    s = np.asarray(spectrum, np.float64).reshape(-1)
    if s.size == 0:
        return 0
    energy = np.cumsum(s**2)
    total = energy[-1]
    if total <= 0:
        return 0
    r = int(np.searchsorted(energy / total, alpha) + 1)
    return max(1, min(r, s.size))


def effective_rank(x: jax.Array, alpha: float = 0.95) -> int:
    """x: (tokens, features) activation matrix."""
    x32 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    s = np.linalg.svd(x32, compute_uv=False)
    return rank_at(s, alpha)


def singular_spectrum(x: jax.Array) -> np.ndarray:
    x32 = np.asarray(x, np.float32).reshape(-1, x.shape[-1])
    return np.linalg.svd(x32, compute_uv=False)


def collect_activation_spectra(model, params, batch, alpha: float = 0.95
                               ) -> List[Dict]:
    """Per-layer effective rank of the residual stream entering each block.

    Uses the scan-over-periods structure: re-runs the stack capturing the
    carry at each period boundary (cheap at analysis scale).
    """
    from repro.models import transformer
    cfg = model.cfg
    dtype = jnp.dtype(cfg.dtype)
    x = model._embed_inputs(params, batch, dtype)
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos_sin = model._cos_sin(positions, batch)

    period = transformer.period_length(cfg)
    kinds = cfg.layer_kinds()
    results = []
    block_params = params["blocks"]
    n_per = transformer.n_periods(cfg)
    for p in range(n_per):
        pparams = jax.tree.map(lambda w: w[p], block_params)
        spec = singular_spectrum(x)
        results.append({
            "layer": p * period,
            "dim": cfg.d_model,
            "effective_rank": rank_at(spec, alpha),
            "spectrum": spec,
        })
        aux = transformer._zero_aux(cfg)
        for i in range(period):
            x, _, aux = transformer._apply_layer(
                cfg, kinds[i], cfg.layer_is_moe(p * period + i),
                pparams[f"layer{i}"], x, cos_sin=cos_sin,
                positions=positions, cache=None, aux_acc=aux)
    spec = singular_spectrum(x)
    results.append({"layer": cfg.num_layers, "dim": cfg.d_model,
                    "effective_rank": rank_at(spec, alpha),
                    "spectrum": spec})
    return results


def pick_draft_ranks(spectra: List[Dict], alpha: float,
                     max_rank: Optional[int] = None) -> Dict[int, int]:
    """Per-layer draft-rank picker for speculative decoding (ROADMAP item
    2; CR-Net's cross-layer observation supports per-layer rather than
    one global truncation).

    ``spectra`` is a list of ``{"layer": idx, "spectrum": 1-D σ array}``
    entries — either measured activation spectra from
    :func:`collect_activation_spectra` or per-site factor-importance
    scores (serve/draft.py).  Returns ``{layer: r'}`` with
    ``r' = rank_at(spectrum, alpha)``, optionally clamped to
    ``max_rank`` (the site's full factor rank — a draft can never use
    more directions than the full model has).

    Properties (tested in tests/test_speculative.py): monotone
    non-decreasing in ``alpha``, never exceeds the spectrum length or
    ``max_rank``, and a pure function of its inputs (bit-identical
    across processes — no salted hashing anywhere).
    """
    if not 0.0 < alpha <= 1.0:
        raise ValueError(f"alpha must be in (0, 1], got {alpha}")
    out: Dict[int, int] = {}
    for entry in spectra:
        r = rank_at(np.asarray(entry["spectrum"]), alpha)
        if max_rank is not None:
            r = min(r, int(max_rank))
        out[int(entry["layer"])] = max(1, r)
    return out
