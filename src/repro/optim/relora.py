"""ReLoRA baseline (Lialin et al. 2023): accumulate low-rank updates by
periodically merging B·A into the frozen W0 and restarting the factors
(+ resetting their optimizer moments).

Used with ``parameterization='lora'``; the train loop calls
``maybe_merge_restart`` every ``cfg.lora.relora_every`` steps.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.common import path_fold
from repro.optim.adamw import AdamState


def _is_lora_site(path_keys) -> bool:
    return any(k in ("lora_a", "lora_b") for k in path_keys)


def merge_restart(cfg: ModelConfig, params, opt: AdamState,
                  rng: jax.Array) -> Tuple[Any, AdamState]:
    """W0 += (α/r)·A·B ; A ~ N(0, 1/√d) ; B = 0 ; moments of A,B zeroed."""
    scale = cfg.lora.alpha / cfg.lora.rank
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    by_path = {jax.tree_util.keystr(p): (p, v) for p, v in flat}
    new_vals = {}
    for key, (path, val) in by_path.items():
        keys = [getattr(q, "key", "") for q in path]
        if keys and keys[-1] == "w0":
            prefix = key[: key.rfind("[")]
            a = by_path.get(prefix + "['lora_a']")
            b = by_path.get(prefix + "['lora_b']")
            if a is not None and b is not None:
                merged = val.astype(jnp.float32) + scale * (
                    a[1].astype(jnp.float32) @ b[1].astype(jnp.float32))
                new_vals[key] = merged.astype(val.dtype)
                continue
        if keys and keys[-1] == "lora_a":
            # path_fold, not hash(): restart draws must match across
            # processes (hash() is PYTHONHASHSEED-salted)
            k = jax.random.fold_in(rng, path_fold(key))
            std = 1.0 / jnp.sqrt(val.shape[0])
            new_vals[key] = (std * jax.random.normal(k, val.shape)
                             ).astype(val.dtype)
        elif keys and keys[-1] == "lora_b":
            new_vals[key] = jnp.zeros_like(val)
    new_params = jax.tree.unflatten(
        treedef, [new_vals.get(jax.tree_util.keystr(p), v)
                  for p, v in flat])

    def zero_lora_moments(tree):
        mflat, mdef = jax.tree_util.tree_flatten_with_path(tree)
        out = []
        for p, v in mflat:
            keys = [getattr(q, "key", "") for q in p]
            out.append(jnp.zeros_like(v) if _is_lora_site(keys) else v)
        return jax.tree.unflatten(mdef, out)

    new_opt = AdamState(m=zero_lora_moments(opt.m),
                        v=zero_lora_moments(opt.v), count=opt.count)
    return new_params, new_opt
