"""GaLore baseline (Zhao et al. 2024): low-rank gradient projection.

For each 2D parameter, gradients are projected onto a rank-r subspace
(R_t = P_tᵀ G_t), Adam moments live in the low-rank space, and updates are
projected back (G̃_t = P R̂_t).  The projector P is refreshed from the SVD of
the current gradient every ``update_every`` steps (paper's T=200).

This is a *baseline* for the paper's Table 1/3/5 comparisons: GaLore's
compute is lower-bounded by full-rank training (C_GaLore = C_full +
16d²r + 12dd_ff r) whereas CoLA's is ~half of it.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class GaloreState(NamedTuple):
    proj: Any      # per-leaf projector ((d, r) or None)
    m: Any         # low-rank (or full for non-2D) first moment
    v: Any
    count: jax.Array


def _projectable(p, rank: int) -> bool:
    return p.ndim == 2 and min(p.shape) > rank


def galore_init(params, rank: int) -> GaloreState:
    def proj0(p):
        if not _projectable(p, rank):
            return jnp.zeros((0,), jnp.float32)
        d = min(p.shape)
        side = 0 if p.shape[0] <= p.shape[1] else 1
        return jnp.eye(p.shape[side], rank, dtype=jnp.float32)

    def mom0(p):
        if not _projectable(p, rank):
            return jnp.zeros(p.shape, jnp.float32)
        if p.shape[0] <= p.shape[1]:
            return jnp.zeros((rank, p.shape[1]), jnp.float32)
        return jnp.zeros((p.shape[0], rank), jnp.float32)

    return GaloreState(proj=jax.tree.map(proj0, params),
                       m=jax.tree.map(mom0, params),
                       v=jax.tree.map(mom0, params),
                       count=jnp.zeros((), jnp.int32))


def _refresh_proj(g: jax.Array, rank: int) -> jax.Array:
    """Top-r singular subspace of G (projects the smaller dim)."""
    g32 = g.astype(jnp.float32)
    if g.shape[0] <= g.shape[1]:
        u, _, _ = jnp.linalg.svd(g32, full_matrices=False)
        return u[:, :rank]
    _, _, vt = jnp.linalg.svd(g32, full_matrices=False)
    return vt[:rank, :].T


def galore_update(tc: TrainConfig, params, grads, state: GaloreState,
                  lr: jax.Array) -> Tuple[Any, GaloreState]:
    rank = tc.galore_rank
    count = state.count + 1
    refresh = (state.count % tc.galore_update_every) == 0
    b1, b2 = tc.beta1, tc.beta2
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(p, g, proj, mm, vv):
        g32 = g.astype(jnp.float32)
        if not _projectable(p, rank):
            m = b1 * mm + (1 - b1) * g32
            v = b2 * vv + (1 - b2) * jnp.square(g32)
            step = (m / bc1) / (jnp.sqrt(v / bc2) + tc.eps)
            new = (p.astype(jnp.float32)
                   - lr * (step + tc.weight_decay * p.astype(jnp.float32)))
            return new.astype(p.dtype), proj, m, v
        new_proj = jax.lax.cond(refresh,
                                lambda: _refresh_proj(g32, rank),
                                lambda: proj)
        left = p.shape[0] <= p.shape[1]
        r_t = (new_proj.T @ g32) if left else (g32 @ new_proj)
        m = b1 * mm + (1 - b1) * r_t
        v = b2 * vv + (1 - b2) * jnp.square(r_t)
        step_lr = (m / bc1) / (jnp.sqrt(v / bc2) + tc.eps)
        step = (new_proj @ step_lr) if left else (step_lr @ new_proj.T)
        new = (p.astype(jnp.float32)
               - lr * (step + tc.weight_decay * p.astype(jnp.float32)))
        return new.astype(p.dtype), new_proj, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_proj = jax.tree.leaves(state.proj)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    outs = [upd(*t) for t in zip(flat_p, flat_g, flat_proj, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_state = GaloreState(
        proj=jax.tree.unflatten(treedef, [o[1] for o in outs]),
        m=jax.tree.unflatten(treedef, [o[2] for o in outs]),
        v=jax.tree.unflatten(treedef, [o[3] for o in outs]),
        count=count)
    return new_params, new_state
