"""AdamW and LAMB, from scratch over pytrees (no optax in this container).

Optimizer states are f32 and inherit the parameter shardings (ZeRO-3
semantics come for free: the jit in_shardings pin m/v to the same
FSDP layout as the master params).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class AdamState(NamedTuple):
    m: Any
    v: Any
    count: jax.Array


def adamw_init(params) -> AdamState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamState(m=jax.tree.map(zeros, params),
                     v=jax.tree.map(zeros, params),
                     count=jnp.zeros((), jnp.int32))


def _adam_moments(tc: TrainConfig, state: AdamState, grads):
    b1, b2 = tc.beta1, tc.beta2
    m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g.astype(jnp.float32),
                     state.m, grads)
    v = jax.tree.map(
        lambda vv, g: b2 * vv + (1 - b2) * jnp.square(g.astype(jnp.float32)),
        state.v, grads)
    count = state.count + 1
    bc1 = 1 - b1 ** count.astype(jnp.float32)
    bc2 = 1 - b2 ** count.astype(jnp.float32)
    return m, v, count, bc1, bc2


def adamw_update(tc: TrainConfig, params, grads, state: AdamState,
                 lr: jax.Array, mask=None) -> Tuple[Any, AdamState]:
    """Returns (new_params, new_state).  mask: False leaves are frozen."""
    m, v, count, bc1, bc2 = _adam_moments(tc, state, grads)

    def upd(p, mm, vv, keep):
        mhat = mm / bc1
        vhat = vv / bc2
        step = mhat / (jnp.sqrt(vhat) + tc.eps)
        step = step + tc.weight_decay * p.astype(jnp.float32)
        new = p.astype(jnp.float32) - lr * step
        new = new.astype(p.dtype)
        return jnp.where(keep, new, p) if keep is not None else new

    if mask is None:
        new_params = jax.tree.map(lambda p, mm, vv: upd(p, mm, vv, None),
                                  params, m, v)
    else:
        new_params = jax.tree.map(upd, params, m, v, mask)
    return new_params, AdamState(m, v, count)


def lamb_update(tc: TrainConfig, params, grads, state: AdamState,
                lr: jax.Array, mask=None) -> Tuple[Any, AdamState]:
    """LAMB (You et al. 2019) — used by the paper's BERT-Large reproduction."""
    m, v, count, bc1, bc2 = _adam_moments(tc, state, grads)

    def upd(p, mm, vv, keep):
        mhat = mm / bc1
        vhat = vv / bc2
        step = mhat / (jnp.sqrt(vhat) + tc.eps)
        step = step + tc.weight_decay * p.astype(jnp.float32)
        wn = jnp.linalg.norm(p.astype(jnp.float32).reshape(-1))
        sn = jnp.linalg.norm(step.reshape(-1))
        trust = jnp.where((wn > 0) & (sn > 0), wn / sn, 1.0)
        new = (p.astype(jnp.float32) - lr * trust * step).astype(p.dtype)
        return jnp.where(keep, new, p) if keep is not None else new

    if mask is None:
        new_params = jax.tree.map(lambda p, mm, vv: upd(p, mm, vv, None),
                                  params, m, v)
    else:
        new_params = jax.tree.map(upd, params, m, v, mask)
    return new_params, AdamState(m, v, count)
