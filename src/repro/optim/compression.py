"""Gradient compression for data-parallel reduction: int8 quantization with
per-tensor scale and error feedback (residual carried across steps).

Two layers:

* ``quantize_tree / dequantize_tree`` — the numerics (tested against the
  error-feedback convergence property);
* ``compressed_psum`` — an explicit shard_map all-reduce that puts the int8
  payload on the wire (8× less DP all-reduce traffic), used when
  ``train.grad_compression='int8'`` and exercised by the collective tests.

Error feedback (Seide et al. 2014): e_{t} = g_t + e_{t-1} - Q(g_t + e_{t-1})
keeps the compressed SGD unbiased in the long run.

The quantization numerics live in ``kernels/cola_ae/quant.py`` (one
symmetric-quant implementation shared with the quantized decode weight
streaming).  ``quantize`` here keeps its historic per-tensor scalar-scale
int8 default but now also exposes the shared per-axis scales (``axis=``)
and int4 (``bits=4``, optionally nibble-packed via ``quant.pack_nibbles``)
for callers that want finer grain.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.kernels.cola_ae import quant as _quant


def quantize(x: jax.Array, *, bits: int = 8,
             axis=None) -> Tuple[jax.Array, jax.Array]:
    """Symmetric quantization: returns (q, scale).

    Defaults (``bits=8, axis=None``) reproduce the original per-tensor
    scalar-scale int8 behaviour exactly; ``axis`` selects per-axis scale
    blocks (keepdims) and ``bits=4`` narrows to the int4 grid.  Delegates
    to :func:`repro.kernels.cola_ae.quant.quantize_array`.
    """
    return _quant.quantize_array(x, bits=bits, axis=axis)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, error):
    """Returns (dequantized grads as would survive the wire, new error)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize(corrected)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), corrected - deq
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in outs]),
            jax.tree.unflatten(treedef, [o[1] for o in outs]))


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(mesh, axis_names, tree):
    """int8-on-the-wire all-reduce over `axis_names` (shard_map explicit).

    Each rank quantizes its local contribution; int8 payloads are summed in
    int32 (exact for <=2^23 ranks), then rescaled by the max of per-rank
    scales.  The scale exchange is one f32 per tensor.
    """
    def body(*leaves):
        outs = []
        for x in leaves:
            q, s = quantize(x)
            smax = jax.lax.pmax(s, axis_names)
            # requantize against the shared scale so sums are coherent
            q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / smax),
                          -127, 127).astype(jnp.int32)
            total = jax.lax.psum(q2, axis_names)
            outs.append((total.astype(jnp.float32) * smax).astype(x.dtype))
        return tuple(outs)

    leaves, treedef = jax.tree.flatten(tree)
    specs = tuple(P(*([None] * x.ndim)) for x in leaves)
    out = shard_map(body, mesh=mesh, in_specs=specs, out_specs=specs,
                    check_rep=False)(*leaves)
    return jax.tree.unflatten(treedef, list(out))
