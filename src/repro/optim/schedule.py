"""LR schedules (paper App. D: cosine annealing with warm-up)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(step, *, base_lr: float, total_steps: int,
                    warmup_ratio: float = 0.1, min_ratio: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warmup = jnp.maximum(1.0, warmup_ratio * total_steps)
    warm = base_lr * (step + 1.0) / warmup  # step 0 takes a nonzero step
    prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total_steps - warmup),
                    0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, base_lr * cos)
