"""Straggler mitigation: per-step wall-time watchdog.

On a real fleet, a straggling host shows up as step-time inflation on every
worker (SPMD collectives synchronize).  The watchdog keeps an EWMA of step
time and flags steps slower than ``threshold ×`` the moving average; the
train loop logs the event and calls a user hook (e.g. emit a preemption
request to the cluster scheduler, trigger an early checkpoint).  The
detection logic is hardware-independent and unit-tested on CPU.
"""
from __future__ import annotations

import time
from typing import Callable, List, Optional


class StepWatchdog:
    def __init__(self, threshold: float = 2.5, ewma: float = 0.9,
                 warmup_steps: int = 5,
                 on_straggler: Optional[Callable[[int, float, float], None]]
                 = None):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.warmup = warmup_steps
        self.on_straggler = on_straggler
        self.avg: Optional[float] = None
        self.seen = 0
        self.events: List[dict] = []
        self._t: Optional[float] = None

    def start(self) -> None:
        self._t = time.monotonic()

    def stop(self, step: int) -> float:
        if self._t is None:  # stop() without start(): no-op, not TypeError
            return 0.0
        dt = time.monotonic() - self._t
        self._t = None
        self.observe(step, dt)
        return dt

    def observe(self, step: int, dt: float) -> bool:
        """Feed a step time; returns True if flagged as straggler."""
        self.seen += 1
        if self.avg is None:
            self.avg = dt
            return False
        flagged = (self.seen > self.warmup and
                   dt > self.threshold * self.avg)
        if flagged:
            self.events.append({"step": step, "dt": dt, "avg": self.avg})
            if self.on_straggler:
                self.on_straggler(step, dt, self.avg)
            # don't poison the EWMA with the outlier
            return True
        self.avg = self.ewma_coef * self.avg + (1 - self.ewma_coef) * dt
        return False
