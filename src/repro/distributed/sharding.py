"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every tensor dim in the framework is annotated with a *logical* axis name
(``'batch'``, ``'rank'``, ``'ffw'``, …).  A *sharding profile* maps logical
names to mesh-axis tuples.  Resolution degrades gracefully:

* mesh axes absent from the current mesh are dropped (so one rule table
  serves the single-pod ``('data','model')`` and multi-pod
  ``('pod','data','model')`` meshes);
* if the dim size is not divisible by the mesh-axes product, axes are
  dropped from the left until it is (e.g. whisper's 6 heads on a 16-way
  'model' axis ⇒ replicated);
* a mesh axis already used by an earlier dim of the same tensor is skipped
  (PartitionSpec forbids reuse).

Profiles are the hillclimb lever for the collective roofline term:

``baseline``  — TP on the CoLA *rank* axis (the naive port: every AE pair
                psums its full output; 7 all-reduces/block),
``megatron``  — output-dim TP adapted to CoLA (heads/ffw sharded; psum only
                at o-proj and down-proj: 2 all-reduces/block at ~½ compute),
``fsdp``      — no tensor parallelism; 'model' joins the batch axes.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, NamedTuple, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

PSpec = PartitionSpec

# A rule value is a tuple of mesh axis names (sharded over their product).
Rules = Dict[str, Tuple[str, ...]]

_COMMON: Rules = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    # sequence-sharding for *saved* activations (the scan carry between
    # blocks): Megatron-SP semantics — residual stream lives seq-sharded
    # over 'model', all-gathered at block entry.  Keeps the CoLA-M residual
    # stack (periods, b, s, d) at 1/16 the footprint.
    "seq_save": ("model",),
    "kv_seq": ("model",),      # long-context KV cache: flash-decode sharding
    "embed": (),
    "layers": (),
    "head_dim": (),
    "state": (),
    "conv": (),
    "mrope": (),
    "null": (),
}

PROFILES: Dict[str, Rules] = {
    # --- naive TP on the CoLA bottleneck (paper-faithful first port) ------
    "baseline": {
        **_COMMON,
        "rank": ("model",),
        "heads": (),
        "kv_heads": (),
        "ffw": (),
        "expert": ("model",),
        "vocab": ("model",),
        "w_fsdp": ("data",),       # FSDP dim of weights (single-pod)
        "w_fsdp2": ("pod", "data"),  # FSDP dim incl. pod axis (weights only)
        "act_rank": ("model",),
        "act_heads": (),
        "act_ffw": (),
    },
    # --- Megatron-adapted CoLA: shard outer dims, psum at block exits -----
    "megatron": {
        **_COMMON,
        "rank": (),                 # A factors replicated on 'model'
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffw": ("model",),
        "expert": ("model",),
        "vocab": ("model",),
        "w_fsdp": ("data",),
        "w_fsdp2": ("pod", "data"),
        "act_rank": (),
        "act_heads": ("model",),
        "act_ffw": ("model",),
    },
    # --- pure FSDP / ZeRO-3 (model axis folded into batch) ---------------
    "fsdp": {
        **_COMMON,
        "batch": ("pod", "data", "model"),
        "seq_save": (),
        "rank": (),
        "heads": (),
        "kv_heads": (),
        "ffw": (),
        "expert": (),
        "vocab": (),
        "kv_seq": (),
        "w_fsdp": ("data", "model"),
        "w_fsdp2": ("pod", "data", "model"),
        "act_rank": (),
        "act_heads": (),
        "act_ffw": (),
    },
}


@dataclass
class MeshEnv:
    """Active mesh + profile; threaded through via a context manager."""
    mesh: Mesh
    profile: str = "baseline"
    overrides: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    @property
    def rules(self) -> Rules:
        base = PROFILES[self.profile]
        if self.overrides:
            merged = dict(base)
            merged.update(self.overrides)
            return merged
        return base

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)


_tls = threading.local()


def current_env() -> Optional[MeshEnv]:
    return getattr(_tls, "env", None)


@contextlib.contextmanager
def mesh_env(mesh: Mesh, profile: str = "baseline",
             overrides: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = current_env()
    _tls.env = MeshEnv(mesh, profile, overrides or {})
    try:
        with mesh:
            yield _tls.env
    finally:
        _tls.env = prev


@contextlib.contextmanager
def use_env(env: MeshEnv):
    """Re-enter an existing MeshEnv.  The serve engine holds one env for
    its whole lifetime and re-enters it around every jitted dispatch so
    the trace (and any retrace) sees the same mesh/profile — force_impl
    and friends act at trace time, and so does this."""
    prev = current_env()
    _tls.env = env
    try:
        with env.mesh:
            yield env
    finally:
        _tls.env = prev


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------
def _resolve_dim(env: MeshEnv, name: Optional[str], size: Optional[int],
                 used: set) -> Optional[Any]:
    if name is None:
        return None
    rule = env.rules.get(name)
    if rule is None:
        raise KeyError(f"no sharding rule for logical axis '{name}' "
                       f"(profile={env.profile})")
    # drop axes absent from the mesh or already used
    axes = [a for a in rule if a in env.mesh.shape and a not in used]
    # drop from the left until the dim divides evenly
    while axes:
        prod = int(np.prod([env.axis_size(a) for a in axes]))
        if size is None or (prod > 0 and size % prod == 0):
            break
        axes = axes[1:]
    if not axes:
        return None
    used.update(axes)
    return tuple(axes) if len(axes) > 1 else axes[0]


def logical_to_pspec(axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None,
                     env: Optional[MeshEnv] = None) -> PartitionSpec:
    """Resolve logical axis names to a PartitionSpec under the active mesh."""
    env = env or current_env()
    if env is None:
        return PartitionSpec(*([None] * len(axes)))
    used: set = set()
    entries = []
    for i, name in enumerate(axes):
        size = None if shape is None else shape[i]
        entries.append(_resolve_dim(env, name, size, used))
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Attach a sharding constraint by logical axis names (no-op w/o mesh)."""
    env = current_env()
    if env is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} tensor")
    spec = logical_to_pspec(axes, x.shape, env)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, spec))


# --------------------------------------------------------------------------
# Tree helpers (params / states carry a parallel tree of logical-axes tuples)
# --------------------------------------------------------------------------
def spec_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    """Map a tree of logical-axes tuples + shapes -> tree of PartitionSpec."""
    env = env or current_env()
    return jax.tree.map(
        lambda axes, shp: logical_to_pspec(axes, shp.shape, env),
        axes_tree, shape_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            x is None or isinstance(x, str) for x in a),
    )


def named_sharding_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    env = env or current_env()
    if env is None:
        raise RuntimeError("named_sharding_tree requires an active mesh_env")
    specs = spec_tree(axes_tree, shape_tree, env)
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))


# --------------------------------------------------------------------------
# Parameter shardings with automatic FSDP fill (ZeRO-3)
# --------------------------------------------------------------------------
_NO_FILL = {"layers", "null", "conv", "state", "mrope"}


def param_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                env: Optional[MeshEnv] = None) -> PartitionSpec:
    """Like logical_to_pspec, then greedily shard the largest still-
    unsharded eligible dim over the remaining FSDP axes ('pod','data').

    This gives every weight/optimizer-state tensor a ZeRO-3 layout without
    per-site annotations: semantic axes (rank/heads/ffw/expert/vocab) take
    'model'; the fattest leftover dim takes the data axes.  Dims named in
    ``_NO_FILL`` (scan/layers etc.) are never filled.
    """
    env = env or current_env()
    if env is None:
        return PartitionSpec(*([None] * len(axes)))
    used: set = set()
    entries = [_resolve_dim(env, name, shape[i], used)
               for i, name in enumerate(axes)]
    fsdp = [a for a in ("pod", "data") if a in env.mesh.shape
            and a not in used]
    if fsdp:
        # candidate dims: unsharded, eligible, divisible — largest first
        cands = sorted(
            (i for i in range(len(axes))
             if entries[i] is None and (axes[i] not in _NO_FILL)),
            key=lambda i: -shape[i])
        for i in cands:
            axes_try = list(fsdp)
            while axes_try:
                prod = int(np.prod([env.axis_size(a) for a in axes_try]))
                if shape[i] % prod == 0:
                    entries[i] = (tuple(axes_try) if len(axes_try) > 1
                                  else axes_try[0])
                    fsdp = [a for a in fsdp if a not in axes_try]
                    break
                axes_try = axes_try[1:]
            if not fsdp:
                break
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def param_sharding_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    """NamedSharding tree for parameters/optimizer states (FSDP-filled)."""
    env = env or current_env()
    if env is None:
        raise RuntimeError("param_sharding_tree requires an active mesh_env")
    specs = jax.tree.map(
        lambda axes, shp: param_pspec(axes, shp.shape, env),
        axes_tree, shape_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            x is None or isinstance(x, str) for x in a))
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))


# --------------------------------------------------------------------------
# Fused CoLA-AE partitioning (kernels/cola_ae/ops.cola_ae_sharded)
# --------------------------------------------------------------------------
def _entry_axes(entry: Optional[Any]) -> Tuple[str, ...]:
    """PartitionSpec entry -> tuple of mesh axis names."""
    if entry is None:
        return ()
    return entry if isinstance(entry, tuple) else (entry,)


class ColaAePartition(NamedTuple):
    """shard_map partitioning of one AE site ``out = B·σ(A·x [+ba]) [+bb]``.

    Specs (global-array views; shard_map reshards inputs to match, which is
    exactly the GSPMD layout the unfused path would use — e.g. FSDP-stored
    weight dims are all-gathered on entry):

    * ``x_spec``    — (b, s, d_in): batch over the data axes, seq over the
                      profile's 'seq_save' axes when they don't collide
                      with batch/d_in (the sequence-parallel entry: the
                      shard_map body gathers explicitly ahead of stage A
                      instead of GSPMD gathering implicitly outside), d_in
                      over the weight's in-axis resolution (row-parallel),
    * ``a_spec``    — (d_in, r), ``b_spec`` — (r, d_out),
    * ``out_spec``  — (b, s, d_out),
    * ``zpre_spec`` — (b·s, r): the f32 pre-activation residual the fused
                      VJP saves; its rank dim carries the same mesh axes as
                      the weights' rank dim, so the saved tensor is 1/|model|
                      per device under the ``baseline`` profile,
    * ``bias_a_spec`` — (r,) on the rank axes; ``bias_b_spec`` — (d_out,)
                      on the out axes (bias-carrying sites only).

    Axis groups (mesh axes to ``psum``/gather over; empty = no collective):

    * ``in_axes``   — shard d_in (megatron row-parallel: o-proj/down-proj);
                      psum of z_pre between stage A and stage B,
    * ``rank_axes`` — shard r (baseline profile); psum of the B-GEMM output
                      in fwd and of ``dz·Aᵀ`` in bwd,
    * ``out_axes``  — shard d_out (megatron column-parallel: qkv/gate/up);
                      psum of the r-dim ``g·Bᵀ`` partial in bwd, between
                      the bwd_dzl kernel and the σ′ product,
    * ``batch_axes``— shard tokens; psum of dA/dB (the per-site slice of the
                      data-parallel gradient all-reduce),
    * ``seq_axes``  — the sequence-sharded entry: explicit ``all_gather``
                      of x at body entry (fwd and bwd), with dx re-sharded
                      on exit (psum_scatter when it rides the rank psum).
    """
    x_spec: PartitionSpec
    a_spec: PartitionSpec
    b_spec: PartitionSpec
    out_spec: PartitionSpec
    zpre_spec: PartitionSpec
    bias_a_spec: PartitionSpec
    bias_b_spec: PartitionSpec
    in_axes: Tuple[str, ...]
    rank_axes: Tuple[str, ...]
    out_axes: Tuple[str, ...]
    batch_axes: Tuple[str, ...]
    seq_axes: Tuple[str, ...]


def cola_ae_partition(env: MeshEnv, x_shape: Sequence[int],
                      a_shape: Sequence[int], b_shape: Sequence[int],
                      in_ax: Optional[str], out_ax: Optional[str]
                      ) -> ColaAePartition:
    """Jointly resolve the sharding of one AE site under ``env``.

    Resolution order makes the factor pair consistent by construction: the
    rank dim resolves first (A's col dim and B's row dim must agree — under
    ``baseline`` rank wins the 'model' axis even at sites whose in-axis is
    itself 'rank', e.g. MLA's uq), then d_in avoiding rank's axes, then
    d_out avoiding rank's axes, then batch avoiding all three, then the
    seq entry avoiding x's other dims (batch + d_in — so row-parallel
    sites, whose d_in owns 'model', keep a seq-replicated in_spec).  Every
    entry inherits `_resolve_dim`'s divisibility fallback, so non-dividing
    dims degrade to replicated instead of producing an invalid shard_map
    spec.
    """
    d_in, r = a_shape
    d_out = b_shape[1]
    used: set = set()
    erank = _resolve_dim(env, "rank", r, used)
    ein = (_resolve_dim(env, in_ax, d_in, used)
           if in_ax is not None else None)
    used_b = set(_entry_axes(erank))
    eout = (_resolve_dim(env, out_ax, d_out, used_b)
            if out_ax is not None else None)
    used_x = (set(_entry_axes(erank)) | set(_entry_axes(ein))
              | set(_entry_axes(eout)))
    ebatch = _resolve_dim(env, "batch", x_shape[0], used_x)
    used_seq = set(_entry_axes(ebatch)) | set(_entry_axes(ein))
    eseq = _resolve_dim(env, "seq_save", x_shape[1], used_seq)
    return ColaAePartition(
        x_spec=PartitionSpec(ebatch, eseq, ein),
        a_spec=PartitionSpec(ein, erank),
        b_spec=PartitionSpec(erank, eout),
        out_spec=PartitionSpec(ebatch, None, eout),
        zpre_spec=PartitionSpec(ebatch, erank),
        bias_a_spec=PartitionSpec(erank),
        bias_b_spec=PartitionSpec(eout),
        in_axes=_entry_axes(ein),
        rank_axes=_entry_axes(erank),
        out_axes=_entry_axes(eout),
        batch_axes=_entry_axes(ebatch),
        seq_axes=_entry_axes(eseq),
    )


def cola_ae_quant_specs(part: ColaAePartition):
    """(sa_spec, sb_spec) for a quantized site's scale arrays under
    ``part``.  Factors are quantized once *globally* and the arrays are
    sharded: the per-row/per-column scale layouts commute with d_in /
    d_out / rank sharding, so sharded quantized decode streams local
    q-blocks with local scales and stays bit-identical to the
    single-device quantized engine (per-shard re-quantization would not:
    a rank-sharded A row's max|w| differs per shard).

    The q arrays reuse ``part.a_spec`` / ``part.b_spec`` verbatim —
    PartitionSpecs carry block semantics, so int4's halved packed axis
    shards correctly as long as the *local* packed extent is whole
    (ops validates local evenness).  Scales:

    * ``sa`` (d_in, 1): one f32 per A input row — shards with d_in
      (``a_spec``'s first entry), replicated over rank,
    * ``sb`` (1, d_out): one f32 per B output column — shards with d_out
      (``b_spec``'s second entry), replicated over rank.
    """
    return (PartitionSpec(part.a_spec[0], None),
            PartitionSpec(None, part.b_spec[1]))


def cola_ae_collective_bytes(env: MeshEnv, part: ColaAePartition, T: int,
                             d_in: int, r: int, d_out: int, *,
                             bytes_el: int = 2, mode: str = "train") -> int:
    """Modeled collective wire bytes for one fwd+bwd of a sharded fused AE
    site (ring collectives: ``2(n-1)/n ×`` payload per all-reduce,
    ``(n-1)/n ×`` per all-gather / reduce-scatter).

    ``mode='infer'`` models one forward of the fwd-only serve body
    (``ops._sh_infer``: prefill or a decode chunk step) — the sequence-
    entry x all-gather once (no bwd recompute gather), the f32 z_pre
    ring-psum at row-parallel sites (the decode_split seam), and the out
    ring-psum at rank-sharded sites; no bwd terms.  These are the
    ``serve_sharded/*`` rows' modeled wire bytes per dispatch.

    Per profile and site this reproduces the design counts: ``baseline``
    pays a (T, d_out) psum in fwd and a (T, d_in) psum in bwd at *every*
    site (7×2/block — the naive port); ``megatron`` pays one f32 (T, r)
    psum per site — fwd at row-parallel sites (o/down: the 2-all-reduce/
    block exits, now placed between the stage kernels), bwd at column-
    parallel sites (qkv/gate/up, between bwd_dzl and σ′) — r-dim, so ~d/r
    cheaper than baseline's; ``fsdp`` pays none.  The sequence-parallel
    entry adds two x all-gathers (fwd + the bwd recompute gather); when the
    dx psum rides the same axes as the seq shard, the exit is a
    reduce-scatter at half the all-reduce wire cost.  The dA/dB psums over
    the batch axes are excluded: they are the per-site slice of the data-
    parallel gradient all-reduce every strategy pays identically.  Token
    psum payloads are the per-device **local** token count (T divided by
    the batch-axes product): inside shard_map each device all-reduces only
    its own token shard.
    """
    def _n(axes: Tuple[str, ...]) -> int:
        return int(np.prod([env.axis_size(a) for a in axes])) if axes else 1

    def ring(axes: Tuple[str, ...], payload: int) -> int:
        n = _n(axes)
        return 0 if n <= 1 else int(2 * (n - 1) / n * payload)

    def half_ring(axes: Tuple[str, ...], payload: int) -> int:
        n = _n(axes)
        return 0 if n <= 1 else int((n - 1) / n * payload)

    if mode not in ("train", "infer"):
        raise ValueError(f"mode must be 'train'|'infer', got {mode!r}")
    t_loc = T // _n(part.batch_axes)
    if mode == "infer":
        return (half_ring(part.seq_axes, bytes_el * t_loc * d_in)  # x gather
                + ring(part.in_axes, 4 * t_loc * r)   # z_pre psum (split seam)
                + ring(part.rank_axes, bytes_el * t_loc * d_out))  # out psum
    if part.rank_axes and part.seq_axes == part.rank_axes:
        # bwd dx: psum_scatter instead of psum-then-slice
        dx_bytes = half_ring(part.rank_axes, bytes_el * t_loc * d_in)
    else:
        dx_bytes = ring(part.rank_axes, bytes_el * t_loc * d_in)
    return (2 * half_ring(part.seq_axes, bytes_el * t_loc * d_in)  # x gathers
            + ring(part.in_axes, 4 * t_loc * r)       # fwd psum of z_pre
            + ring(part.rank_axes, bytes_el * t_loc * d_out)  # fwd: out
            + dx_bytes                                # bwd: dx
            + ring(part.out_axes, 4 * t_loc * r))     # bwd psum of g·Bᵀ
