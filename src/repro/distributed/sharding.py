"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Every tensor dim in the framework is annotated with a *logical* axis name
(``'batch'``, ``'rank'``, ``'ffw'``, …).  A *sharding profile* maps logical
names to mesh-axis tuples.  Resolution degrades gracefully:

* mesh axes absent from the current mesh are dropped (so one rule table
  serves the single-pod ``('data','model')`` and multi-pod
  ``('pod','data','model')`` meshes);
* if the dim size is not divisible by the mesh-axes product, axes are
  dropped from the left until it is (e.g. whisper's 6 heads on a 16-way
  'model' axis ⇒ replicated);
* a mesh axis already used by an earlier dim of the same tensor is skipped
  (PartitionSpec forbids reuse).

Profiles are the hillclimb lever for the collective roofline term:

``baseline``  — TP on the CoLA *rank* axis (the naive port: every AE pair
                psums its full output; 7 all-reduces/block),
``megatron``  — output-dim TP adapted to CoLA (heads/ffw sharded; psum only
                at o-proj and down-proj: 2 all-reduces/block at ~½ compute),
``fsdp``      — no tensor parallelism; 'model' joins the batch axes.
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

PSpec = PartitionSpec

# A rule value is a tuple of mesh axis names (sharded over their product).
Rules = Dict[str, Tuple[str, ...]]

_COMMON: Rules = {
    # activations
    "batch": ("pod", "data"),
    "seq": (),
    # sequence-sharding for *saved* activations (the scan carry between
    # blocks): Megatron-SP semantics — residual stream lives seq-sharded
    # over 'model', all-gathered at block entry.  Keeps the CoLA-M residual
    # stack (periods, b, s, d) at 1/16 the footprint.
    "seq_save": ("model",),
    "kv_seq": ("model",),      # long-context KV cache: flash-decode sharding
    "embed": (),
    "layers": (),
    "head_dim": (),
    "state": (),
    "conv": (),
    "mrope": (),
    "null": (),
}

PROFILES: Dict[str, Rules] = {
    # --- naive TP on the CoLA bottleneck (paper-faithful first port) ------
    "baseline": {
        **_COMMON,
        "rank": ("model",),
        "heads": (),
        "kv_heads": (),
        "ffw": (),
        "expert": ("model",),
        "vocab": ("model",),
        "w_fsdp": ("data",),       # FSDP dim of weights (single-pod)
        "w_fsdp2": ("pod", "data"),  # FSDP dim incl. pod axis (weights only)
        "act_rank": ("model",),
        "act_heads": (),
        "act_ffw": (),
    },
    # --- Megatron-adapted CoLA: shard outer dims, psum at block exits -----
    "megatron": {
        **_COMMON,
        "rank": (),                 # A factors replicated on 'model'
        "heads": ("model",),
        "kv_heads": ("model",),
        "ffw": ("model",),
        "expert": ("model",),
        "vocab": ("model",),
        "w_fsdp": ("data",),
        "w_fsdp2": ("pod", "data"),
        "act_rank": (),
        "act_heads": ("model",),
        "act_ffw": ("model",),
    },
    # --- pure FSDP / ZeRO-3 (model axis folded into batch) ---------------
    "fsdp": {
        **_COMMON,
        "batch": ("pod", "data", "model"),
        "seq_save": (),
        "rank": (),
        "heads": (),
        "kv_heads": (),
        "ffw": (),
        "expert": (),
        "vocab": (),
        "kv_seq": (),
        "w_fsdp": ("data", "model"),
        "w_fsdp2": ("pod", "data", "model"),
        "act_rank": (),
        "act_heads": (),
        "act_ffw": (),
    },
}


@dataclass
class MeshEnv:
    """Active mesh + profile; threaded through via a context manager."""
    mesh: Mesh
    profile: str = "baseline"
    overrides: Dict[str, Tuple[str, ...]] = field(default_factory=dict)

    @property
    def rules(self) -> Rules:
        base = PROFILES[self.profile]
        if self.overrides:
            merged = dict(base)
            merged.update(self.overrides)
            return merged
        return base

    def axis_size(self, name: str) -> int:
        return self.mesh.shape.get(name, 1)


_tls = threading.local()


def current_env() -> Optional[MeshEnv]:
    return getattr(_tls, "env", None)


@contextlib.contextmanager
def mesh_env(mesh: Mesh, profile: str = "baseline",
             overrides: Optional[Dict[str, Tuple[str, ...]]] = None):
    prev = current_env()
    _tls.env = MeshEnv(mesh, profile, overrides or {})
    try:
        with mesh:
            yield _tls.env
    finally:
        _tls.env = prev


# --------------------------------------------------------------------------
# Resolution
# --------------------------------------------------------------------------
def _resolve_dim(env: MeshEnv, name: Optional[str], size: Optional[int],
                 used: set) -> Optional[Any]:
    if name is None:
        return None
    rule = env.rules.get(name)
    if rule is None:
        raise KeyError(f"no sharding rule for logical axis '{name}' "
                       f"(profile={env.profile})")
    # drop axes absent from the mesh or already used
    axes = [a for a in rule if a in env.mesh.shape and a not in used]
    # drop from the left until the dim divides evenly
    while axes:
        prod = int(np.prod([env.axis_size(a) for a in axes]))
        if size is None or (prod > 0 and size % prod == 0):
            break
        axes = axes[1:]
    if not axes:
        return None
    used.update(axes)
    return tuple(axes) if len(axes) > 1 else axes[0]


def logical_to_pspec(axes: Sequence[Optional[str]],
                     shape: Optional[Sequence[int]] = None,
                     env: Optional[MeshEnv] = None) -> PartitionSpec:
    """Resolve logical axis names to a PartitionSpec under the active mesh."""
    env = env or current_env()
    if env is None:
        return PartitionSpec(*([None] * len(axes)))
    used: set = set()
    entries = []
    for i, name in enumerate(axes):
        size = None if shape is None else shape[i]
        entries.append(_resolve_dim(env, name, size, used))
    # trim trailing Nones (canonical form)
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Attach a sharding constraint by logical axis names (no-op w/o mesh)."""
    env = current_env()
    if env is None:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} tensor")
    spec = logical_to_pspec(axes, x.shape, env)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(env.mesh, spec))


# --------------------------------------------------------------------------
# Tree helpers (params / states carry a parallel tree of logical-axes tuples)
# --------------------------------------------------------------------------
def spec_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    """Map a tree of logical-axes tuples + shapes -> tree of PartitionSpec."""
    env = env or current_env()
    return jax.tree.map(
        lambda axes, shp: logical_to_pspec(axes, shp.shape, env),
        axes_tree, shape_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            x is None or isinstance(x, str) for x in a),
    )


def named_sharding_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    env = env or current_env()
    if env is None:
        raise RuntimeError("named_sharding_tree requires an active mesh_env")
    specs = spec_tree(axes_tree, shape_tree, env)
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))


# --------------------------------------------------------------------------
# Parameter shardings with automatic FSDP fill (ZeRO-3)
# --------------------------------------------------------------------------
_NO_FILL = {"layers", "null", "conv", "state", "mrope"}


def param_pspec(axes: Sequence[Optional[str]], shape: Sequence[int],
                env: Optional[MeshEnv] = None) -> PartitionSpec:
    """Like logical_to_pspec, then greedily shard the largest still-
    unsharded eligible dim over the remaining FSDP axes ('pod','data').

    This gives every weight/optimizer-state tensor a ZeRO-3 layout without
    per-site annotations: semantic axes (rank/heads/ffw/expert/vocab) take
    'model'; the fattest leftover dim takes the data axes.  Dims named in
    ``_NO_FILL`` (scan/layers etc.) are never filled.
    """
    env = env or current_env()
    if env is None:
        return PartitionSpec(*([None] * len(axes)))
    used: set = set()
    entries = [_resolve_dim(env, name, shape[i], used)
               for i, name in enumerate(axes)]
    fsdp = [a for a in ("pod", "data") if a in env.mesh.shape
            and a not in used]
    if fsdp:
        # candidate dims: unsharded, eligible, divisible — largest first
        cands = sorted(
            (i for i in range(len(axes))
             if entries[i] is None and (axes[i] not in _NO_FILL)),
            key=lambda i: -shape[i])
        for i in cands:
            axes_try = list(fsdp)
            while axes_try:
                prod = int(np.prod([env.axis_size(a) for a in axes_try]))
                if shape[i] % prod == 0:
                    entries[i] = (tuple(axes_try) if len(axes_try) > 1
                                  else axes_try[0])
                    fsdp = [a for a in fsdp if a not in axes_try]
                    break
                axes_try = axes_try[1:]
            if not fsdp:
                break
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def param_sharding_tree(axes_tree, shape_tree, env: Optional[MeshEnv] = None):
    """NamedSharding tree for parameters/optimizer states (FSDP-filled)."""
    env = env or current_env()
    if env is None:
        raise RuntimeError("param_sharding_tree requires an active mesh_env")
    specs = jax.tree.map(
        lambda axes, shp: param_pspec(axes, shp.shape, env),
        axes_tree, shape_tree,
        is_leaf=lambda a: isinstance(a, tuple) and all(
            x is None or isinstance(x, str) for x in a))
    return jax.tree.map(lambda s: NamedSharding(env.mesh, s), specs,
                        is_leaf=lambda s: isinstance(s, PartitionSpec))
