"""Elastic restart: resume a checkpoint on a different mesh.

Checkpoints are host numpy keyed by pytree path (checkpoint/manager.py), so
elasticity is just "device_put with the new mesh's shardings".  This module
adds the bookkeeping a real fleet needs: recompute shardings for the new
mesh, validate divisibility (the sharding rules degrade to replication when
an axis no longer divides), and rescale the data-pipeline sharding.

Restart targets the newest checkpoint that passes manifest verification
(``latest_good_step``) — an elastic restart after a crash is exactly when
a half-written or corrupt checkpoint is most likely, so the corrupt one is
skipped, not served (tests/test_checkpoint.py exercises both).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig, TrainConfig
from repro.distributed.sharding import MeshEnv, param_sharding_tree
from repro.models.model import build_model
from repro.train import step as step_mod


def resume_on_mesh(ckpt_dir: str, mc: ModelConfig, tc: TrainConfig,
                   env: MeshEnv, step: Optional[int] = None
                   ) -> Tuple[step_mod.TrainState, int]:
    """Load the latest (or given) checkpoint onto `env`'s mesh — the mesh
    may differ arbitrarily from the one that wrote the checkpoint."""
    model = build_model(mc)
    mgr = CheckpointManager(ckpt_dir)
    step = step if step is not None else mgr.latest_good_step()
    if step is None:
        raise FileNotFoundError(
            f"no verifiable checkpoints in {ckpt_dir}")
    template = step_mod.abstract_train_state(model, tc)
    axes = step_mod.train_state_axes(model, tc)
    shardings = param_sharding_tree(axes, template, env)
    state = mgr.restore(step, template, shardings)
    return state, int(mgr.restore_extra(step)["step"])
