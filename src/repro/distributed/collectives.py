"""Explicit collective helpers (shard_map level).

Most collectives in this framework are *derived* by the SPMD partitioner
from sharding constraints; these helpers exist for the paths where explicit
scheduling wins (flash-decode over a sequence-sharded KV cache, int8
compressed all-reduce, ring all-gather for the pipeline stage loop).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def flash_decode_attention(mesh: Mesh, q: jax.Array, k: jax.Array,
                           v: jax.Array, lengths: jax.Array,
                           axis: str = "model") -> jax.Array:
    """Decode attention over a KV cache whose *sequence* dim is sharded.

    q: (b, 1, h, hd) replicated over `axis`; k/v: (b, S, kv, hd) sharded on
    dim 1.  Each rank computes partial scores over its S/n slice with a
    numerically-stable local softmax, then partials are combined with a
    logsumexp reduction (psum of (m, l, o) statistics) — the flash-decoding
    schedule, written explicitly for the serve engine.
    """
    b, _, h, hd = q.shape
    kv = k.shape[2]
    group = h // kv

    def body(qb, kb, vb, ln):
        n = jax.lax.psum(1, axis)
        rank = jax.lax.axis_index(axis)
        S_local = kb.shape[1]
        base = rank * S_local
        qg = qb.reshape(b, kv, group, hd)
        s = jnp.einsum("bkgh,bskh->bkgs", qg, kb).astype(jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        idx = base + jnp.arange(S_local)
        valid = idx[None, :] < ln[:, None]                  # (b, S_local)
        s = jnp.where(valid[:, None, None, :], s, -jnp.inf)
        m = jnp.max(s, axis=-1)                             # (b, kv, g)
        m = jnp.maximum(m, -1e30)  # all-masked shard guard
        e = jnp.exp(s - m[..., None])
        l = jnp.sum(e, axis=-1)
        o = jnp.einsum("bkgs,bskh->bkgh", e.astype(qb.dtype), vb)
        # logsumexp combine across shards
        m_all = jax.lax.pmax(m, axis)
        scale = jnp.exp(m - m_all)
        l_all = jax.lax.psum(l * scale, axis)
        o_all = jax.lax.psum(o * scale[..., None].astype(o.dtype), axis)
        out = o_all / jnp.maximum(l_all, 1e-30)[..., None].astype(o.dtype)
        return out.reshape(b, 1, h, hd)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None, None, None), P(None, axis, None, None),
                  P(None, axis, None, None), P(None)),
        out_specs=P(None, None, None, None),
        check_rep=False)(q, k, v, lengths)


def ring_all_gather(mesh: Mesh, x: jax.Array, axis: str) -> jax.Array:
    """Ring all-gather via collective_permute (N-1 hops) — the schedule a
    bandwidth-optimal ICI all-gather uses; exercised by tests and available
    to the pipeline loop."""
    def body(xl):
        n = jax.lax.psum(1, axis)
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % n) for i in range(n)]
        chunks = [xl]
        cur = xl
        for _ in range(n - 1):
            cur = jax.lax.ppermute(cur, axis, perm)
            chunks.append(cur)
        # rank r holds [r, r-1, ..., r-n+1]; roll into canonical order
        stacked = jnp.stack(chunks)                          # (n, ...)
        order = (idx - jnp.arange(n)) % n
        canon = jnp.zeros_like(stacked).at[order].set(stacked)
        return jnp.concatenate(list(canon), axis=0)

    return shard_map(body, mesh=mesh, in_specs=P(axis),
                     out_specs=P(None), check_rep=False)(x)
