from repro.distributed.sharding import (  # noqa: F401
    MeshEnv,
    PSpec,
    current_env,
    logical_to_pspec,
    mesh_env,
    named_sharding_tree,
    shard,
    spec_tree,
)
