"""GPipe-style pipeline parallelism over a mesh axis (optional feature).

The default multi-pod layout uses the 'pod' axis as extra data parallelism
(FSDP); this module provides the alternative: map layer *periods* onto
pipeline stages along an axis and run the classic GPipe microbatch schedule
with ``ppermute`` hops between stages.

Implementation: stage-local parameters arrive via shard_map in_specs
(stacked period params sharded on the leading 'layers' dim); microbatches
stream through a ``lax.scan`` over (num_micro + num_stages - 1) ticks —
the standard bubble.  Activations hop stages with collective_permute.

This is exercised at test scale (4 stages on 4 CPU devices) and available
from the launcher via ``--pipeline pod``; the dry-run exercises the default
FSDP-over-pod layout, and EXPERIMENTS.md §Perf discusses when PP beats FSDP
for the 400B cell (weights-AG-bound at small per-pod batch).
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def pipeline_forward(mesh: Mesh, axis: str, stage_fn: Callable,
                     stage_params, x: jax.Array, num_micro: int
                     ) -> jax.Array:
    """Run x through num_stages stages of `stage_fn` laid out on `axis`.

    stage_params: pytree whose leaves are stacked (num_stages, ...);
    x: (num_micro * mb, ...) global batch. Returns the pipeline output
    (valid on every rank, broadcast from the last stage).
    """
    n_stage = mesh.shape[axis]

    def body(params_local, xl):
        # params_local: leaves (1, ...) — this stage's slice
        p = jax.tree.map(lambda w: w[0], params_local)
        stage = jax.lax.axis_index(axis)
        micro = jnp.split(xl, num_micro, axis=0)
        micro = jnp.stack(micro)                     # (num_micro, mb, ...)
        ticks = num_micro + n_stage - 1
        fwd_perm = [(i, i + 1) for i in range(n_stage - 1)]

        def tick(carry, t):
            buf, out = carry                          # buf: (mb, ...) in-flight
            # stage 0 injects microbatch t (if any)
            inject = jnp.where(t < num_micro, t, num_micro - 1)
            x_in = jnp.where(stage == 0,
                             micro[inject], buf)
            y = stage_fn(p, x_in)
            # last stage emits result for microbatch (t - n_stage + 1)
            emit_idx = t - (n_stage - 1)
            do_emit = (emit_idx >= 0) & (stage == n_stage - 1)
            out = jax.lax.cond(
                do_emit,
                lambda o: o.at[jnp.maximum(emit_idx, 0)].set(y),
                lambda o: o, out)
            nxt = jax.lax.ppermute(y, axis, fwd_perm)
            return (nxt, out), None

        mb_shape = micro[0].shape
        out0 = jnp.zeros((num_micro,) + tuple(mb_shape), x.dtype)
        (_, out), _ = jax.lax.scan(
            tick, (jnp.zeros(mb_shape, x.dtype), out0),
            jnp.arange(ticks))
        # broadcast final outputs from the last stage to all ranks
        # (masked psum: multicast ppermute is not portable)
        out = jnp.where(stage == n_stage - 1, out, jnp.zeros_like(out))
        out = jax.lax.psum(out, axis)
        return out.reshape((-1,) + tuple(mb_shape[1:]))

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(body, mesh=mesh,
                     in_specs=(pspec, P(None)),
                     out_specs=P(None), check_rep=False)(stage_params, x)
