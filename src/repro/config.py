"""Configuration system for the CoLA reproduction framework.

Frozen dataclasses + a registry keyed by ``--arch`` id.  Every assigned
architecture lives in ``repro/configs/<id>.py`` and registers a
:class:`ModelConfig`; input-shape cells are :class:`ShapeSpec` entries shared
across the LM family.

Design notes
------------
* Configs are *plain data* — no jax imports here, so importing a config never
  touches device state (required for the dry-run's XLA_FLAGS ordering).
* ``parameterization`` selects how every linear site is realized:
  ``dense`` (full-rank baseline), ``cola`` (the paper), ``lora`` (ReLoRA
  baseline), ``sltrain`` (low-rank + sparse baseline).
* ``cola_sigma`` follows paper Appendix E.1 Table 10.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

# --------------------------------------------------------------------------
# Enums (plain strings to keep configs JSON-serializable)
# --------------------------------------------------------------------------
PARAMETERIZATIONS = ("dense", "cola", "lora", "sltrain")
ATTENTION_KINDS = ("gqa", "mla", "none")  # "none" => attention-free (rwkv)
BLOCK_KINDS = ("attn", "mamba", "rwkv6")
ROPE_KINDS = ("rope", "mrope", "none")
COLA_SIGMA = ("both", "lowrank_only", "reduced", "fullrank_only")
REMAT_POLICIES = ("none", "full", "cola_m", "dots")
FAMILIES = ("dense", "moe", "ssm", "hybrid", "audio", "vlm")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 1
    capacity_factor: float = 1.25
    # apply MoE every `interleave_step` layers (1 = every layer, 2 = alternate)
    interleave_step: int = 1
    # dense d_ff used on the non-MoE layers when interleave_step > 1
    dense_d_ff: int = 0
    # shared expert (llama4-style); 0 disables
    shared_expert_d_ff: int = 0
    # router jitter / z-loss
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2

    @property
    def enabled(self) -> bool:
        return self.num_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head latent attention (DeepSeek/MiniCPM3 style)."""
    kv_lora_rank: int = 256
    q_lora_rank: int = 768
    qk_rope_head_dim: int = 32
    qk_nope_head_dim: int = 64
    v_head_dim: int = 64


@dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class ColaConfig:
    """CoLA knobs (paper §3, App. D/E)."""
    rank_attn: int = 0          # 0 => d_model // 4
    rank_mlp: int = 0           # 0 => d_model // 4
    sigma: str = "lowrank_only"  # COLA_SIGMA
    # Use the fused Pallas auto-encoder path (forward AND backward: the
    # custom VJP saves only the r-dim z_pre residual) when on TPU.
    # Threaded models/linear.py → core/cola.py → kernels/cola_ae/ops.py;
    # flip from the CLI with `launch.train --fused`.
    use_fused_kernel: bool = False


@dataclass(frozen=True)
class LoraConfig:
    rank: int = 128
    alpha: float = 32.0
    # ReLoRA merge-and-restart period (steps); 0 disables restarts.
    relora_every: int = 0


@dataclass(frozen=True)
class SLTrainConfig:
    rank: int = 128
    sparsity: float = 0.03  # fraction of nonzeros in S


@dataclass(frozen=True)
class ModelConfig:
    name: str = "unnamed"
    family: str = "dense"             # FAMILIES
    num_layers: int = 4
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                 # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    max_seq_len: int = 4096
    attention: str = "gqa"            # ATTENTION_KINDS
    rope: str = "rope"                # ROPE_KINDS
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    # --- block layout -------------------------------------------------
    # Pattern of block kinds, tiled to num_layers. E.g. jamba:
    # ("mamba",)*3 + ("attn",) + ("mamba",)*4  (1 attn per 8).
    block_pattern: Tuple[str, ...] = ("attn",)
    # --- substructure ---------------------------------------------------
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    mamba: MambaConfig = field(default_factory=MambaConfig)
    # --- parameterization (the paper's axis) ----------------------------
    parameterization: str = "cola"    # PARAMETERIZATIONS
    cola: ColaConfig = field(default_factory=ColaConfig)
    lora: LoraConfig = field(default_factory=LoraConfig)
    sltrain: SLTrainConfig = field(default_factory=SLTrainConfig)
    # --- enc-dec (whisper) ----------------------------------------------
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq_len: int = 1500
    # --- vlm ----------------------------------------------------------
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- numerics -------------------------------------------------------
    dtype: str = "bfloat16"           # compute dtype
    param_dtype: str = "float32"      # master params
    # --- training-time behaviour ----------------------------------------
    remat: str = "cola_m"             # REMAT_POLICIES
    # ---------------------------------------------------------------------
    notes: str = ""

    # ----- derived ---------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def rank_attn(self) -> int:
        return self.cola.rank_attn or (self.d_model // 4)

    @property
    def rank_mlp(self) -> int:
        return self.cola.rank_mlp or (self.d_model // 4)

    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind per layer, tiling block_pattern to num_layers."""
        pat = self.block_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    def layer_is_moe(self, layer_idx: int) -> bool:
        if not self.moe.enabled:
            return False
        step = max(1, self.moe.interleave_step)
        # MoE on layers (step-1, 2*step-1, ...) — matches llama4/jamba refs.
        return (layer_idx % step) == (step - 1)

    def sub_quadratic(self) -> bool:
        """True when long-context decode is feasible (SSM/hybrid/linear)."""
        kinds = set(self.layer_kinds())
        return bool(kinds & {"mamba", "rwkv6"}) or self.attention == "none"

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # Reduced copy for CPU smoke tests -------------------------------------
    def smoke(self) -> "ModelConfig":
        pat = self.block_pattern
        # keep one full pattern repetition (bounded), tiny dims
        n_layers = min(len(pat), 8) if len(pat) > 1 else 2
        d = 64
        heads = 4
        kv = min(self.num_kv_heads, heads) or heads
        kv = heads if heads % kv else kv
        moe = self.moe
        if moe.enabled:
            moe = dataclasses.replace(
                moe, num_experts=min(4, moe.num_experts),
                dense_d_ff=128 if moe.dense_d_ff else 0,
                shared_expert_d_ff=128 if moe.shared_expert_d_ff else 0)
        return dataclasses.replace(
            self,
            num_layers=n_layers,
            d_model=d,
            num_heads=heads,
            num_kv_heads=min(kv, 2) if self.num_kv_heads < self.num_heads else heads,
            head_dim=16,
            d_ff=128,
            vocab_size=512,
            max_seq_len=128,
            moe=moe,
            mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                          qk_rope_head_dim=8, qk_nope_head_dim=16,
                          v_head_dim=16),
            mamba=MambaConfig(d_state=8, d_conv=4, expand=2, dt_rank=8),
            cola=dataclasses.replace(self.cola, rank_attn=16, rank_mlp=16),
            lora=dataclasses.replace(self.lora, rank=8),
            sltrain=dataclasses.replace(self.sltrain, rank=8),
            num_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=32 if self.is_encoder_decoder else self.encoder_seq_len,
            mrope_sections=(2, 3, 3),  # sums to head_dim//2 = 8
        )


# --------------------------------------------------------------------------
# Input-shape cells (assigned LM shapes)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


LM_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> List[ShapeSpec]:
    """Which of the 4 assigned shape cells apply to this arch (spec rules)."""
    out = []
    for s in LM_SHAPES.values():
        if s.name == "long_500k" and not cfg.sub_quadratic():
            continue  # documented skip: full-attention arch
        out.append(s)
    return out


# --------------------------------------------------------------------------
# Training hyper-params (paper Appendix D)
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 256
    learning_rate: float = 3e-3
    min_lr_ratio: float = 0.1
    warmup_ratio: float = 0.1
    weight_decay: float = 0.01
    grad_clip: float = 0.5
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    optimizer: str = "adamw"          # adamw | lamb
    # baselines / extensions
    galore_rank: int = 0              # 0 disables GaLore projection
    galore_update_every: int = 200
    grad_compression: str = "none"    # none | int8
    # infra
    stop_after: int = 0               # stop early (checkpoint) — emulates
                                      # preemption without changing the
                                      # LR-schedule horizon (tests/ops)
    checkpoint_every: int = 0         # 0 disables
    checkpoint_dir: str = ""
    keep_checkpoints: int = 3
    async_checkpoint: bool = True
    # robustness / recovery policy (train/guard.py)
    nonfinite_guard: bool = True      # in-jit: skip update on NaN/inf
    loss_spike_threshold: float = 0.0  # flag loss > t×EWMA (0 disables)
    spike_warmup_steps: int = 5       # EWMA warmup before spikes flag
    spike_ewma: float = 0.9           # EWMA coefficient for the loss avg
    max_recoveries: int = 3           # rollbacks before hard failure
    recovery_backoff_s: float = 0.0   # sleep attempt×this between retries
    skip_window: int = 0              # extra data offset per recovery
                                      # (0 => just past the bad batch)
    log_every: int = 10
    eval_every: int = 0
    eval_batches: int = 4
    # data
    data: str = "synthetic"           # synthetic | packed:<path>
    # microbatching (grad accumulation)
    microbatch: int = 0               # 0 = no accumulation


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------
_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    # late import so registration side-effects run
    from repro import configs as _configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]()


def list_archs() -> List[str]:
    from repro import configs as _configs  # noqa: F401
    return sorted(_REGISTRY)
