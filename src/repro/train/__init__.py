from repro.train.step import TrainState, build_train_step, make_train_state  # noqa: F401
