"""Training guardrails: loss-spike detection and the automatic recovery
policy.

Low-rank/compressed-activation training (CoLA, CompAct) is numerically
touchier than full-rank baselines, and paper-scale runs are long enough
that divergence *will* happen.  Two detectors feed one recovery policy:

* the **in-jit finite-ness guard** (train/step.py) flags NaN/inf loss or
  grad-norm and refuses the poisoned update — the host reads it as
  ``metrics['nonfinite']``;
* the host-side :class:`LossSpikeDetector` keeps an EWMA of the loss and
  flags steps whose loss exceeds ``threshold ×`` the moving average after
  warmup (the same ledger shape as
  ``distributed.straggler.StepWatchdog`` — flagged steps do not poison
  the EWMA).

On either signal :class:`RecoveryPolicy` rolls the run back to the last
*good* checkpoint (``latest_good_step`` — corrupt ones are skipped),
advances the data pipeline's skip offset past the offending window so the
replay draws fresh batches, sleeps a bounded backoff, and retries.  After
``tc.max_recoveries`` recoveries it raises :class:`TrainingDiverged` —
a hard failure is better than silently looping on a poisoned region.
Every recovery is recorded in the MetricsLogger event ledger/counters so
the run can be audited after the fact.
"""
from __future__ import annotations

import math
import time
from typing import Callable, List, Optional

from repro.train.metrics import MetricsLogger


class TrainingDiverged(RuntimeError):
    """Recovery budget exhausted: the run kept producing non-finite or
    spiking losses after ``max_recoveries`` rollbacks."""


class LossSpikeDetector:
    """EWMA loss-spike detector (StepWatchdog's event-ledger shape).

    ``observe`` returns True when the loss is flagged; flagged steps are
    excluded from the EWMA so one spike does not inflate the baseline and
    mask the next one.  ``threshold <= 0`` disables detection (observe
    still tracks the EWMA for logging)."""

    def __init__(self, threshold: float = 0.0, ewma: float = 0.9,
                 warmup_steps: int = 5):
        self.threshold = threshold
        self.ewma_coef = ewma
        self.warmup = warmup_steps
        self.avg: Optional[float] = None
        self.seen = 0
        self.events: List[dict] = []

    def observe(self, step: int, loss: float) -> bool:
        if not math.isfinite(loss):
            return False  # non-finite is the guard's signal, not a spike
        self.seen += 1
        if self.avg is None:
            self.avg = loss
            return False
        flagged = (self.threshold > 0 and self.seen > self.warmup and
                   loss > self.threshold * self.avg)
        if flagged:
            self.events.append({"step": step, "loss": loss,
                                "avg": self.avg})
            return True
        self.avg = self.ewma_coef * self.avg + \
            (1 - self.ewma_coef) * loss
        return False

    def reset(self) -> None:
        """Forget the EWMA (called after a rollback: the restored state's
        loss scale may differ from the diverged trajectory's)."""
        self.avg = None
        self.seen = 0


class RecoveryPolicy:
    """Rollback-and-retry driver shared by the train loop.

    ``recover(step, state, kind, loss)`` returns ``(state, resume_step)``:
    either the restored checkpoint state and its step, or (when no
    checkpoint exists) the current state and the same step with the data
    window advanced — the in-jit guard already kept the params clean for
    the non-finite case, so skipping the bad batch is sufficient."""

    def __init__(self, tc, mgr, pipe, logger: MetricsLogger,
                 restore_fn: Optional[Callable] = None):
        self.tc = tc
        self.mgr = mgr
        self.pipe = pipe
        self.logger = logger
        self.restore_fn = restore_fn  # (step) -> TrainState
        self.recoveries = 0

    def recover(self, step: int, state, kind: str, loss: float):
        self.recoveries += 1
        counter = ("nonfinite_steps" if kind == "nonfinite"
                   else "loss_spikes")
        self.logger.count(counter)
        self.logger.count("recoveries")
        if self.recoveries > self.tc.max_recoveries:
            self.logger.event("hard_failure", step, cause=kind, loss=loss,
                              recoveries=self.recoveries)
            raise TrainingDiverged(
                f"step {step}: {kind} (loss={loss!r}) after "
                f"{self.recoveries - 1} recoveries — budget "
                f"max_recoveries={self.tc.max_recoveries} exhausted")
        if self.tc.recovery_backoff_s:
            time.sleep(self.tc.recovery_backoff_s * self.recoveries)

        good = self.mgr.latest_good_step() if self.mgr is not None else None
        if good is not None and self.restore_fn is not None:
            # roll back to the last good checkpoint, then skip the data
            # window [good, step] so the replay draws fresh batches
            # (restore first: it resets the pipeline offset to the
            # checkpointed value, which the skip must build on)
            state = self.restore_fn(good)
            window = (step - good + 1) + self.tc.skip_window
            offset = self.pipe.skip_window(window)
            self.logger.event("rollback", step, cause=kind, loss=loss,
                              restored_step=good, data_offset=offset)
            print(f"[recover] {kind} at step {step} "
                  f"(loss={loss:.4g}) — rolled back to step {good}, "
                  f"data offset -> {offset} "
                  f"(attempt {self.recoveries}/{self.tc.max_recoveries})")
            return state, good
        # no restorable checkpoint: the guard kept params clean; skip just
        # the offending batch and continue in place
        offset = self.pipe.skip_window(1 + self.tc.skip_window)
        self.logger.event("skip_batch", step, cause=kind, loss=loss,
                          data_offset=offset)
        print(f"[recover] {kind} at step {step} (loss={loss:.4g}) — no "
              f"checkpoint to roll back to; skipping batch "
              f"(data offset -> {offset}, attempt "
              f"{self.recoveries}/{self.tc.max_recoveries})")
        return state, step
