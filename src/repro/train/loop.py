"""Fault-tolerant training loop.

Features wired together here: sharded jit step (params/opt FSDP+TP via
param_sharding_tree), deterministic resumable data, atomic+async+verified
checkpointing with auto-resume, SIGTERM → checkpoint-and-exit (preemption),
straggler watchdog, ReLoRA merge/restart scheduling, periodic eval.

Guardrails (this layer's contract — chaos-tested in tests/test_chaos.py):

* resume targets ``latest_good_step()`` — a corrupt or partially-written
  checkpoint is skipped, never served;
* the jitted step carries a finite-ness guard (train/step.py): a NaN/inf
  loss or grad-norm never updates params, and the host reads the flag from
  the already-synced metrics at zero extra dispatch cost;
* an EWMA loss-spike detector (train/guard.py) catches finite divergence;
* both signals drive :class:`~repro.train.guard.RecoveryPolicy` — roll
  back to the last good checkpoint, advance the data pipeline's skip
  offset past the offending window, bounded retries with backoff, then a
  hard :class:`~repro.train.guard.TrainingDiverged`;
* every recovery/straggler/checkpoint-failure event lands in the
  MetricsLogger counters + event ledger (audited in the returned metrics);
* checkpoint writes are saved-once per step (a preemption landing on a
  ``checkpoint_every`` boundary no longer double-saves), and background
  writer failures re-raise from ``wait()`` instead of dying on a daemon
  thread.

Chaos hooks: ``hooks['before_step'](step, state) -> state|None`` and
``hooks['after_step'](step, state, metrics)`` let the fault-injection
harness (repro/testing/faults.py) crash/delay/poison deterministically;
production code leaves them unset.
"""
from __future__ import annotations

import signal
import sys
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager, CheckpointWriteError
from repro.config import ModelConfig, TrainConfig
from repro.data.pipeline import make_pipeline
from repro.distributed.sharding import (current_env, named_sharding_tree,
                                        param_sharding_tree, spec_tree)
from repro.distributed.straggler import StepWatchdog
from repro.models.model import build_model
from repro.optim import relora
from repro.train import step as step_mod
from repro.train.guard import LossSpikeDetector, RecoveryPolicy
from repro.train.metrics import MetricsLogger


def train(mc: ModelConfig, tc: TrainConfig, *,
          log_path: Optional[str] = None,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict:
    """Run the loop; returns final metrics.  Works with or without an active
    mesh_env (single-device CPU smoke up to multi-pod)."""
    hooks = hooks or {}
    model = build_model(mc)
    env = current_env()
    train_step = step_mod.build_train_step(model, tc)
    eval_step = step_mod.build_eval_step(model)

    # ---- state init / resume ------------------------------------------------
    mgr = (CheckpointManager(tc.checkpoint_dir, tc.keep_checkpoints,
                             tc.async_checkpoint)
           if tc.checkpoint_dir else None)
    rng = jax.random.PRNGKey(tc.seed)
    pipe = make_pipeline(mc, tc)

    def _restore_tools():
        template = jax.eval_shape(
            lambda: step_mod.make_train_state(model, tc, rng))
        shardings = None
        if env is not None:
            axes = step_mod.train_state_axes(model, tc)
            shardings = param_sharding_tree(axes, template, env)
        return template, shardings

    def restore_fn(step: int):
        """Restore a verified checkpoint + its pipeline state (shared by
        initial resume and mid-run rollback)."""
        template, shardings = _restore_tools()
        state = mgr.restore(step, template, shardings)
        pipe.resume(mgr.restore_extra(step))
        return state

    start_step = 0
    state = None
    if mgr is not None:
        latest = mgr.latest_good_step()
        if latest is not None:
            state = restore_fn(latest)
            start_step = int(mgr.restore_extra(latest)["step"])
            print(f"[resume] restored checkpoint step={start_step}")
    if state is None:
        state = step_mod.make_train_state(model, tc, rng)
        if env is not None:
            axes = step_mod.train_state_axes(model, tc)
            shardings = param_sharding_tree(axes, state, env)
            state = jax.tree.map(jax.device_put, state, shardings)

    # ---- jit the step ---------------------------------------------------------
    if env is not None:
        axes = step_mod.train_state_axes(model, tc)
        state_sh = param_sharding_tree(axes, state, env)
        step_fn = jax.jit(train_step, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None), donate_argnums=0)
    else:
        step_fn = jax.jit(train_step, donate_argnums=0)
    eval_fn = jax.jit(eval_step)

    # ---- guardrails -----------------------------------------------------------
    logger = MetricsLogger(log_path)
    watchdog = StepWatchdog(
        on_straggler=hooks.get("on_straggler"))
    detector = LossSpikeDetector(threshold=tc.loss_spike_threshold,
                                 ewma=tc.spike_ewma,
                                 warmup_steps=tc.spike_warmup_steps)
    recovery = RecoveryPolicy(tc, mgr, pipe, logger,
                              restore_fn=restore_fn if mgr else None)

    # ---- preemption: checkpoint on SIGTERM ----------------------------------------
    preempted = {"flag": False}

    def _sigterm(signum, frame):
        preempted["flag"] = True
    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    last_saved = start_step if start_step else None

    def save_ckpt(step: int) -> None:
        """Save exactly once per step (checkpoint_every firing on the same
        step as a preemption/stop_after exit must not double-save)."""
        nonlocal last_saved
        if mgr is None or last_saved == step:
            return
        try:
            mgr.save(step, state, extra=pipe.state(step))
            last_saved = step
        except CheckpointWriteError:
            logger.count("checkpoint_failures")
            logger.event("checkpoint_failure", step)
            raise

    metrics = {}
    tokens_per_step = tc.global_batch * tc.seq_len
    try:
        s = start_step
        while s < tc.steps:
            if "before_step" in hooks:  # chaos: poison/crash/delay
                maybe = hooks["before_step"](s, state)
                if maybe is not None:
                    state = maybe
            batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
            watchdog.start()
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])  # syncs (block_until_ready)
            n_straggles = len(watchdog.events)
            watchdog.stop(s)
            if len(watchdog.events) > n_straggles:
                logger.count("straggler_events")
            if "after_step" in hooks:
                hooks["after_step"](s, state, metrics)

            # ---- guardrails: nonfinite / loss spike -> recovery --------
            nonfinite = bool(metrics.get("nonfinite", 0.0)) or \
                not np.isfinite(loss)
            spiked = detector.observe(s, loss)
            if nonfinite or spiked:
                kind = "nonfinite" if nonfinite else "loss_spike"
                state, s = recovery.recover(s, state, kind, loss)
                detector.reset()
                continue  # retry from the restored step

            if (mc.parameterization == "lora" and mc.lora.relora_every and
                    (s + 1) % mc.lora.relora_every == 0):
                new_params, new_opt = relora.merge_restart(
                    mc, state.params, state.opt,
                    jax.random.fold_in(rng, s))
                state = state._replace(params=new_params, opt=new_opt)

            if tc.log_every and (s % tc.log_every == 0 or s == tc.steps - 1):
                logger.log(s, metrics, tokens=tokens_per_step)
            if tc.eval_every and (s + 1) % tc.eval_every == 0:
                evals = []
                for i in range(tc.eval_batches):
                    eb = {k: jnp.asarray(v) for k, v in
                          pipe.get_batch(10**6 + i).items()}
                    evals.append(eval_fn(state.params, eb))
                eval_loss = float(np.mean([float(e["ce_loss"])
                                           for e in evals]))
                print(f"[eval step {s}] loss={eval_loss:.4f} "
                      f"ppl={np.exp(min(eval_loss, 50)):.2f}")
            if tc.checkpoint_every and (s + 1) % tc.checkpoint_every == 0:
                save_ckpt(s + 1)
            if preempted["flag"] or (tc.stop_after and s + 1 >= tc.stop_after):
                if preempted["flag"]:
                    print("[preempt] SIGTERM received — checkpointing and "
                          "exiting cleanly")
                if mgr is not None:
                    save_ckpt(s + 1)
                    mgr.wait()
                break
            s += 1
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if mgr is not None:
            try:
                mgr.wait()
            except CheckpointWriteError as e:
                # teardown: record, don't shadow an in-flight exception
                logger.count("checkpoint_failures")
                print(f"[checkpoint] background write failed: {e}",
                      file=sys.stderr)
        logger.close()
    out = {k: float(v) for k, v in metrics.items()
           if jnp.ndim(v) == 0}
    out["straggler_events"] = len(watchdog.events)
    out["recovery_events"] = len(logger.events)
    out["recoveries"] = recovery.recoveries
    out["counters"] = dict(logger.counters)
    out["events"] = list(logger.events) + \
        [{"kind": "straggler", **e} for e in watchdog.events]
    out["final_step"] = int(state.step)
    out["state"] = state
    return out
