"""Fault-tolerant training loop.

Features wired together here: sharded jit step (params/opt FSDP+TP via
param_sharding_tree), deterministic resumable data, atomic+async
checkpointing with auto-resume, SIGTERM → checkpoint-and-exit (preemption),
straggler watchdog, ReLoRA merge/restart scheduling, periodic eval.
"""
from __future__ import annotations

import signal
import sys
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.config import ModelConfig, TrainConfig
from repro.data.pipeline import make_pipeline
from repro.distributed.sharding import (current_env, named_sharding_tree,
                                        param_sharding_tree, spec_tree)
from repro.distributed.straggler import StepWatchdog
from repro.models.model import build_model
from repro.optim import relora
from repro.train import step as step_mod
from repro.train.metrics import MetricsLogger


def train(mc: ModelConfig, tc: TrainConfig, *,
          log_path: Optional[str] = None,
          hooks: Optional[Dict[str, Callable]] = None) -> Dict:
    """Run the loop; returns final metrics.  Works with or without an active
    mesh_env (single-device CPU smoke up to multi-pod)."""
    hooks = hooks or {}
    model = build_model(mc)
    env = current_env()
    train_step = step_mod.build_train_step(model, tc)
    eval_step = step_mod.build_eval_step(model)

    # ---- state init / resume ------------------------------------------------
    mgr = (CheckpointManager(tc.checkpoint_dir, tc.keep_checkpoints,
                             tc.async_checkpoint)
           if tc.checkpoint_dir else None)
    rng = jax.random.PRNGKey(tc.seed)
    start_step = 0
    state = None
    if mgr is not None:
        latest = mgr.latest_step()
        if latest is not None:
            template = jax.eval_shape(
                lambda: step_mod.make_train_state(model, tc, rng))
            shardings = None
            if env is not None:
                axes = step_mod.train_state_axes(model, tc)
                shardings = param_sharding_tree(axes, template, env)
            state = mgr.restore(latest, template, shardings)
            start_step = int(mgr.restore_extra(latest)["step"])
            print(f"[resume] restored checkpoint step={start_step}")
    if state is None:
        state = step_mod.make_train_state(model, tc, rng)
        if env is not None:
            axes = step_mod.train_state_axes(model, tc)
            shardings = param_sharding_tree(axes, state, env)
            state = jax.tree.map(jax.device_put, state, shardings)

    # ---- jit the step ---------------------------------------------------------
    if env is not None:
        axes = step_mod.train_state_axes(model, tc)
        state_sh = param_sharding_tree(axes, state, env)
        step_fn = jax.jit(train_step, in_shardings=(state_sh, None),
                          out_shardings=(state_sh, None), donate_argnums=0)
    else:
        step_fn = jax.jit(train_step, donate_argnums=0)
    eval_fn = jax.jit(eval_step)

    # ---- data -------------------------------------------------------------------
    pipe = make_pipeline(mc, tc)
    logger = MetricsLogger(log_path)
    watchdog = StepWatchdog(on_straggler=hooks.get("on_straggler"))

    # ---- preemption: checkpoint on SIGTERM ----------------------------------------
    preempted = {"flag": False}

    def _sigterm(signum, frame):
        preempted["flag"] = True
    old_handler = signal.signal(signal.SIGTERM, _sigterm)

    metrics = {}
    tokens_per_step = tc.global_batch * tc.seq_len
    try:
        for s in range(start_step, tc.steps):
            batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(s).items()}
            watchdog.start()
            state, metrics = step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            watchdog.stop(s)

            if (mc.parameterization == "lora" and mc.lora.relora_every and
                    (s + 1) % mc.lora.relora_every == 0):
                new_params, new_opt = relora.merge_restart(
                    mc, state.params, state.opt,
                    jax.random.fold_in(rng, s))
                state = state._replace(params=new_params, opt=new_opt)

            if tc.log_every and (s % tc.log_every == 0 or s == tc.steps - 1):
                logger.log(s, metrics, tokens=tokens_per_step)
            if tc.eval_every and (s + 1) % tc.eval_every == 0:
                evals = []
                for i in range(tc.eval_batches):
                    eb = {k: jnp.asarray(v) for k, v in
                          pipe.get_batch(10**6 + i).items()}
                    evals.append(eval_fn(state.params, eb))
                eval_loss = float(np.mean([float(e["ce_loss"])
                                           for e in evals]))
                print(f"[eval step {s}] loss={eval_loss:.4f} "
                      f"ppl={np.exp(min(eval_loss, 50)):.2f}")
            if mgr is not None and tc.checkpoint_every and \
                    (s + 1) % tc.checkpoint_every == 0:
                mgr.save(s + 1, state, extra=pipe.state(s + 1))
            if preempted["flag"] or (tc.stop_after and s + 1 >= tc.stop_after):
                if preempted["flag"]:
                    print("[preempt] SIGTERM received — checkpointing and "
                          "exiting cleanly")
                if mgr is not None:
                    mgr.save(s + 1, state, extra=pipe.state(s + 1))
                    mgr.wait()
                break
    finally:
        signal.signal(signal.SIGTERM, old_handler)
        if mgr is not None:
            mgr.wait()
        logger.close()
    out = {k: float(v) for k, v in metrics.items()
           if jnp.ndim(v) == 0}
    out["straggler_events"] = len(watchdog.events)
    out["final_step"] = int(state.step)
    out["state"] = state
    return out
