"""Train/eval step builders.

The step is a pure jit-able function of (TrainState, batch):
bf16 forward/backward over f32 master params, CoLA-M (or other) remat via
the model config, global-norm clip, cosine LR, AdamW/LAMB/GaLore update,
optional int8 gradient compression with error feedback, optional
microbatched gradient accumulation.

Finite-ness guard (``tc.nonfinite_guard``): the step checks loss and
global grad-norm for NaN/inf *inside* the jit and, when either is
non-finite, keeps the previous params/opt/err instead of applying the
poisoned update — so by the time the host reads ``metrics['nonfinite']``
(one scalar, already synced by the loop's block_until_ready) the state is
still clean and the recovery policy (train/guard.py) can roll back and
skip the offending data window without losing the run.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, TrainConfig
from repro.models.linear import trainable_mask
from repro.models.model import Model
from repro.optim import adamw, clip, compression, galore, schedule


class TrainState(NamedTuple):
    params: Any
    opt: Any                  # AdamState | GaloreState
    step: jax.Array
    err: Any                  # error-feedback tree ({} when unused)


def make_train_state(model: Model, tc: TrainConfig, rng: jax.Array
                     ) -> TrainState:
    params = model.init(rng)
    opt = (galore.galore_init(params, tc.galore_rank) if tc.galore_rank
           else adamw.adamw_init(params))
    err = (compression.init_error(params)
           if tc.grad_compression == "int8" else {})
    return TrainState(params, opt, jnp.zeros((), jnp.int32), err)


def abstract_train_state(model: Model, tc: TrainConfig) -> TrainState:
    """ShapeDtypeStruct TrainState (dry-run: no allocation)."""
    params = model.abstract()
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    if tc.galore_rank:
        opt = jax.eval_shape(
            lambda: galore.galore_init(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), params),
                tc.galore_rank))
    else:
        opt = adamw.AdamState(m=jax.tree.map(f32, params),
                              v=jax.tree.map(f32, params),
                              count=jax.ShapeDtypeStruct((), jnp.int32))
    err = (jax.tree.map(f32, params) if tc.grad_compression == "int8" else {})
    return TrainState(params, opt,
                      jax.ShapeDtypeStruct((), jnp.int32), err)


def train_state_axes(model: Model, tc: TrainConfig) -> TrainState:
    """Logical-axes tree matching TrainState (for param_sharding_tree)."""
    axes = model.axes()
    scalar = ("null",) * 0  # 0-dim
    if tc.galore_rank:
        # galore state leaves have data-dependent shapes; replicate them
        # (GaLore is a small-scale baseline, not a dry-run configuration)
        params_template = model.abstract()
        opt_shapes = jax.eval_shape(
            lambda: galore.galore_init(
                jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                             params_template), tc.galore_rank))
        opt_axes = jax.tree.map(lambda s: ("null",) * len(s.shape),
                                opt_shapes)
    else:
        opt_axes = adamw.AdamState(m=axes, v=axes, count=())
    err_axes = axes if tc.grad_compression == "int8" else {}
    return TrainState(params=axes, opt=opt_axes, step=(), err=err_axes)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token CE in f32 (gather form — safe with -inf padded vocab)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def chunked_unembed_ce(x: jax.Array, w: jax.Array, labels: jax.Array,
                       vocab_size: int, n_chunks: int = 8) -> jax.Array:
    """Fused unembed + cross-entropy, chunked over tokens.

    The (tokens, vocab) logits tensor never materializes: each chunk
    computes its logits, its CE partial sum, and (via jax.checkpoint)
    recomputes them in backward — the Liger-kernel trick in XLA.  w grads
    accumulate across chunks through the scan cotangent.
    """
    from repro.distributed.sharding import shard
    b, s, d = x.shape
    T = b * s
    while T % n_chunks:
        n_chunks //= 2
    xt = x.reshape(n_chunks, T // n_chunks, d)
    lt = labels.reshape(n_chunks, T // n_chunks)
    pad_mask = (jnp.arange(w.shape[-1]) >= vocab_size) if \
        w.shape[-1] != vocab_size else None

    @jax.checkpoint
    def body(carry, inp):
        xc, lc = inp
        logits = jnp.einsum("td,dv->tv", xc, w.astype(xc.dtype))
        logits = shard(logits, "batch", "vocab")
        logits = logits.astype(jnp.float32)
        if pad_mask is not None:
            logits = jnp.where(pad_mask[None, :], -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, lc[:, None].astype(jnp.int32), axis=-1)[:, 0]
        return carry + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xt, lt))
    return total / T


def build_loss_fn(model: Model):
    def loss_fn(params, batch):
        x, aux = model.hidden(params, batch, training=True)
        x = model.final_norm(params, x)
        loss = chunked_unembed_ce(x, model.unembed_matrix(params),
                                  batch["labels"],
                                  model.cfg.vocab_size)
        total = loss
        for k in ("moe_aux", "moe_zloss"):
            if k in aux:
                total = total + aux[k]
        metrics = {"ce_loss": loss, **aux}
        return total, metrics
    return loss_fn


def build_train_step(model: Model, tc: TrainConfig):
    loss_fn = build_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    mask = None  # computed lazily (needs a params tree)

    def compute_grads(params, batch):
        if tc.microbatch and tc.microbatch > 1:
            n = tc.microbatch
            def slice_mb(i, t):
                mb = t.shape[0] // n
                return jax.lax.dynamic_slice_in_dim(t, i * mb, mb, axis=0)
            def body(carry, i):
                acc, loss_acc = carry
                mb = {k: slice_mb(i, v) if v.ndim >= 1 and
                      v.shape[0] == batch["labels"].shape[0] else v
                      for k, v in batch.items()}
                if "position_ids" in batch:  # (3, B, S) layout
                    mb["position_ids"] = jax.lax.dynamic_slice_in_dim(
                        batch["position_ids"],
                        i * (batch["position_ids"].shape[1] // n),
                        batch["position_ids"].shape[1] // n, axis=1)
                (l, mets), g = grad_fn(params, mb)
                acc = jax.tree.map(lambda a, b: a + b, acc, g)
                return (acc, loss_acc + l), mets
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), mets = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(n))
            grads = jax.tree.map(lambda g: g / n, gsum)
            metrics = jax.tree.map(lambda m: m[-1], mets)
            metrics["ce_loss"] = lsum / n
            return (lsum / n, metrics), grads
        return grad_fn(params, batch)

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        (loss, metrics), grads = compute_grads(state.params, batch)
        grads, gnorm = clip.clip_by_global_norm(grads, tc.grad_clip)
        err = state.err
        if tc.grad_compression == "int8":
            grads, err = compression.compress_with_feedback(grads, err)
        lr = schedule.cosine_schedule(
            state.step, base_lr=tc.learning_rate, total_steps=tc.steps,
            warmup_ratio=tc.warmup_ratio, min_ratio=tc.min_lr_ratio)
        if tc.galore_rank:
            new_params, new_opt = galore.galore_update(
                tc, state.params, grads, state.opt, lr)
        elif tc.optimizer == "lamb":
            m = trainable_mask(model.cfg, state.params)
            new_params, new_opt = adamw.lamb_update(
                tc, state.params, grads, state.opt, lr, m)
        else:
            m = trainable_mask(model.cfg, state.params)
            new_params, new_opt = adamw.adamw_update(
                tc, state.params, grads, state.opt, lr, m)
        metrics = dict(metrics)
        metrics.update(loss=loss, grad_norm=gnorm, lr=lr)
        if tc.nonfinite_guard:
            ok = jnp.isfinite(loss) & jnp.isfinite(gnorm)
            keep = lambda n, o: jnp.where(ok, n, o)
            new_params = jax.tree.map(keep, new_params, state.params)
            new_opt = jax.tree.map(keep, new_opt, state.opt)
            err = jax.tree.map(keep, err, state.err)
            metrics["nonfinite"] = (~ok).astype(jnp.float32)
        return TrainState(new_params, new_opt, state.step + 1, err), metrics

    return train_step


def build_eval_step(model: Model):
    loss_fn = build_loss_fn(model)

    def eval_step(params, batch) -> Dict:
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
