"""Lightweight metrics logging (CSV + stdout) + the robustness event
ledger: recovery/guardrail events are counted (``count``) and recorded
(``event``) here so a run can be audited after the fact — every CSV row
carries the cumulative counters, and the structured ledger survives in
``events``."""
from __future__ import annotations

import csv
import math
import os
import time
from typing import Dict, List, Optional

# counters seeded at init so the CSV header includes them from row one
# (DictWriter fixes fieldnames at the first write)
COUNTER_KEYS = ("recoveries", "nonfinite_steps", "loss_spikes",
                "straggler_events", "checkpoint_failures")


class MetricsLogger:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._writer = None
        self._file = None
        self._t0 = time.time()
        self.counters: Dict[str, int] = {k: 0 for k in COUNTER_KEYS}
        self.events: List[dict] = []

    def count(self, name: str, n: int = 1) -> None:
        self.counters[name] = self.counters.get(name, 0) + n

    def event(self, kind: str, step: int, **detail) -> None:
        """Append to the structured event ledger (same shape as
        StepWatchdog.events) and bump the matching counter."""
        self.events.append({"kind": kind, "step": step, **detail})

    def log(self, step: int, metrics: Dict[str, float], tokens: int = 0):
        row = {"step": step, "time": time.time() - self._t0}
        row.update(self.counters)
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                pass
        if tokens:
            row["tokens_per_s"] = tokens / max(row["time"], 1e-9)
        if "ce_loss" in row and row["ce_loss"] < 50:
            row["ppl"] = math.exp(row["ce_loss"])
        if self.path:
            new = self._writer is None
            if new:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._file = open(self.path, "a", newline="")
                self._writer = csv.DictWriter(
                    self._file, fieldnames=sorted(row))
                if self._file.tell() == 0:
                    self._writer.writeheader()
            self._writer.writerow({k: row.get(k) for k in
                                   self._writer.fieldnames})
            self._file.flush()
        msg = " ".join(f"{k}={row[k]:.4g}" for k in sorted(row)
                       if isinstance(row[k], float))
        print(f"[step {step}] {msg}", flush=True)

    def close(self):
        if self._file:
            self._file.close()
