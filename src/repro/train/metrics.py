"""Lightweight metrics logging (CSV + stdout)."""
from __future__ import annotations

import csv
import math
import os
import time
from typing import Dict, Optional


class MetricsLogger:
    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._writer = None
        self._file = None
        self._t0 = time.time()

    def log(self, step: int, metrics: Dict[str, float], tokens: int = 0):
        row = {"step": step, "time": time.time() - self._t0}
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                pass
        if tokens:
            row["tokens_per_s"] = tokens / max(row["time"], 1e-9)
        if "ce_loss" in row and row["ce_loss"] < 50:
            row["ppl"] = math.exp(row["ce_loss"])
        if self.path:
            new = self._writer is None
            if new:
                os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
                self._file = open(self.path, "a", newline="")
                self._writer = csv.DictWriter(
                    self._file, fieldnames=sorted(row))
                if self._file.tell() == 0:
                    self._writer.writeheader()
            self._writer.writerow({k: row.get(k) for k in
                                   self._writer.fieldnames})
            self._file.flush()
        msg = " ".join(f"{k}={row[k]:.4g}" for k in sorted(row)
                       if isinstance(row[k], float))
        print(f"[step {step}] {msg}", flush=True)

    def close(self):
        if self._file:
            self._file.close()
