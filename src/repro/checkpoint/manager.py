"""Fault-tolerant checkpointing.

* **Atomic**: write to ``step_<N>.tmp/`` then ``os.rename`` — a crash mid-
  write can never corrupt the latest checkpoint.
* **Async**: the device→host copy happens on the caller thread (cheap),
  serialization runs on a background thread so the train loop is not
  blocked (paper-scale runs checkpoint ~GBs).
* **Retention**: keep the newest K checkpoints.
* **Elastic**: checkpoints are host numpy keyed by pytree path — restore
  accepts any target shardings, so a 512-chip run resumes on 256 chips
  (distributed/elastic.py + tests/test_checkpoint.py exercise this).
* **Resume**: ``latest_step()`` scans the directory; the data pipeline state
  (one integer) rides along in ``extra.json``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}


def _unflatten(template, blobs: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tv in flat:
        key = jax.tree_util.keystr(p)
        if key not in blobs:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = blobs[key]
        want = tuple(tv.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---- write -------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None) -> None:
        host = _flatten(jax.device_get(state))  # sync copy off device
        if self.async_save:
            self.wait()  # one in-flight save at a time
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "state.npz"),
                 **{k: v for k, v in host.items()})
        with open(os.path.join(tmp, "extra.json"), "w") as f:
            json.dump({"step": step, **extra}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self) -> None:
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # ---- read ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Load into `template`'s structure; optionally device_put with
        `shardings` (any mesh — elastic restart)."""
        path = os.path.join(self.dir, f"step_{step}")
        blobs = dict(np.load(os.path.join(path, "state.npz")))
        state = _unflatten(template, blobs)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_extra(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step}", "extra.json")
        with open(path) as f:
            return json.load(f)
