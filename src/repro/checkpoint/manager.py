"""Fault-tolerant checkpointing.

* **Atomic**: write to ``step_<N>.tmp/`` then ``os.rename`` — a crash mid-
  write can never corrupt the latest checkpoint.  Every file is flushed and
  fsync'd before the rename, and the parent directory is fsync'd after it,
  so a host power-cut cannot leave a renamed-but-empty checkpoint either.
* **Verified**: each checkpoint carries a ``manifest.json`` with a per-leaf
  CRC32 (over the raw array bytes) plus a whole-file CRC/size for
  ``state.npz``.  ``restore`` verifies integrity by default and raises
  :class:`CheckpointCorruptError` on any mismatch; ``latest_good_step``
  walks checkpoints newest-first and returns the newest one that passes
  verification — a corrupt or partially-written checkpoint is skipped, not
  served.
* **Async**: the device→host copy happens on the caller thread (cheap),
  serialization runs on a background thread so the train loop is not
  blocked (paper-scale runs checkpoint ~GBs).  A failure on the writer
  thread is captured and re-raised from the next ``wait()``/``save()`` —
  never silently dropped on a daemon thread.
* **Retention**: keep the newest K checkpoints; stray ``*.tmp`` dirs from
  crashed writers are garbage-collected on the next save.
* **Elastic**: checkpoints are host numpy keyed by pytree path — restore
  accepts any target shardings, so a 512-chip run resumes on 256 chips
  (distributed/elastic.py + tests/test_checkpoint.py exercise this).
* **Resume**: ``latest_step()``/``latest_good_step()`` scan the directory;
  the data pipeline state (step + skip offset) rides along in
  ``extra.json``.
* **Chaos hooks**: ``fault_hook(stage, step)`` is called at the write
  stages ``"post_state"`` (state.npz written, manifest not yet) and
  ``"pre_rename"`` (everything written, rename pending) so the
  fault-injection harness (repro/testing/faults.py) can simulate a death
  mid-write deterministically; production leaves it ``None``.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

MANIFEST = "manifest.json"
STATE = "state.npz"
EXTRA = "extra.json"


class CheckpointCorruptError(RuntimeError):
    """A checkpoint failed integrity verification (missing files, bad CRC,
    truncated archive)."""


class CheckpointWriteError(RuntimeError):
    """A background checkpoint write failed; re-raised from wait()."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(p): np.asarray(v) for p, v in flat}


def _unflatten(template, blobs: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for p, tv in flat:
        key = jax.tree_util.keystr(p)
        if key not in blobs:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = blobs[key]
        want = tuple(tv.shape)
        if tuple(arr.shape) != want:
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def _leaf_crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _file_crc(path: str, chunk: int = 1 << 20) -> int:
    crc = 0
    with open(path, "rb") as f:
        while True:
            b = f.read(chunk)
            if not b:
                break
            crc = zlib.crc32(b, crc)
    return crc & 0xFFFFFFFF


def _fsync_file(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _fsync_dir(path: str) -> None:
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:  # platforms without dir fds
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        # chaos-testing hook: called at write stages; may raise to simulate
        # a crash mid-write (repro/testing/faults.py)
        self.fault_hook: Optional[Callable[[str, int], None]] = None
        os.makedirs(directory, exist_ok=True)

    # ---- write -------------------------------------------------------------
    def save(self, step: int, state, extra: Optional[Dict] = None) -> None:
        host = _flatten(jax.device_get(state))  # sync copy off device
        if self.async_save:
            self.wait()  # one in-flight save at a time; re-raises failures
            self._thread = threading.Thread(
                target=self._write_guarded, args=(step, host, extra or {}),
                daemon=True)
            self._thread.start()
        else:
            self._write(step, host, extra or {})

    def _write_guarded(self, step: int, host: Dict[str, np.ndarray],
                       extra: Dict) -> None:
        try:
            self._write(step, host, extra)
        except BaseException as e:  # captured; re-raised from wait()
            self._error = e

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict) -> None:
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        state_path = os.path.join(tmp, STATE)
        np.savez(state_path, **{k: v for k, v in host.items()})
        if self.fault_hook is not None:
            self.fault_hook("post_state", step)
        manifest = {
            "step": step,
            "leaves": {k: {"crc32": _leaf_crc(v),
                           "shape": list(v.shape),
                           "dtype": str(v.dtype)}
                       for k, v in host.items()},
            "files": {STATE: {"crc32": _file_crc(state_path),
                              "size": os.path.getsize(state_path)}},
        }
        with open(os.path.join(tmp, EXTRA), "w") as f:
            json.dump({"step": step, **extra}, f)
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
        for name in (STATE, EXTRA, MANIFEST):
            _fsync_file(os.path.join(tmp, name))
        _fsync_dir(tmp)
        if self.fault_hook is not None:
            self.fault_hook("pre_rename", step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_dir(self.dir)
        self._gc()

    def wait(self) -> None:
        """Join any in-flight background save and re-raise its failure —
        a lost checkpoint must surface on the train loop, not die with a
        daemon thread."""
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise CheckpointWriteError(
                f"background checkpoint write failed: {err!r}") from err

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)
        # stray tmp dirs are crashed writers' leftovers (save() serializes
        # writes, and _gc runs after the active write's rename)
        for name in os.listdir(self.dir):
            if re.fullmatch(r"step_\d+\.tmp", name):
                shutil.rmtree(os.path.join(self.dir, name),
                              ignore_errors=True)

    # ---- read ---------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def latest_good_step(self) -> Optional[int]:
        """Newest checkpoint that passes integrity verification — corrupt
        or partially-written checkpoints are skipped, so a bad write (or a
        bit-flipped disk) falls back to the previous good step instead of
        wedging resume."""
        for s in reversed(self.all_steps()):
            if self.verify(s):
                return s
        return None

    def verify(self, step: int) -> bool:
        try:
            self.verify_or_raise(step)
            return True
        except CheckpointCorruptError:
            return False

    def verify_or_raise(self, step: int) -> None:
        """Full integrity check: manifest present, state.npz file CRC/size
        match, every manifest leaf present with matching per-leaf CRC."""
        path = os.path.join(self.dir, f"step_{step}")
        state_path = os.path.join(path, STATE)
        man_path = os.path.join(path, MANIFEST)
        for p in (state_path, man_path, os.path.join(path, EXTRA)):
            if not os.path.exists(p):
                raise CheckpointCorruptError(f"step {step}: missing {p}")
        try:
            with open(man_path) as f:
                manifest = json.load(f)
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointCorruptError(
                f"step {step}: unreadable manifest: {e}") from e
        finfo = manifest.get("files", {}).get(STATE, {})
        if os.path.getsize(state_path) != finfo.get("size"):
            raise CheckpointCorruptError(
                f"step {step}: {STATE} size {os.path.getsize(state_path)} "
                f"!= manifest {finfo.get('size')} (truncated write?)")
        if _file_crc(state_path) != finfo.get("crc32"):
            raise CheckpointCorruptError(
                f"step {step}: {STATE} file CRC mismatch (corrupt bytes)")
        try:
            blobs = dict(np.load(state_path))
        except Exception as e:
            raise CheckpointCorruptError(
                f"step {step}: unreadable {STATE}: {e}") from e
        leaves = manifest.get("leaves", {})
        if set(blobs) != set(leaves):
            raise CheckpointCorruptError(
                f"step {step}: leaf set mismatch vs manifest")
        for k, info in leaves.items():
            if _leaf_crc(blobs[k]) != info["crc32"]:
                raise CheckpointCorruptError(
                    f"step {step}: leaf {k} CRC mismatch")

    def restore(self, step: int, template, shardings=None, *,
                verify: bool = True):
        """Load into `template`'s structure; optionally device_put with
        `shardings` (any mesh — elastic restart).  Verifies manifest
        integrity first unless ``verify=False`` (raises
        :class:`CheckpointCorruptError` on mismatch)."""
        if verify:
            self.verify_or_raise(step)
        path = os.path.join(self.dir, f"step_{step}")
        blobs = dict(np.load(os.path.join(path, STATE)))
        state = _unflatten(template, blobs)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state

    def restore_extra(self, step: int) -> Dict:
        path = os.path.join(self.dir, f"step_{step}", EXTRA)
        with open(path) as f:
            return json.load(f)
