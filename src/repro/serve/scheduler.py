"""Slot-based continuous-batching scheduler for the serve engine.

The engine (serve/engine.py) owns the device state — persistent slot
caches, the jitted admission prefill and the jitted k-token decode chunk.
This module owns the *policy*: request/response dataclasses, slot
admission, EOS/length detection, slot recycling, and the serving
guardrails (deadlines, admission-queue bounds, NaN-slot quarantine).

Execution model
---------------
``max_batch`` slots share one (B, max_seq) cache set.  Each scheduler
round:

1. **Admit** — free slots pull requests off the queue.  The newly admitted
   prompts are **left-padded** to a shared bucket length and prefilled in
   one batched dispatch; rows that are not being admitted carry an all-pad
   dummy whose cache writes land in the sacrificial last slot and whose
   cache rows are masked back to their previous contents on merge
   (engine._admit).  Left padding puts every prompt's last real token in
   the final column, so one ``logits[:, -1]`` read samples every first
   token.  Pad columns carry **negative positions**: rope/visibility use
   the true per-sequence position (cache slot == sequence index, identical
   to an unpadded run), the attention mask hides everything the row has
   not written, and pad K/V parks in the reserved ``max_seq - 1`` slot —
   which is why a request must fit ``prompt + max_new ≤ max_seq - 1``.

2. **Decode** — one jitted ``lax.scan`` dispatch advances every slot by
   up to ``decode_block`` tokens.  The scheduler passes each live slot's
   remaining budget and the engine scans only ``min(decode_block,
   min(remaining over live slots))`` steps: the *smallest* live budget
   bounds the chunk, so a nearly-done slot never rides through (and a
   fresh long request never inflates) a chunk whose tail it would drop
   anyway.  The host then scans the (B, k) chunk for per-request EOS /
   length exhaustion, finalizes responses and recycles slots for the next
   admit round.

   With a ``draft_plan`` set the decode dispatch is a **speculative
   round** instead (``engine.spec_chunk``): the low-rank self-draft
   proposes ``spec_window - 1`` tokens, the full model verifies the whole
   window in one dispatch, and only the accepted prefix + bonus token
   (``toks[i, :n_valid[i]]``) is consumed — every consumed token is the
   full model's greedy argmax, so the output stream is bit-identical to
   plain decode.  Speculative mode is greedy-only (``run`` rejects
   ``temperature > 0`` requests up front).

Paged engines add a third policy axis: page-pool admission.  Each
request's full token span (``prompt + max_new``) is claimed at admit and
released the moment its slot finishes or is quarantined
(``engine.release_slot``).  When the free list cannot cover the next
queued request, admission *waits* — live slots keep decoding, and their
releases unblock the queue.  This cannot deadlock: a submit-time guard
rejects any request whose span exceeds the whole pool, so an all-free
engine (⇒ an all-free pool) can always admit the queue head.

Guardrails (chaos-tested in tests/test_chaos.py)
------------------------------------------------
* **Bounded admission queue** — with ``engine.max_queue`` set, requests
  beyond ``free slots + max_queue`` at submit are finished immediately
  with ``finish_reason='rejected'`` (a typed response, never an
  exception) so a traffic spike degrades instead of OOMing the host.
* **Per-request deadlines** — ``Request.deadline_s`` is a wall-clock
  budget from submission; a request that expires while queued or
  mid-generation is finalized with whatever tokens it has and
  ``finish_reason='timeout'``.
* **NaN quarantine** — the engine flags any slot whose logits went
  non-finite during a chunk.  That slot's chunk tokens are discarded, the
  slot is quarantined (freed; its cache row is rewritten by the next
  admission prefill) and the request is re-queued from scratch at the
  front of the queue, bounded by ``engine.max_slot_retries`` before
  ``finish_reason='error'``.  The surviving slots consume their chunk
  normally — slots are independent batch rows, so their greedy streams
  stay bit-identical to an undisturbed run.

``finish_reason`` is the guardrail contract: ``'eos' | 'length' |
'timeout' | 'rejected' | 'error'`` — failures surface as typed responses,
and every event is counted in ``engine.stats()``.

Ragged prompts require per-position attention masking, which only the
attention caches implement; recurrent archs (mamba/rwkv6) would absorb the
pad tokens into their state, so the scheduler rejects ragged admission for
them (equal-length prompts still work — pad is zero).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

FINISH_REASONS = ("eos", "length", "timeout", "rejected", "error")


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is a 1-D int32 token array.
    ``deadline_s`` is an optional wall-clock budget measured from
    submission (None = no deadline)."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens < 1")


@dataclasses.dataclass
class Response:
    """Completed generation.  ``tokens`` includes the EOS token when the
    request finished on one; ``finish_reason`` is one of
    :data:`FINISH_REASONS` (rejected/timed-out requests return partial or
    empty token arrays, never raise)."""
    uid: int
    prompt_len: int
    tokens: np.ndarray
    finish_reason: str          # FINISH_REASONS
    latency_s: float            # submit -> finish


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: List[int]
    t_admit: float


def _bucket(n: int, quantum: int) -> int:
    """Round a prompt length up to the bucket quantum (bounds the number
    of prefill recompiles to O(max_seq / quantum))."""
    return ((n + quantum - 1) // quantum) * quantum


class SlotScheduler:
    """Continuous batching over a ServeEngine's slots."""

    def __init__(self, engine):
        self.engine = engine

    # -----------------------------------------------------------------
    def run(self, requests: List[Request], *,
            rng: Optional[np.ndarray] = None) -> List[Response]:
        """Drive all requests to completion; returns responses in uid
        order.  ``rng`` is a jax PRNGKey enabling temperature sampling
        (greedy rows are unaffected — see engine._sample_batch)."""
        eng = self.engine
        B, max_seq = eng.max_batch, eng.max_seq
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > max_seq - 1:
                raise ValueError(
                    f"request {r.uid}: prompt({len(r.prompt)}) + "
                    f"max_new({r.max_new_tokens}) must fit max_seq-1 = "
                    f"{max_seq - 1} (last slot is the pad-parking slot)")
            if eng.paged:
                span = len(r.prompt) + r.max_new_tokens
                if eng.alloc.pages_needed(span) > eng.alloc.capacity_pages:
                    raise ValueError(
                        f"request {r.uid}: token span {span} needs "
                        f"{eng.alloc.pages_needed(span)} pages but the "
                        f"pool holds {eng.alloc.capacity_pages} — it "
                        "could never be admitted")
        if not eng.supports_ragged:
            lens = {len(r.prompt) for r in requests}
            if len(lens) > 1:
                raise ValueError(
                    "ragged prompts need per-position attention masking; "
                    f"recurrent arch '{eng.model.cfg.name}' requires "
                    "equal-length prompts")
        if eng.speculating and any(r.temperature > 0 for r in requests):
            raise ValueError(
                "speculative decoding is greedy-only: acceptance compares "
                "draft tokens against the full model's argmax (sampled "
                "verification needs rejection sampling — not implemented)")

        t0 = time.perf_counter()
        t_submit = {r.uid: t0 for r in requests}
        retries: Dict[int, int] = collections.Counter()
        done: Dict[int, Response] = {}

        # ---- bounded admission: reject overflow with a typed response --
        queue = collections.deque()
        capacity = (B + eng.max_queue if eng.max_queue is not None
                    else None)
        for r in requests:
            if capacity is not None and len(queue) >= capacity:
                done[r.uid] = Response(
                    uid=r.uid, prompt_len=len(r.prompt),
                    tokens=np.zeros((0,), np.int32),
                    finish_reason="rejected", latency_s=0.0)
                eng.count("rejected")
            else:
                queue.append(r)

        slots: Dict[int, Optional[_Slot]] = {i: None for i in range(B)}
        free = list(range(B))
        # host mirrors of the device carry
        cur_tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)

        def expired(req: Request) -> bool:
            return (req.deadline_s is not None and
                    time.perf_counter() - t_submit[req.uid] >
                    req.deadline_s)

        def finish(i: int, reason: str) -> None:
            s = slots[i]
            done[s.req.uid] = Response(
                uid=s.req.uid, prompt_len=len(s.req.prompt),
                tokens=np.asarray(s.tokens, np.int32), finish_reason=reason,
                latency_s=time.perf_counter() - t_submit[s.req.uid])
            if reason in ("timeout", "error"):
                eng.count("timeouts" if reason == "timeout" else "errors")
            slots[i] = None
            temps[i] = 0.0
            eng.release_slot(i)  # paged: pages return to the pool now
            free.append(i)

        def quarantine(i: int) -> None:
            """The engine flagged slot i's logits non-finite: its chunk
            tokens are garbage.  Free the slot (the next admission prefill
            rewrites its cache row) and re-queue the request from scratch,
            bounded by engine.max_slot_retries."""
            s = slots[i]
            eng.count("quarantines")
            eng.events.append({"kind": "quarantine", "uid": s.req.uid,
                               "slot": i,
                               "retry": retries[s.req.uid] + 1})
            retries[s.req.uid] += 1
            if retries[s.req.uid] > eng.max_slot_retries:
                finish(i, "error")
                return
            eng.count("requeues")
            queue.appendleft(s.req)  # front: it already held a slot
            slots[i] = None
            temps[i] = 0.0
            eng.release_slot(i)  # paged: pages return to the pool now
            free.append(i)

        def consume(i: int, toks: np.ndarray) -> None:
            """Fold freshly decoded tokens into slot i, finishing on EOS
            or budget exhaustion (extra chunk tokens are dropped)."""
            s = slots[i]
            for t in toks:
                s.tokens.append(int(t))
                if s.req.eos_id is not None and int(t) == s.req.eos_id:
                    finish(i, "eos")
                    return
                if len(s.tokens) >= s.req.max_new_tokens:
                    finish(i, "length")
                    return
            if expired(s.req):  # deadline hit mid-generation
                finish(i, "timeout")

        while queue or len(free) < B:
            # ---- admit ------------------------------------------------
            newly: List[int] = []
            pending_pages = 0  # pages this round will claim in eng.admit
            while queue and free:
                req = queue[0]  # peek: pool waits must not reorder
                if expired(req):  # died waiting in the queue
                    queue.popleft()
                    done[req.uid] = Response(
                        uid=req.uid, prompt_len=len(req.prompt),
                        tokens=np.zeros((0,), np.int32),
                        finish_reason="timeout",
                        latency_s=time.perf_counter() - t_submit[req.uid])
                    eng.count("timeouts")
                    continue
                if eng.paged:
                    need = eng.alloc.pages_needed(
                        len(req.prompt) + req.max_new_tokens)
                    # allocation happens inside eng.admit, after this
                    # loop — count this round's earlier admissions too
                    if need + pending_pages > len(eng.alloc.free):
                        # wait for a live slot to finish and release
                        # pages — the submit-time guard makes this
                        # unreachable with an idle engine (all slots
                        # free ⇒ the whole pool free)
                        if not newly and len(free) == B:
                            raise RuntimeError(
                                f"page pool wedged: request {req.uid} "
                                "cannot be admitted with every slot free")
                        break
                    pending_pages += need
                queue.popleft()
                i = free.pop()
                slots[i] = _Slot(req=req, tokens=[],
                                 t_admit=time.perf_counter())
                newly.append(i)
            if newly:
                if not eng.supports_ragged:
                    P = max(len(slots[i].req.prompt) for i in newly)
                else:
                    P = _bucket(max(len(slots[i].req.prompt)
                                    for i in newly), eng.prompt_bucket)
                tokens = np.zeros((B, P), np.int32)
                pads = np.full((B,), P, np.int32)  # non-admitted: all-pad
                admit = np.zeros((B,), bool)
                budgets = np.zeros((B,), np.int32)
                for i in newly:
                    p = slots[i].req.prompt
                    tokens[i, P - len(p):] = p
                    pads[i] = P - len(p)
                    admit[i] = True
                    temps[i] = slots[i].req.temperature
                    budgets[i] = len(p) + slots[i].req.max_new_tokens
                positions = (np.arange(P)[None, :] -
                             pads[:, None]).astype(np.int32)
                tok0, ok = eng.admit(tokens, positions, admit, temps, rng,
                                     budgets=budgets)
                for i in newly:
                    if not ok[i]:  # poisoned prefill: quarantine
                        quarantine(i)
                        continue
                    cur_tok[i, 0] = tok0[i]
                    pos[i] = len(slots[i].req.prompt)
                    consume(i, tok0[i:i + 1])
            # ---- decode one chunk --------------------------------------
            if len(free) == B:
                continue  # everything finished at its first token
            remaining = np.zeros((B,), np.int32)
            for i in range(B):
                if slots[i] is not None:
                    remaining[i] = (slots[i].req.max_new_tokens -
                                    len(slots[i].tokens))
            if eng.speculating:
                # one spec round: only toks[i, :n_valid[i]] are real —
                # the accepted draft prefix plus the bonus/correction
                # token, each the full model's greedy argmax
                toks, n_valid, new_tok, new_pos, ok = eng.spec_chunk(
                    cur_tok, pos, temps, rng, remaining=remaining)
            else:
                toks, new_tok, new_pos, ok = eng.decode_chunk(
                    cur_tok, pos, temps, rng, remaining=remaining)
                n_valid = np.full((B,), toks.shape[1], np.int32)
            cur_tok, pos = new_tok, new_pos
            for i in range(B):
                if slots[i] is None:
                    continue
                if not ok[i]:  # poisoned chunk: drop its tokens
                    quarantine(i)
                    continue
                consume(i, toks[i, :n_valid[i]])

        out = [done[r.uid] for r in requests]
        self.last_wall_s = time.perf_counter() - t0
        return out
