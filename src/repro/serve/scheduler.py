"""Slot-based continuous-batching scheduler for the serve engine.

The engine (serve/engine.py) owns the device state — persistent slot
caches, the jitted admission prefill and the jitted k-token decode chunk.
This module owns the *policy*: request/response dataclasses, slot
admission, EOS/length detection, slot recycling, and the serving
guardrails (deadlines, admission-queue bounds, NaN-slot quarantine).

Execution model
---------------
``max_batch`` slots share one (B, max_seq) cache set.  With the default
**overlap** engine each slot carries a phase (``prefill`` | ``decode``)
and every scheduler round issues ONE fused mixed dispatch
(``engine.mixed_chunk``): free slots pull arrived requests off the FIFO
queue (page-pool permitting) and enter the prefill phase; prefilling
slots consume their next ``prefill_chunk`` prompt tokens (the final,
partial slice left-padded so the newest token is always the last
column); decoding slots advance up to ``decode_block`` tokens (or one
spec round) in the same call.  A slot whose prompt completes flips to
decode with the dispatch's sampled first token; deadlines are re-checked
at every chunk boundary, so a long prompt can time out *mid-prefill* and
a page-blocked request is admitted the first chunk after pages free up.
Rounds with no prefilling slot fall through to the plain decode path
below.  ``overlap=False`` (and recurrent archs, automatically) restores
the legacy admit-then-decode rounds:

1. **Admit** — free slots pull requests off the queue.  The newly admitted
   prompts are **left-padded** to a shared bucket length and prefilled in
   one batched dispatch; rows that are not being admitted carry an all-pad
   dummy whose cache writes land in the sacrificial last slot and whose
   cache rows are masked back to their previous contents on merge
   (engine._admit).  Left padding puts every prompt's last real token in
   the final column, so one ``logits[:, -1]`` read samples every first
   token.  Pad columns carry **negative positions**: rope/visibility use
   the true per-sequence position (cache slot == sequence index, identical
   to an unpadded run), the attention mask hides everything the row has
   not written, and pad K/V parks in the reserved ``max_seq - 1`` slot —
   which is why a request must fit ``prompt + max_new ≤ max_seq - 1``.

2. **Decode** — one jitted ``lax.scan`` dispatch advances every slot by
   up to ``decode_block`` tokens.  The scheduler passes each live slot's
   remaining budget and the engine scans only ``min(decode_block,
   min(remaining over live slots))`` steps: the *smallest* live budget
   bounds the chunk, so a nearly-done slot never rides through (and a
   fresh long request never inflates) a chunk whose tail it would drop
   anyway.  The host then scans the (B, k) chunk for per-request EOS /
   length exhaustion, finalizes responses and recycles slots for the next
   admit round.

   With a ``draft_plan`` set the decode dispatch is a **speculative
   round** instead (``engine.spec_chunk``): the low-rank self-draft
   proposes ``spec_window - 1`` tokens, the full model verifies the whole
   window in one dispatch, and only the accepted prefix + bonus token
   (``toks[i, :n_valid[i]]``) is consumed — every consumed token is the
   full model's greedy argmax, so the output stream is bit-identical to
   plain decode.  Speculative mode is greedy-only (``run`` rejects
   ``temperature > 0`` requests up front).

Paged engines add a third policy axis: page-pool admission.  Each
request's full token span (``prompt + max_new``) is claimed at admit and
released the moment its slot finishes or is quarantined
(``engine.release_slot``).  When the free list cannot cover the next
queued request, admission *waits* — live slots keep decoding, and their
releases unblock the queue.  This cannot deadlock: a submit-time guard
rejects any request whose span exceeds the whole pool, so an all-free
engine (⇒ an all-free pool) can always admit the queue head.

Guardrails (chaos-tested in tests/test_chaos.py)
------------------------------------------------
* **Bounded admission queue** — with ``engine.max_queue`` set, requests
  beyond ``free slots + max_queue`` at submit are finished immediately
  with ``finish_reason='rejected'`` (a typed response, never an
  exception) so a traffic spike degrades instead of OOMing the host.
* **Per-request deadlines** — ``Request.deadline_s`` is a wall-clock
  budget from arrival; a request that expires while queued, mid-prefill
  (overlap engines: between prompt chunks) or mid-generation is
  finalized with whatever tokens it has and ``finish_reason='timeout'``.
  Deadlines are swept after *every* dispatch — a queued request whose
  deadline passes during a long dispatch is reaped immediately
  (``queue_timeout`` event), not one full round late.
* **NaN quarantine** — the engine flags any slot whose logits went
  non-finite during a chunk.  That slot's chunk tokens are discarded, the
  slot is quarantined (freed; its cache row is rewritten by the next
  admission prefill) and the request is re-queued from scratch at the
  front of the queue, bounded by ``engine.max_slot_retries`` before
  ``finish_reason='error'``.  The surviving slots consume their chunk
  normally — slots are independent batch rows, so their greedy streams
  stay bit-identical to an undisturbed run.

``finish_reason`` is the guardrail contract: ``'eos' | 'length' |
'timeout' | 'rejected' | 'error'`` — failures surface as typed responses,
and every event is counted in ``engine.stats()``.

Ragged prompts require per-position attention masking, which only the
attention caches implement; recurrent archs (mamba/rwkv6) would absorb the
pad tokens into their state, so the scheduler rejects ragged admission for
them (equal-length prompts still work — pad is zero).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Dict, List, Optional

import numpy as np

FINISH_REASONS = ("eos", "length", "timeout", "rejected", "error")


@dataclasses.dataclass
class Request:
    """One generation request.  ``prompt`` is a 1-D int32 token array.
    ``deadline_s`` is an optional wall-clock budget measured from arrival
    (None = no deadline).  ``arrival_s`` staggers the request's arrival
    relative to ``run()``'s start (churn traces for the latency
    benchmarks; 0 = available immediately, the historical behavior) —
    admission stays FIFO, a not-yet-arrived queue head blocks the ones
    behind it."""
    uid: int
    prompt: np.ndarray
    max_new_tokens: int
    temperature: float = 0.0
    eos_id: Optional[int] = None
    deadline_s: Optional[float] = None
    arrival_s: float = 0.0

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(f"request {self.uid}: max_new_tokens < 1")


@dataclasses.dataclass
class Response:
    """Completed generation.  ``tokens`` includes the EOS token when the
    request finished on one; ``finish_reason`` is one of
    :data:`FINISH_REASONS` (rejected/timed-out requests return partial or
    empty token arrays, never raise)."""
    uid: int
    prompt_len: int
    tokens: np.ndarray
    finish_reason: str          # FINISH_REASONS
    latency_s: float            # arrival -> finish
    ttft_s: Optional[float] = None  # arrival -> first token (None if the
                                    # request never produced one)


@dataclasses.dataclass
class _Slot:
    req: Request
    tokens: List[int]
    t_admit: float
    # chunked-prefill state (overlap engines): how many prompt tokens
    # have been written to the cache, and which phase the slot is in
    cursor: int = 0
    phase: str = "decode"       # 'prefill' | 'decode'
    # latency bookkeeping
    t_first: Optional[float] = None  # first-token wall time
    t_last: Optional[float] = None   # latest-token wall time


def _bucket(n: int, quantum: int) -> int:
    """Round a prompt length up to the bucket quantum (bounds the number
    of prefill recompiles to O(max_seq / quantum))."""
    return ((n + quantum - 1) // quantum) * quantum


class SlotScheduler:
    """Continuous batching over a ServeEngine's slots."""

    def __init__(self, engine):
        self.engine = engine

    # -----------------------------------------------------------------
    def run(self, requests: List[Request], *,
            rng: Optional[np.ndarray] = None) -> List[Response]:
        """Drive all requests to completion; returns responses in uid
        order.  ``rng`` is a jax PRNGKey enabling temperature sampling
        (greedy rows are unaffected — see engine._sample_batch)."""
        eng = self.engine
        B, max_seq = eng.max_batch, eng.max_seq
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > max_seq - 1:
                raise ValueError(
                    f"request {r.uid}: prompt({len(r.prompt)}) + "
                    f"max_new({r.max_new_tokens}) must fit max_seq-1 = "
                    f"{max_seq - 1} (last slot is the pad-parking slot)")
            if eng.paged:
                span = len(r.prompt) + r.max_new_tokens
                if eng.alloc.pages_needed(span) > eng.alloc.capacity_pages:
                    raise ValueError(
                        f"request {r.uid}: token span {span} needs "
                        f"{eng.alloc.pages_needed(span)} pages but the "
                        f"pool holds {eng.alloc.capacity_pages} — it "
                        "could never be admitted")
        if not eng.supports_ragged:
            lens = {len(r.prompt) for r in requests}
            if len(lens) > 1:
                raise ValueError(
                    "ragged prompts need per-position attention masking; "
                    f"recurrent arch '{eng.model.cfg.name}' requires "
                    "equal-length prompts")
        if eng.speculating and any(r.temperature > 0 for r in requests):
            raise ValueError(
                "speculative decoding is greedy-only: acceptance compares "
                "draft tokens against the full model's argmax (sampled "
                "verification needs rejection sampling — not implemented)")

        overlap = eng.overlap
        t0 = time.perf_counter()
        # arrival: deadlines and latency are measured from when the
        # request arrives, not from run()'s start
        t_submit = {r.uid: t0 + r.arrival_s for r in requests}
        retries: Dict[int, int] = collections.Counter()
        done: Dict[int, Response] = {}

        # ---- bounded admission: reject overflow with a typed response --
        queue = collections.deque()
        capacity = (B + eng.max_queue if eng.max_queue is not None
                    else None)
        for r in requests:
            if capacity is not None and len(queue) >= capacity:
                done[r.uid] = Response(
                    uid=r.uid, prompt_len=len(r.prompt),
                    tokens=np.zeros((0,), np.int32),
                    finish_reason="rejected", latency_s=0.0)
                eng.count("rejected")
            else:
                queue.append(r)

        slots: Dict[int, Optional[_Slot]] = {i: None for i in range(B)}
        free = list(range(B))
        # host mirrors of the device carry
        cur_tok = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        temps = np.zeros((B,), np.float32)

        def expired(req: Request) -> bool:
            return (req.deadline_s is not None and
                    time.perf_counter() - t_submit[req.uid] >
                    req.deadline_s)

        def finish(i: int, reason: str) -> None:
            s = slots[i]
            done[s.req.uid] = Response(
                uid=s.req.uid, prompt_len=len(s.req.prompt),
                tokens=np.asarray(s.tokens, np.int32), finish_reason=reason,
                latency_s=time.perf_counter() - t_submit[s.req.uid],
                ttft_s=(None if s.t_first is None
                        else s.t_first - t_submit[s.req.uid]))
            if reason in ("timeout", "error"):
                eng.count("timeouts" if reason == "timeout" else "errors")
            slots[i] = None
            temps[i] = 0.0
            eng.release_slot(i)  # paged: pages return to the pool now
            free.append(i)

        def sweep_queue() -> None:
            """Deadline sweep over *queued* requests.  Runs after every
            dispatch — not just at round boundaries — so a request whose
            deadline passes during a long dispatch (or a long prompt's
            chunked prefill) is finalized immediately instead of one full
            round late."""
            for req in [r for r in queue if expired(r)]:
                queue.remove(req)
                done[req.uid] = Response(
                    uid=req.uid, prompt_len=len(req.prompt),
                    tokens=np.zeros((0,), np.int32),
                    finish_reason="timeout",
                    latency_s=time.perf_counter() - t_submit[req.uid])
                eng.count("timeouts")
                eng.events.append({"kind": "queue_timeout", "uid": req.uid})

        def quarantine(i: int) -> None:
            """The engine flagged slot i's logits non-finite: its chunk
            tokens are garbage.  Free the slot (the next admission prefill
            rewrites its cache row) and re-queue the request from scratch,
            bounded by engine.max_slot_retries."""
            s = slots[i]
            eng.count("quarantines")
            eng.events.append({"kind": "quarantine", "uid": s.req.uid,
                               "slot": i,
                               "retry": retries[s.req.uid] + 1})
            retries[s.req.uid] += 1
            if retries[s.req.uid] > eng.max_slot_retries:
                finish(i, "error")
                return
            eng.count("requeues")
            queue.appendleft(s.req)  # front: it already held a slot
            slots[i] = None
            temps[i] = 0.0
            eng.release_slot(i)  # paged: pages return to the pool now
            free.append(i)

        def consume(i: int, toks: np.ndarray,
                    t_now: Optional[float] = None) -> None:
            """Fold freshly decoded tokens into slot i, finishing on EOS
            or budget exhaustion (extra chunk tokens are dropped).
            ``t_now`` is the dispatch-completion wall time: every token
            of one dispatch shares it, so the recorded inter-token gaps
            are 0 within a chunk and the real stall between chunks —
            exactly the tail the latency percentiles must surface."""
            s = slots[i]
            t_now = time.perf_counter() if t_now is None else t_now
            for t in toks:
                if s.t_first is None:
                    s.t_first = t_now
                    eng.record_ttft(t_now - t_submit[s.req.uid])
                else:
                    eng.record_itl(t_now - s.t_last)
                s.t_last = t_now
                s.tokens.append(int(t))
                if s.req.eos_id is not None and int(t) == s.req.eos_id:
                    finish(i, "eos")
                    return
                if len(s.tokens) >= s.req.max_new_tokens:
                    finish(i, "length")
                    return
            if expired(s.req):  # deadline hit mid-generation
                finish(i, "timeout")

        while queue or len(free) < B:
            sweep_queue()
            # ---- admit: assign free slots (FIFO) ----------------------
            newly: List[int] = []
            pending_pages = 0  # pages this round's admissions will claim
            while queue and free:
                req = queue[0]  # peek: pool/arrival waits must not reorder
                if t_submit[req.uid] > time.perf_counter():
                    break  # not yet arrived
                if expired(req):  # died waiting in the queue
                    queue.popleft()
                    done[req.uid] = Response(
                        uid=req.uid, prompt_len=len(req.prompt),
                        tokens=np.zeros((0,), np.int32),
                        finish_reason="timeout",
                        latency_s=time.perf_counter() - t_submit[req.uid])
                    eng.count("timeouts")
                    continue
                if eng.paged:
                    need = eng.alloc.pages_needed(
                        len(req.prompt) + req.max_new_tokens)
                    # allocation happens inside the admitting dispatch,
                    # after this loop — count this round's earlier
                    # admissions too.  Overlap engines re-run this check
                    # at every chunk boundary (admission is no longer a
                    # once-per-round event), so a page-blocked request
                    # is admitted the first chunk after pages free up.
                    if need + pending_pages > len(eng.alloc.free):
                        # wait for a live slot to finish and release
                        # pages — the submit-time guard makes this
                        # unreachable with an idle engine (all slots
                        # free ⇒ the whole pool free)
                        if not newly and len(free) == B:
                            raise RuntimeError(
                                f"page pool wedged: request {req.uid} "
                                "cannot be admitted with every slot free")
                        break
                    pending_pages += need
                queue.popleft()
                i = free.pop()
                slots[i] = _Slot(req=req, tokens=[],
                                 t_admit=time.perf_counter(),
                                 phase="prefill" if overlap else "decode")
                temps[i] = req.temperature
                newly.append(i)

            # ---- overlap: one fused mixed-phase dispatch ---------------
            pre_rows = [i for i in range(B) if slots[i] is not None and
                        slots[i].phase == "prefill"] if overlap else []
            if pre_rows:
                c = eng.prefill_chunk
                ptoks = np.zeros((B, c), np.int32)
                ppos = np.full((B, c), -1, np.int32)
                completes: List[int] = []
                for i in pre_rows:
                    s = slots[i]
                    prompt = s.req.prompt
                    take = min(c, len(prompt) - s.cursor)
                    # left-pad the (final, partial) chunk so the row's
                    # newest token always lands in the last column
                    ptoks[i, c - take:] = prompt[s.cursor:s.cursor + take]
                    ppos[i, c - take:] = np.arange(s.cursor, s.cursor + take)
                    s.cursor += take
                    if s.cursor == len(prompt):
                        completes.append(i)
                admit_budgets = None
                if newly:
                    admit_budgets = np.zeros((B,), np.int32)
                    for i in newly:
                        admit_budgets[i] = (len(slots[i].req.prompt) +
                                            slots[i].req.max_new_tokens)
                dec_rows = [i for i in range(B) if slots[i] is not None and
                            slots[i].phase == "decode"]
                dec_mask = np.zeros((B,), bool)
                dec_mask[dec_rows] = True
                remaining = np.zeros((B,), np.int32)
                for i in dec_rows:
                    remaining[i] = (slots[i].req.max_new_tokens -
                                    len(slots[i].tokens))
                first, ok_p, toks, n_valid, new_tok, new_pos, ok_d = \
                    eng.mixed_chunk(ptoks, ppos, cur_tok, pos, dec_mask,
                                    temps, rng, remaining=remaining,
                                    admit_budgets=admit_budgets)
                t_disp = time.perf_counter()
                cur_tok, pos = new_tok, new_pos
                for i in dec_rows:
                    if not ok_d[i]:  # poisoned chunk: drop its tokens
                        quarantine(i)
                        continue
                    consume(i, toks[i, :n_valid[i]], t_disp)
                for i in pre_rows:
                    s = slots[i]
                    if not ok_p[i]:  # poisoned prefill chunk: re-queue
                        quarantine(i)  # (the retry restarts the prompt)
                        continue
                    if i in completes:
                        s.phase = "decode"
                        cur_tok[i, 0] = first[i]
                        pos[i] = len(s.req.prompt)
                        consume(i, first[i:i + 1], t_disp)
                    elif expired(s.req):
                        finish(i, "timeout")  # timed out mid-prefill
                sweep_queue()
                continue

            # ---- non-overlap: monolithic batched admission -------------
            if not overlap and newly:
                if not eng.supports_ragged:
                    P = max(len(slots[i].req.prompt) for i in newly)
                else:
                    P = _bucket(max(len(slots[i].req.prompt)
                                    for i in newly), eng.prompt_bucket)
                tokens = np.zeros((B, P), np.int32)
                pads = np.full((B,), P, np.int32)  # non-admitted: all-pad
                admit = np.zeros((B,), bool)
                budgets = np.zeros((B,), np.int32)
                for i in newly:
                    p = slots[i].req.prompt
                    tokens[i, P - len(p):] = p
                    pads[i] = P - len(p)
                    admit[i] = True
                    budgets[i] = len(p) + slots[i].req.max_new_tokens
                positions = (np.arange(P)[None, :] -
                             pads[:, None]).astype(np.int32)
                tok0, ok = eng.admit(tokens, positions, admit, temps, rng,
                                     budgets=budgets)
                t_disp = time.perf_counter()
                for i in newly:
                    if not ok[i]:  # poisoned prefill: quarantine
                        quarantine(i)
                        continue
                    cur_tok[i, 0] = tok0[i]
                    pos[i] = len(slots[i].req.prompt)
                    consume(i, tok0[i:i + 1], t_disp)
                sweep_queue()
            # ---- decode one chunk --------------------------------------
            if len(free) == B:
                if queue:
                    # every slot free but the queue head hasn't arrived
                    # yet — sleep toward the next arrival instead of
                    # spinning (page-blocked is impossible here: all
                    # slots free ⇒ the whole pool free)
                    t_next = min(t_submit[r.uid] for r in queue)
                    time.sleep(min(max(t_next - time.perf_counter(), 0.0),
                                   0.05))
                continue  # or: everything finished at its first token
            remaining = np.zeros((B,), np.int32)
            for i in range(B):
                if slots[i] is not None:
                    remaining[i] = (slots[i].req.max_new_tokens -
                                    len(slots[i].tokens))
            if eng.speculating:
                # one spec round: only toks[i, :n_valid[i]] are real —
                # the accepted draft prefix plus the bonus/correction
                # token, each the full model's greedy argmax
                toks, n_valid, new_tok, new_pos, ok = eng.spec_chunk(
                    cur_tok, pos, temps, rng, remaining=remaining)
            else:
                toks, new_tok, new_pos, ok = eng.decode_chunk(
                    cur_tok, pos, temps, rng, remaining=remaining)
                n_valid = np.full((B,), toks.shape[1], np.int32)
            t_disp = time.perf_counter()
            cur_tok, pos = new_tok, new_pos
            for i in range(B):
                if slots[i] is None:
                    continue
                if not ok[i]:  # poisoned chunk: drop its tokens
                    quarantine(i)
                    continue
                consume(i, toks[i, :n_valid[i]], t_disp)
            sweep_queue()

        out = [done[r.uid] for r in requests]
        self.last_wall_s = time.perf_counter() - t0
        return out
