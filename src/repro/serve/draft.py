"""Low-rank self-draft construction for speculative decoding.

CoLA's 2×-smaller-model claim (paper Table 11) makes a CoLA model its own
draft model: every linear site is already factorized ``h = B·σ(A·x)``, so
a cheaper draft falls out of the *same* weights in two ways —

* **rank truncation** — keep the r' most important factor directions of
  each site.  Importance of direction j is ``s_j = ‖A[:, j]‖·‖B[j, :]‖``
  (the exact σ_j when A, B come from an SVD; a cheap, calibration-free
  proxy otherwise), aggregated over the period-stacked leading axis by
  RMS.  ``core.rank_analysis.pick_draft_ranks`` turns those importance
  spectra into per-site draft ranks at an energy level α — per-layer, not
  one global cut (CR-Net's cross-layer rank observation, PAPERS.md).
  The draft parameters are **gather views into the full A/B factors**
  (``A[..., idx]``, ``B[..., idx, :]``) built in-trace at dispatch time:
  the draft owns zero persistent weight HBM, and because the kept
  directions preserve their original order, an α=1 draft reproduces the
  full model's GEMM summation order — bit-identical logits, which is what
  lets the α→1 limit degrade speculative decoding into plain decode
  instead of into a subtly different stream.

* **depth truncation** — keep a subset of the period-stacked transformer
  blocks: every p-th period (``stride``, the cheap-uniform choice) or the
  first ⌈n/p⌉ periods (``prefix``, which measures better on briefly
  trained models whose late blocks contribute least).  The stacked
  ``lax.scan`` derives its trip count from the leading axis of the
  parameter leaves, so the sliced tree runs through the unmodified Model.

Both compose.  The draft needs its own KV cache (its K/V projections
differ from the full model's), shaped by the same page table in paged
mode — ``draft_caches`` derives the pool from the engine's abstract cache
shapes with the kept-period leading axis.

``serve/engine.py`` drives the draft k−1 greedy steps through the
existing decode GEMV path at reduced r, then verifies all k positions in
one full-model dispatch; see the engine's spec-decode machinery for the
accept/rollback protocol.  Under the overlap engine the draft KV is
prefilled **chunk by chunk** alongside the full model's (each mixed
dispatch's prompt slice runs through the truncated views too), so
speculation composes with chunked prefill without a draft-side admission
stall.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.rank_analysis import pick_draft_ranks


@dataclasses.dataclass(frozen=True)
class SiteTrunc:
    """One CoLA site's rank truncation: keep ``idx`` (sorted, original
    order — summation-order-preserving) of the full rank."""
    path: Tuple[str, ...]
    d_in: int
    rank: int
    draft_rank: int
    d_out: int
    idx: Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class DraftPlan:
    """Static description of the self-draft: which periods survive depth
    truncation and which factor directions survive rank truncation.
    Pure data — ``draft_params`` applies it in-trace."""
    n_periods: int
    keep_periods: Tuple[int, ...]
    sites: Tuple[SiteTrunc, ...]
    alpha: Optional[float] = None
    depth: Optional[int] = None
    depth_mode: str = "stride"

    @property
    def is_identity(self) -> bool:
        return (len(self.keep_periods) == self.n_periods and
                all(s.draft_rank == s.rank for s in self.sites))

    def describe(self) -> Dict:
        """JSON-able summary (benchmarks / launch logging)."""
        return {
            "alpha": self.alpha, "depth": self.depth,
            "depth_mode": self.depth_mode,
            "keep_periods": list(self.keep_periods),
            "n_periods": self.n_periods,
            "site_ranks": {"/".join(s.path): [s.rank, s.draft_rank]
                           for s in self.sites},
        }


def _is_cola_site(tree) -> bool:
    return isinstance(tree, dict) and "a" in tree and "b" in tree


def _host_factor(w) -> np.ndarray:
    """Concrete f32 host copy of a factor — dequantizes QuantFactors so
    the importance spectra (and therefore the plan) computed on a
    quantized engine match an engine holding ``dequantize(params)``."""
    from repro.kernels.cola_ae import quant as _quant
    if isinstance(w, _quant.QuantFactor):
        return np.asarray(_quant.dequantize(w), np.float32)
    return np.asarray(w, np.float32)


def _take_rank(w, idx: np.ndarray, axis: int):
    """Rank-axis gather view for dense factors AND QuantFactors.  The
    rank axis never carries int4 packing (packing is along d_in/d_out)
    and the scale layouts are rank-independent, so a quantized draft
    gathers the q codes and *shares the scale arrays untouched* — still
    zero persistent draft weight HBM, and
    ``dequantize(take(q)) == take(dequantize(q))`` keeps the truncated
    quant draft bit-identical to truncating the dequantized factors."""
    from repro.kernels.cola_ae import quant as _quant
    if isinstance(w, _quant.QuantFactor):
        return _quant.QuantFactor(jnp.take(w.q, idx, axis=axis),
                                  w.scale, kind=w.kind, bits=w.bits)
    return jnp.take(w, idx, axis=axis)


def _walk_sites(tree, path=()):
    """Yield (path, site_dict) for every CoLA site in a block tree."""
    if _is_cola_site(tree):
        yield path, tree
        return
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _walk_sites(tree[k], path + (k,))


def site_importance(site: Dict, keep_periods: np.ndarray) -> np.ndarray:
    """Per-direction importance ``s_j = ‖A[:, j]‖·‖B[j, :]‖`` of a
    period-stacked CoLA site, RMS-aggregated over the kept periods.
    Host-side numpy on concrete params (plan time, not trace time)."""
    a = _host_factor(site["a"])[keep_periods]            # (P', ..., d_in, r)
    b = _host_factor(site["b"])[keep_periods]            # (P', ..., r, d_out)
    na = np.sqrt(np.sum(a * a, axis=-2))                 # (P', ..., r)
    nb = np.sqrt(np.sum(b * b, axis=-1))                 # (P', ..., r)
    s = na * nb
    s = s.reshape(-1, s.shape[-1])                       # fold periods/experts
    return np.sqrt(np.mean(s * s, axis=0))               # (r,)


def plan_draft(params: Dict, *, alpha: Optional[float] = None,
               depth: Optional[int] = None,
               depth_mode: str = "stride") -> DraftPlan:
    """Build a :class:`DraftPlan` from concrete full-model params.

    ``alpha``   — keep each site's smallest direction set holding α of
                  its importance energy (``pick_draft_ranks``); None or
                  1.0 keeps the full rank.
    ``depth``   — keep every ``depth``-th period (``depth_mode='stride'``)
                  or the first ⌈n/depth⌉ periods (``'prefix'``); None or
                  1 keeps the full depth.
    """
    if depth_mode not in ("stride", "prefix"):
        raise ValueError(f"depth_mode must be stride|prefix: {depth_mode}")
    blocks = params["blocks"]
    n_per = int(jax.tree.leaves(blocks)[0].shape[0])
    if depth is None or depth <= 1:
        keep = tuple(range(n_per))
    elif depth_mode == "stride":
        keep = tuple(range(0, n_per, int(depth)))
    else:
        keep = tuple(range(-(-n_per // int(depth))))
    kp = np.asarray(keep, np.int32)

    sites: List[SiteTrunc] = []
    for path, site in _walk_sites(blocks):
        d_in = int(site["a"].shape[-2])
        rank = int(site["a"].shape[-1])
        d_out = int(site["b"].shape[-1])
        if alpha is None or alpha >= 1.0:
            r_draft, idx = rank, tuple(range(rank))
        else:
            imp = site_importance(site, kp)
            r_draft = pick_draft_ranks(
                [{"layer": 0, "spectrum": imp}], alpha, max_rank=rank)[0]
            order = np.argsort(-imp, kind="stable")[:r_draft]
            idx = tuple(int(i) for i in np.sort(order))
        sites.append(SiteTrunc(path, d_in, rank, r_draft, d_out, idx))
    return DraftPlan(n_per, keep, tuple(sites), alpha=alpha, depth=depth,
                     depth_mode=depth_mode)


def draft_params(params: Dict, plan: DraftPlan) -> Dict:
    """Derive the draft parameter tree as views into the full params.
    Safe to call inside a jit trace: period selection and rank selection
    are static gathers (the indices are plan constants), so XLA fuses
    them into the consuming GEMVs — the draft stores no weights of its
    own."""
    if plan.is_identity:
        return params
    kp = np.asarray(plan.keep_periods, np.int32)
    blocks = jax.tree.map(lambda w: w[kp], params["blocks"])
    for s in plan.sites:
        if s.draft_rank == s.rank:
            continue
        node = blocks
        for k in s.path[:-1]:
            node = node[k]
        site = dict(node[s.path[-1]])
        idx = np.asarray(s.idx, np.int32)
        site["a"] = _take_rank(site["a"], idx, axis=-1)
        site["b"] = _take_rank(site["b"], idx, axis=-2)
        if site.get("bias_a") is not None:
            site["bias_a"] = jnp.take(site["bias_a"], idx, axis=-1)
        node[s.path[-1]] = site
    out = dict(params)
    out["blocks"] = blocks
    return out


def draft_caches(abstract_full: Dict, plan: DraftPlan,
                 make=jnp.zeros) -> Dict:
    """Fresh draft KV buffers shaped like the engine's full caches with
    the kept-period leading axis (the draft's K/V differ from the full
    model's, so it cannot share cache storage — only weight storage)."""
    n_keep = len(plan.keep_periods)
    return jax.tree.map(
        lambda l: make((n_keep,) + tuple(l.shape[1:]), l.dtype),
        abstract_full)


# ---- modeled HBM ---------------------------------------------------------
def _site_stream_bytes(rank: int, d_in: int, d_out: int, bytes_el: int,
                       weight_bits: Optional[int]) -> int:
    """Streamed bytes for one site's factor pair at the given rank.
    ``weight_bits`` (8|4) models the quantized stream: packed codes at
    ``ceil(n·bits/8)`` plus 4 f32 scale bytes per A row and per B column
    — the scale term does NOT shrink under rank truncation (a quantized
    draft gathers q codes but streams the full per-row/column scale
    vectors), so drafts over quantized factors are charged honestly."""
    if weight_bits is None:
        return bytes_el * rank * (d_in + d_out)
    return ((rank * (d_in + d_out) * weight_bits + 7) // 8
            + 4 * (d_in + d_out))


def draft_weight_bytes(plan: DraftPlan, *, bytes_el: int = 2,
                       weight_bits: Optional[int] = None) -> int:
    """Streamed A/B factor bytes for ONE draft decode step (all kept
    periods, truncated ranks) — the ``w`` term of the modeled
    HBM-per-accepted-token story."""
    per_period = sum(
        _site_stream_bytes(s.draft_rank, s.d_in, s.d_out, bytes_el,
                           weight_bits)
        for s in plan.sites)
    return per_period * len(plan.keep_periods)


def full_weight_bytes(plan: DraftPlan, *, bytes_el: int = 2,
                      weight_bits: Optional[int] = None) -> int:
    """Streamed A/B factor bytes for one full-model dispatch (weights are
    read once per dispatch regardless of the resident token count — the
    decode kernel's amortization, kernels/cola_ae/kernel.py)."""
    per_period = sum(
        _site_stream_bytes(s.rank, s.d_in, s.d_out, bytes_el, weight_bits)
        for s in plan.sites)
    return per_period * plan.n_periods


def spec_hbm_per_accepted_token(plan: DraftPlan, window: int,
                                mean_accepted: float, *,
                                bytes_el: int = 2,
                                weight_bits: Optional[int] = None
                                ) -> Dict[str, float]:
    """Modeled weight-stream bytes per *accepted* token of one
    speculative round against the plain-decode baseline.

    One round = (window−1) draft steps (each streams the truncated
    factors once) + one full-model verify dispatch (streams the full
    factors once, amortized over all ``window`` resident positions),
    yielding ``mean_accepted`` tokens.  Plain decode streams the full
    factors once per token.  ``weight_bits`` composes the quantized
    stream into both sides (scale bytes charged per step, unshrunk by
    rank truncation).
    """
    d = draft_weight_bytes(plan, bytes_el=bytes_el, weight_bits=weight_bits)
    f = full_weight_bytes(plan, bytes_el=bytes_el, weight_bits=weight_bits)
    spec = ((window - 1) * d + f) / max(mean_accepted, 1e-9)
    return {"plain_bytes_per_token": float(f),
            "spec_bytes_per_accepted_token": float(spec),
            "draft_step_bytes": float(d),
            "hbm_ratio_vs_plain": float(spec / f)}
