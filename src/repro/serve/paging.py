"""Paged KV-cache bookkeeping for the serve engine (host-side).

The engine's dense per-slot caches hold ``(B, max_seq)`` rows per leaf —
every slot pays the worst case for its whole lifetime.  Paged mode keeps
one flat **physical-row pool** per cache leaf, ``(periods, R, ...)`` with
``R = n_pages × page_size``, shared across slots.  This allocator owns the
mapping from logical positions to pool rows:

* a **free list** of fixed-size pages (page 0 is never on it — see below),
* **per-slot page lists**: pages are allocated up-front at admission to
  cover the request's full token span (prompt + generation budget, known
  at admit time) and returned the moment the slot finishes or is
  quarantined — compaction is immediate, not deferred,
* the ``page_map`` — an ``(B, max_seq) int32`` map from (slot, logical
  position) to physical pool row, shipped to the device with every
  dispatch.  Attention writes K/V through it and gathers the logical view
  back out of the pool (models/attention.py).

**The sacrificial page.**  Row 0 (all of page 0) plays the role the dense
layout gives the ``max_seq - 1`` slot: left-pad positions are negative and
park their K/V writes there, and any position a slot does not own
(beyond its allocated span, or after release) also maps to row 0.  Reads
through those map entries are always masked by the causal/visibility mask
(`_sdpa` uses -inf → exp ≡ 0), so garbage in the sacrificial row never
reaches a live score — the same argument that makes the dense pad-parking
slot safe.  Column ``max_seq - 1`` of every map row therefore always
stays sacrificial, preserving the engine's ``prompt + max_new ≤
max_seq - 1`` invariant in paged form.

Invariants (property-tested in tests/test_properties.py):

* a page is never owned by two slots (no double-allocation),
* ``len(free) + Σ_slot len(pages[slot]) == n_pages - 1`` always
  (the pool is conserved; page 0 is permanently reserved),
* a slot's page list reconstructs exactly the token positions a dense
  cache would hold: logical position p lives at row
  ``pages[p // page_size] * page_size + p % page_size``,
* after release, a slot's map row is entirely sacrificial.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np


class PageAllocator:
    """Free-list page allocator + logical→physical page map.

    Pure host-side numpy; the engine ships ``page_map`` to the device as
    an argument of each jitted dispatch (its values change between
    dispatches, so it must not be baked into the trace).
    """

    SACRIFICIAL = 0  # physical row (and page) that absorbs masked writes

    def __init__(self, n_pages: int, page_size: int, max_batch: int,
                 max_seq: int):
        if n_pages < 2:
            raise ValueError("need at least one allocatable page "
                             "beside the sacrificial page 0")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        self.max_batch = int(max_batch)
        self.max_seq = int(max_seq)
        # LIFO free list: recently released pages are re-used first (their
        # rows are re-zeroed on admission — recycled-slot purity)
        self.free: List[int] = list(range(1, self.n_pages))
        self.pages: Dict[int, List[int]] = {i: [] for i in range(max_batch)}
        self.spans: Dict[int, int] = {i: 0 for i in range(max_batch)}
        self.page_map = np.zeros((max_batch, max_seq), dtype=np.int32)
        self.peak_pages = 0

    # -- capacity ----------------------------------------------------------
    @property
    def capacity_pages(self) -> int:
        return self.n_pages - 1

    @property
    def pages_in_use(self) -> int:
        return sum(len(p) for p in self.pages.values())

    def pages_needed(self, n_tokens: int) -> int:
        return -(-int(n_tokens) // self.page_size)

    def can_allocate(self, n_tokens: int) -> bool:
        return self.pages_needed(n_tokens) <= len(self.free)

    # -- allocate / release ------------------------------------------------
    def allocate(self, slot: int, n_tokens: int) -> np.ndarray:
        """Claim pages covering logical positions [0, n_tokens) for a slot
        and point its map row at them.  Returns the physical rows that now
        belong to the slot (the engine zeroes exactly these before the
        admission prefill — no cross-request KV leakage).
        """
        if self.pages[slot]:
            raise RuntimeError(f"slot {slot} still holds pages; "
                               f"release it before re-admission")
        n_tokens = int(n_tokens)
        if not 0 < n_tokens <= self.max_seq - 1:
            raise ValueError(f"token span {n_tokens} outside "
                             f"(0, {self.max_seq - 1}]")
        need = self.pages_needed(n_tokens)
        if need > len(self.free):
            raise RuntimeError(f"page pool exhausted: need {need}, "
                               f"free {len(self.free)}")
        got = [self.free.pop() for _ in range(need)]
        self.pages[slot] = got
        self.spans[slot] = n_tokens
        ps = self.page_size
        row = self.page_map[slot]
        row[:] = self.SACRIFICIAL
        for k, pid in enumerate(got):
            lo = k * ps
            hi = min(lo + ps, self.max_seq - 1)  # last col stays sacrificial
            row[lo:hi] = pid * ps + np.arange(hi - lo, dtype=np.int32)
        self.peak_pages = max(self.peak_pages, self.pages_in_use)
        return self.rows_of(slot)

    def release(self, slot: int) -> None:
        """Return a slot's pages to the free list and re-park its map row
        on the sacrificial page.  Idempotent."""
        self.free.extend(self.pages[slot])
        self.pages[slot] = []
        self.spans[slot] = 0
        self.page_map[slot, :] = self.SACRIFICIAL

    def rows_of(self, slot: int) -> np.ndarray:
        """All physical rows owned by a slot (page-granular, includes the
        tail rows of a partially-used last page)."""
        ps = self.page_size
        if not self.pages[slot]:
            return np.zeros((0,), dtype=np.int32)
        base = np.asarray(self.pages[slot], dtype=np.int32) * ps
        return (base[:, None] + np.arange(ps, dtype=np.int32)).reshape(-1)

    def check_invariants(self) -> None:
        """Raise AssertionError if any pool invariant is violated."""
        live = [p for ps_ in self.pages.values() for p in ps_]
        assert self.SACRIFICIAL not in live and self.SACRIFICIAL not in \
            self.free, "sacrificial page entered circulation"
        assert len(set(live)) == len(live), "page double-allocated"
        assert len(set(live) & set(self.free)) == 0, \
            "page simultaneously live and free"
        assert len(self.free) + len(live) == self.n_pages - 1, \
            "pool not conserved"
        ps = self.page_size
        for slot, plist in self.pages.items():
            row = self.page_map[slot]
            # the map is page-granular: a slot's row covers the full extent
            # of its pages (the tail of a partially-used last page belongs
            # to the slot too — zeroed at admit, masked until written)
            extent = len(plist) * ps
            for col in range(self.max_seq):
                if col < extent and col < self.max_seq - 1:
                    want = plist[col // ps] * ps + col % ps
                else:
                    want = self.SACRIFICIAL
                assert row[col] == want, (
                    f"slot {slot} col {col}: map row {row[col]} != {want}")
