"""Serving engine: persistent slot caches + jitted admission prefill +
a jitted ``lax.scan`` decode loop advancing every slot k tokens per device
dispatch.  Policy (admission order, EOS, slot recycling) lives in
serve/scheduler.py; this module owns the device state and the compiled
functions.

CoLA inference advantage (paper Table 11): the 2× smaller projections
halve both weight traffic and decode FLOPs.  The whole serving stack runs
``mode='infer'`` (model facade → linear_apply → cola_apply → the ops
planner): no residuals are saved anywhere, and each decode step's B×1
token batch lands below ``ops.DECODE_T_MAX`` so every CoLA site dispatches
the GEMV-shaped ``cola_ae_decode`` kernel — single launch, weights
streamed, z in VMEM — instead of the training-shaped token-tile grids
that are degenerate at T=1.

Dispatch discipline: the old engine issued one device dispatch per token
(84-line Python loop).  Here ``decode_chunk`` is one jitted call that
scans up to ``decode_block`` decode steps on device; the per-token Python
loop survives only as ``generate_python_loop``, the parity/benchmark
reference.  ``stats()['decode_dispatches']`` counts the jitted calls so
tests can assert dispatches == ceil(tokens / k).  Chunks are
**variable-k**: the scheduler passes each live slot's remaining budget
and the chunk scans only ``min(decode_block, max(remaining))`` steps —
finished slots no longer burn up to k decode steps per chunk, and
``stats()['decode_steps']`` counts the steps actually scanned (equal-
budget batches decode exactly ``max_new - 1`` steps, zero waste).

Paged KV (default for attn-only architectures): instead of dense
``(B, max_seq)`` slot caches, each cache leaf is a flat physical-row
pool ``(periods, R, ...)`` with ``R = n_pages × page_size``, shared
across slots through a free-list page allocator (serve/paging.py).
Pages are claimed at admission for the request's full token span and
released the moment the slot finishes — a finished long request frees
its rows immediately instead of holding ``max_seq`` of them until the
slot is recycled.  The (B, max_seq) ``page_map`` ships with every
dispatch; admission zeroes exactly the freshly claimed rows (recycled-
slot purity) and needs **no cache merge** — page ownership already
isolates tenants.  ``cache_hbm_bytes()`` reports paged-vs-dense
footprints for the benchmark rows.

Tensor-parallel serving: construct the engine with ``mesh=``/``profile=``
(baseline | megatron) and every jitted dispatch traces under that
``sharding.MeshEnv`` — each CoLA site then routes through
``ops.cola_ae_sharded(mode='infer')``, whose shard_map body runs the
per-shard decode kernels with the profile's collectives
(``sharded_infer_*`` DISPATCH counters; bit-identical greedy streams are
proven by tests/test_serve_sharded.py).

Guardrails (chaos-tested in tests/test_chaos.py): every jitted admit /
decode chunk also returns a per-slot **finite-ness flag** computed in-jit
(``isfinite`` over the slot's logits — one cheap reduction riding the
scan), so one NaN-poisoned slot can be quarantined by the scheduler
without touching the other slots' bit streams; a host-side **stall
watchdog** flags chunks slower than ``stall_timeout_s``; and
``fault_hook`` lets the fault-injection harness
(repro/testing/faults.py) poison a chosen slot's logits or delay a chosen
dispatch deterministically.  All guardrail events land in ``stats()``
(quarantines / requeues / timeouts / rejected / stalls /
nonfinite_chunks) so serving incidents are auditable after the fact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import Model, build_model
from repro.serve.paging import PageAllocator
from repro.serve.scheduler import Request, Response, SlotScheduler


def _sample_batch(logits: jax.Array, temps: jax.Array, rng: jax.Array,
                  idx) -> jax.Array:
    """Per-slot sampling: greedy where temps == 0, categorical at the
    slot's temperature otherwise — one batched op, so mixed batches cost
    nothing.  ``idx`` is the global step index folded into the key (the
    same fold schedule as the old per-token loop, for parity)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(rng, idx)
    samp = jax.random.categorical(
        key, logits.astype(jnp.float32) /
        jnp.maximum(temps, 1e-6)[:, None], axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)[:, None]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Dict
    max_batch: int
    max_seq: int
    decode_block: int = 8     # tokens decoded per device dispatch
    prompt_bucket: int = 16   # prefill length quantum (bounds recompiles)
    # ---- guardrails ------------------------------------------------------
    max_queue: Optional[int] = None   # admission-queue bound (None = ∞);
                                      # overflow -> finish_reason='rejected'
    max_slot_retries: int = 2         # re-queues per request after a
                                      # quarantine before 'error'
    stall_timeout_s: Optional[float] = None  # per-chunk stall watchdog
    # chaos hook: fault_hook(kind, dispatch_idx) -> None | dict with
    # optional 'poison' ((B,) bool slot mask -> NaN logits in-jit) and
    # 'delay_s' (host sleep inside the timed region).  Production: None.
    fault_hook: Optional[object] = None
    # ---- paged KV --------------------------------------------------------
    paged: Optional[bool] = None      # None = auto (attn-only archs)
    page_size: int = 16               # tokens per KV page
    n_pages: Optional[int] = None     # pool size incl. the sacrificial
                                      # page 0; None = dense-equivalent
    # ---- tensor parallelism ----------------------------------------------
    mesh: Optional[object] = None     # jax Mesh; dispatches trace under it
    profile: str = "baseline"         # sharding profile when mesh is set

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.is_encoder_decoder:
            raise ValueError("serve engine targets decoder-only LMs "
                             "(whisper serving needs a frames frontend)")
        self.supports_ragged = set(cfg.layer_kinds()) == {"attn"}
        if self.paged is None:
            self.paged = self.supports_ragged
        elif self.paged and not self.supports_ragged:
            raise ValueError("paged KV requires an attn-only architecture "
                             "(recurrent states are O(1) per slot already)")
        self._env = None
        if self.mesh is not None:
            from repro.distributed import sharding as _sh
            self._env = _sh.MeshEnv(self.mesh, self.profile)
        if self.paged:
            if self.n_pages is None:
                # dense-equivalent pool: every slot can hold max_seq rows
                self.n_pages = 1 + self.max_batch * \
                    (-(-self.max_seq // self.page_size))
            self.alloc = PageAllocator(self.n_pages, self.page_size,
                                       self.max_batch, self.max_seq)
            self._caches = self._init_paged_caches()
        else:
            self.alloc = None
            self._caches = self.model.init_caches(self.max_batch,
                                                  self.max_seq)
        self._admit_fn = jax.jit(self._admit_impl, donate_argnums=4)
        # decode chunks jit per (static) step count k: variable-k chunks
        # stop early when every live slot's budget is spent.  At most
        # decode_block entries ever exist.
        self._chunk_fns: Dict[int, object] = {}
        # the python-loop reference path keeps its own cached jits — fresh
        # wrappers per call would re-trace every invocation and poison the
        # scan-vs-loop benchmark's steady-state numbers
        self._loop_prefill = jax.jit(self.model.prefill)
        self._loop_decode = jax.jit(self.model.decode_step, donate_argnums=2)
        self._rng_step = 0
        self._no_poison = jnp.zeros((self.max_batch,), bool)
        self._stats = self._fresh_stats()
        self.events: List[dict] = []

    def _init_paged_caches(self) -> Dict:
        """Flat physical-row pools: each dense leaf (periods, B, S, ...)
        becomes (periods, R, ...) with R = n_pages × page_size shared
        across slots (page 0 is the sacrificial row set)."""
        rows = self.n_pages * self.page_size
        ab = self.model.abstract_caches(1, 1)
        return jax.tree.map(
            lambda l: jnp.zeros((l.shape[0], rows) + l.shape[3:], l.dtype),
            ab)

    def _ctx(self):
        """Trace/dispatch context: re-enters the engine's MeshEnv so every
        jit trace (and retrace) sees the TP mesh + profile."""
        if self._env is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as _sh
        return _sh.use_env(self._env)

    def _page_map(self):
        return jnp.asarray(self.alloc.page_map) if self.paged else None

    def _fresh_stats(self) -> Dict:
        return {"prefill_dispatches": 0, "decode_dispatches": 0,
                "decode_tokens": 0, "decode_steps": 0,
                "chunk_s": [], "chunk_k": [], "prefill_s": [],
                "quarantines": 0, "requeues": 0, "timeouts": 0,
                "rejected": 0, "stalls": 0, "nonfinite_chunks": 0,
                "errors": 0}

    def count(self, name: str, n: int = 1) -> None:
        """Guardrail event counter (scheduler + watchdog feed this)."""
        self._stats[name] = self._stats.get(name, 0) + n

    # ---- device functions -------------------------------------------------
    def _admit_impl(self, params, tokens, positions, admit_mask, caches,
                    temps, rng, idx, poison, page_map=None,
                    fresh_mask=None):
        """Batched left-padded prefill over the full slot dim.  Rows not
        being admitted run an all-pad dummy prompt (their writes park in
        the sacrificial slot/row) and — dense mode — their cache rows are
        masked back to the previous tenant's contents.  Paged mode needs
        no merge: page ownership isolates tenants, and the freshly claimed
        physical rows (``fresh_mask`` over the pool's row axis) are zeroed
        before the prefill so a recycled page never leaks the previous
        tenant's K/V.  Also returns a per-slot finite-ness flag over the
        sampled-from logits (``poison`` is the chaos-injection mask)."""
        if fresh_mask is not None:
            def wipe(c):
                m = fresh_mask.reshape((1, -1) + (1,) * (c.ndim - 2))
                return jnp.where(m, jnp.zeros_like(c), c)
            caches = jax.tree.map(wipe, caches)
        logits, new_caches = self.model.prefill(
            params, {"tokens": tokens}, caches, positions=positions,
            page_map=page_map)
        if page_map is None:
            def merge(n, o):
                # cache leaves are period-stacked: (periods, B, ...) — the
                # slot dim is axis 1, so the admit mask must broadcast over
                # axis 1 (masking axis 0 would mix periods across tenants)
                m = admit_mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)
            caches = jax.tree.map(merge, new_caches, caches)
        else:
            caches = new_caches
        last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
        ok = jnp.all(jnp.isfinite(last), axis=-1)
        tok = _sample_batch(last, temps, rng, idx)
        return tok, caches, ok

    def _chunk_impl(self, k, params, tok, pos, temps, caches, rng, base,
                    poison, page_map=None):
        """k decode steps in one dispatch (k static, ≤ decode_block — the
        variable-k policy jits one scan per distinct step count): the scan
        body is one model.decode_step (mode='infer') + batched sampling;
        the KV caches ride the carry and never leave the device
        (``page_map`` is loop-invariant, closed over).  A per-slot
        finite-ness flag (AND over the chunk's logits) rides out with the
        tokens; ``poison`` NaNs a chosen slot's logits for chaos tests."""
        def body(carry, i):
            tok, pos, caches, ok = carry
            logits, caches = self.model.decode_step(params, tok, caches,
                                                    pos[:, None],
                                                    page_map=page_map)
            last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
            ok = ok & jnp.all(jnp.isfinite(last), axis=-1)
            nxt = _sample_batch(last, temps, rng, base + i)
            pos = jnp.minimum(pos + 1, self.max_seq - 1)
            return (nxt, pos, caches, ok), nxt[:, 0]

        ok0 = jnp.ones((self.max_batch,), bool)
        (tok, pos, caches, ok), toks = jax.lax.scan(
            body, (tok, pos, caches, ok0), jnp.arange(k))
        return toks.T, tok, pos, caches, ok

    def _get_chunk_fn(self, k: int):
        fn = self._chunk_fns.get(k)
        if fn is None:
            fn = jax.jit(functools.partial(self._chunk_impl, k),
                         donate_argnums=4)
            self._chunk_fns[k] = fn
        return fn

    # ---- scheduler-facing API --------------------------------------------
    def _rng(self, rng) -> jax.Array:
        return jax.random.PRNGKey(0) if rng is None else rng

    def _fault(self, kind: str, idx: int) -> Tuple[jax.Array, float]:
        """Consult the chaos hook for this dispatch; returns the logits
        poison mask and a host delay (0 in production)."""
        if self.fault_hook is None:
            return self._no_poison, 0.0
        act = self.fault_hook(kind, idx) or {}
        poison = act.get("poison")
        poison = (self._no_poison if poison is None
                  else jnp.asarray(poison, bool))
        return poison, float(act.get("delay_s", 0.0))

    def _watch_stall(self, kind: str, idx: int, elapsed: float) -> None:
        if self.stall_timeout_s is not None and \
                elapsed > self.stall_timeout_s:
            self.count("stalls")
            self.events.append({"kind": "stall", "dispatch": kind,
                                "idx": idx, "elapsed_s": elapsed,
                                "timeout_s": self.stall_timeout_s})

    def admit(self, tokens: np.ndarray, positions: np.ndarray,
              admit_mask: np.ndarray, temps: np.ndarray,
              rng, budgets: Optional[np.ndarray] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (first token per slot, per-slot finite-ness flag).

        ``budgets``: per-slot token spans (prompt + generation budget) for
        the newly admitted rows — paged mode claims exactly that many
        pages per slot up front (the scheduler's ``prompt + max_new ≤
        max_seq - 1`` invariant bounds it) and zeroes them in-dispatch."""
        idx = self._stats["prefill_dispatches"]
        poison, delay_s = self._fault("prefill", idx)
        page_map = fresh = None
        if self.paged:
            if budgets is None:
                raise ValueError("paged engine: admit() needs per-slot "
                                 "token budgets")
            fresh_np = np.zeros((self.n_pages * self.page_size,), bool)
            for i in np.nonzero(np.asarray(admit_mask))[0]:
                self.alloc.release(int(i))  # idempotent (normally a no-op:
                # the scheduler releases on finish/quarantine)
                fresh_np[self.alloc.allocate(int(i), int(budgets[i]))] = True
            page_map, fresh = self._page_map(), jnp.asarray(fresh_np)
        t0 = time.perf_counter()
        with self._ctx():
            tok, self._caches, ok = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(admit_mask), self._caches, jnp.asarray(temps),
                self._rng(rng), self._rng_step, poison, page_map, fresh)
        tok, ok = np.asarray(tok), np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += 1
        self._stats["prefill_dispatches"] += 1
        self._stats["prefill_s"].append(elapsed)
        self._watch_stall("prefill", idx, elapsed)
        return tok[:, 0], ok

    def release_slot(self, slot: int) -> None:
        """Return a finished/quarantined slot's pages to the pool (no-op
        for the dense layout — the admit-mask merge recycles its rows)."""
        if self.paged:
            self.alloc.release(slot)

    def decode_chunk(self, cur_tok: np.ndarray, pos: np.ndarray,
                     temps: np.ndarray, rng,
                     remaining: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
        """Returns (chunk tokens (B, k), next token, next pos, per-slot
        finite-ness flag — False means the slot's logits went NaN/inf
        somewhere in the chunk and its tokens are garbage).

        ``remaining``: per-slot tokens still owed (0 for free/finished
        slots).  The chunk scans k = min(decode_block, max(remaining))
        steps, so a chunk whose live slots all finish early stops with
        them instead of burning the full block."""
        k = self.decode_block
        if remaining is not None:
            owed = int(np.max(remaining))
            if owed > 0:
                k = min(k, owed)
        idx = self._stats["decode_dispatches"]
        poison, delay_s = self._fault("decode", idx)
        t0 = time.perf_counter()
        with self._ctx():
            toks, tok, pos, self._caches, ok = self._get_chunk_fn(k)(
                self.params, jnp.asarray(cur_tok), jnp.asarray(pos),
                jnp.asarray(temps), self._caches, self._rng(rng),
                self._rng_step, poison, self._page_map())
        toks = np.asarray(toks)  # (B, k) — the one host sync per chunk
        ok = np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += k
        self._stats["decode_dispatches"] += 1
        self._stats["decode_steps"] += k
        self._stats["decode_tokens"] += toks.shape[0] * toks.shape[1]
        self._stats["chunk_s"].append(elapsed)
        self._stats["chunk_k"].append(k)
        self._watch_stall("decode", idx, elapsed)
        if not ok.all():
            self.count("nonfinite_chunks")
        # writable copies: the scheduler mutates these host mirrors in place
        return toks, np.array(tok), np.array(pos), ok

    def cache_hbm_bytes(self, *, peak: bool = True) -> Dict[str, int]:
        """Measured KV-cache HBM footprint: bytes per logical row summed
        over every (period-stacked) leaf, × rows held.  ``paged`` counts
        the rows actually backed by claimed pages (+ the sacrificial
        page); ``dense`` is the B × max_seq layout the paged pool
        replaces.  Benchmarks emit both (serve_sharded/* rows)."""
        ab = self.model.abstract_caches(1, 1)
        row_bytes = sum(
            l.shape[0] * int(np.prod(l.shape[3:], dtype=np.int64))
            * jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(ab))
        dense_rows = self.max_batch * self.max_seq
        out = {"row_bytes": int(row_bytes),
               "dense_bytes": int(row_bytes * dense_rows)}
        if self.paged:
            pages = (self.alloc.peak_pages if peak
                     else self.alloc.pages_in_use)
            out["paged_bytes"] = int(
                row_bytes * (pages + 1) * self.page_size)
            out["pool_bytes"] = int(
                row_bytes * self.n_pages * self.page_size)
        return out

    def stats(self) -> Dict:
        s = dict(self._stats)
        chunks = s.pop("chunk_s")
        ks = s.pop("chunk_k")
        pre = s.pop("prefill_s")
        # steady-state: the first chunk carries compile time
        steady = [t / kk for t, kk in zip(chunks, ks)]
        steady = steady[1:] or steady
        if chunks:
            s["per_token_p50_s"] = float(np.percentile(steady, 50))
            s["per_token_p95_s"] = float(np.percentile(steady, 95))
            s["decode_s"] = float(np.sum(chunks))
        if pre:
            s["prefill_s"] = float(np.sum(pre))
        if self.paged:
            s["pages_in_use"] = self.alloc.pages_in_use
            s["peak_pages"] = self.alloc.peak_pages
            s["page_size"] = self.page_size
        return s

    def reset_stats(self) -> None:
        self._rng_step = 0
        self._stats = self._fresh_stats()
        self.events = []

    # ---- request-level entry points --------------------------------------
    def serve(self, requests: List[Request], *,
              rng: Optional[jax.Array] = None) -> List[Response]:
        """Run a request list through the continuous-batching scheduler."""
        return SlotScheduler(self).run(requests, rng=rng)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, Dict]:
        """Equal-length batched generation (benchmark-harness compat):
        B prompts admitted together, decoded to completion through the
        scan engine.  Returns ((B, max_new_tokens) tokens, stats)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch
        assert p + max_new_tokens <= self.max_seq - 1
        self.reset_stats()
        reqs = [Request(uid=i, prompt=prompts[i],
                        max_new_tokens=max_new_tokens,
                        temperature=temperature) for i in range(b)]
        resps = self.serve(reqs, rng=rng)
        toks = np.stack([r.tokens for r in resps])
        stats = self.stats()
        dec_s = max(stats.get("decode_s", 0.0), 1e-9)
        stats["decode_tok_per_s"] = b * max_new_tokens / dec_s
        return toks, stats

    def generate_python_loop(self, prompts: np.ndarray,
                             max_new_tokens: int, temperature: float = 0.0,
                             rng: Optional[jax.Array] = None
                             ) -> Tuple[np.ndarray, Dict]:
        """The pre-refactor per-token Python loop: one device dispatch per
        decoded token over fresh caches.  Kept as the scan-vs-python-loop
        benchmark baseline and the greedy-parity oracle for the new
        engine (token streams must match bit for bit)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch and p + max_new_tokens <= self.max_seq
        caches = self.model.init_caches(b, self.max_seq)
        prefill, decode = self._loop_prefill, self._loop_decode
        key = self._rng(rng)
        temps = jnp.full((b,), temperature, jnp.float32)
        t0 = time.perf_counter()
        with self._ctx():
            logits, caches = prefill(self.params,
                                     {"tokens": jnp.asarray(prompts)},
                                     caches)
        t_prefill = time.perf_counter() - t0
        tok = _sample_batch(logits[:, -1], temps, key, 0)
        out = [tok]
        t1 = time.perf_counter()
        with self._ctx():
            for i in range(max_new_tokens - 1):
                pos = jnp.full((b, 1), p + i, jnp.int32)
                logits, caches = decode(self.params, tok, caches, pos)
                tok = _sample_batch(logits[:, -1], temps, key, i + 1)
                out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return tokens, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_dispatches": max_new_tokens - 1,
            "decode_tok_per_s": b * max_new_tokens / max(t_decode, 1e-9),
        }


def make_engine(cfg: ModelConfig, params: Optional[Dict] = None, *,
                max_batch: int = 8, max_seq: int = 256, seed: int = 0,
                decode_block: int = 8, mesh: Optional[object] = None,
                profile: str = "baseline", paged: Optional[bool] = None,
                page_size: int = 16,
                n_pages: Optional[int] = None) -> ServeEngine:
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    return ServeEngine(model, params, max_batch, max_seq,
                       decode_block=decode_block, mesh=mesh, profile=profile,
                       paged=paged, page_size=page_size, n_pages=n_pages)
