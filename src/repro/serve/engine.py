"""Serving engine: persistent slot caches + jitted **mixed dispatches**
(chunked prefill fused with the decode scan) advancing every slot per
device call.  Policy (admission order, EOS, slot recycling) lives in
serve/scheduler.py; this module owns the device state and the compiled
functions.

CoLA inference advantage (paper Table 11): the 2× smaller projections
halve both weight traffic and decode FLOPs.  The whole serving stack runs
``mode='infer'`` (model facade → linear_apply → cola_apply → the ops
planner): no residuals are saved anywhere, and each decode step's B×1
token batch lands below ``ops.DECODE_T_MAX`` so every CoLA site dispatches
the GEMV-shaped ``cola_ae_decode`` kernel — single launch, weights
streamed, z in VMEM — instead of the training-shaped token-tile grids
that are degenerate at T=1.

Chunked prefill / prefill-decode overlap (the default, ROADMAP item 1):
admission no longer fences the decode stream.  Each admitted prompt is
consumed in fixed ``prefill_chunk``-token slices, and every slice rides a
**mixed dispatch** (``mixed_chunk``): one jitted call in which prefilling
slots run their next left-padded prompt chunk at its true cache
positions while decoding slots advance k tokens through the same scan.
Non-participating rows run at position -1 — fully masked queries, K/V
parked in the sacrificial row (models/attention.py) — so the two phases
share one compiled function per static (c, k) without any masking logic
in the model.  Greedy streams are bit-identical to the non-overlapped
engine (``overlap=False``): chunked prefill writes the same cache bytes
as a monolithic one, per-token projections follow the same
T-independent decode plan (keep B·c ≤ ops.DECODE_T_MAX), and batch rows
are independent.  Chunks are left-padded so the final slice's newest
token always sits in the last column — one ``logits[:, -1]`` read
samples the first token exactly like the monolithic admit.  Pure-decode
rounds still go through ``decode_chunk``/``spec_chunk`` unchanged, and
recurrent archs auto-fall back to the admit-then-decode path (chunk
re-entry needs positional caches).  ``stats()['mixed_dispatches']`` /
``['prefill_chunks']`` count the fused calls and per-slot chunks;
``ttft_s``/``itl_s`` percentile samples (fed by the scheduler) surface
the latency this exists to fix.

Dispatch discipline: the old engine issued one device dispatch per token
(84-line Python loop).  Here ``decode_chunk`` is one jitted call that
scans up to ``decode_block`` decode steps on device; the per-token Python
loop survives only as ``generate_python_loop``, the parity/benchmark
reference.  ``stats()['decode_dispatches']`` counts the jitted calls so
tests can assert dispatches == ceil(tokens / k).  Chunks are
**variable-k**: the scheduler passes each live slot's remaining budget
and the chunk scans only ``min(decode_block, min(remaining over live
slots))`` steps — the smallest live budget bounds the chunk, so a
freshly admitted long request cannot inflate k past a nearly-done slot
(its overshoot tokens would be dropped by the scheduler — pure waste),
and ``stats()['decode_steps']`` counts the steps actually scanned
(equal-budget batches decode exactly ``max_new - 1`` steps, zero waste).

Speculative decoding (ROADMAP item 2): constructing the engine with a
``draft_plan`` (serve/draft.py) replaces plain chunks with **spec
rounds**: a truncated-rank/-depth self-draft — gather *views* into the
same A/B factors, zero extra weight HBM — greedily scans
``spec_window - 1`` draft tokens through the same decode GEMV path at
reduced r, then the full model scores all ``spec_window`` positions in
ONE dispatch (the decode kernel streams weights once per dispatch
regardless of the resident token count, so verifying k positions costs
barely more than decoding one).  The longest matching prefix of the
draft is accepted plus the full model's bonus/correction token; rejected
positions are rolled back by zeroing exactly the cache rows they wrote
(page-map-aware — the sacrificial row 0 is never touched), leaving the
paged KV byte-identical to a never-drafted run.  Every emitted token is
the full model's greedy argmax, so greedy streams are bit-identical to
plain decode *by construction*; acceptance only affects speed.  Spec
mode is greedy-only (the scheduler rejects temperature>0 requests), and
``stats()`` gains spec_drafted / spec_accepted / spec_rejected counters
plus the realized acceptance rate.

Paged KV (default for attn-only architectures): instead of dense
``(B, max_seq)`` slot caches, each cache leaf is a flat physical-row
pool ``(periods, R, ...)`` with ``R = n_pages × page_size``, shared
across slots through a free-list page allocator (serve/paging.py).
Pages are claimed at admission for the request's full token span and
released the moment the slot finishes — a finished long request frees
its rows immediately instead of holding ``max_seq`` of them until the
slot is recycled.  The (B, max_seq) ``page_map`` ships with every
dispatch; admission zeroes exactly the freshly claimed rows (recycled-
slot purity) and needs **no cache merge** — page ownership already
isolates tenants.  ``cache_hbm_bytes()`` reports paged-vs-dense
footprints for the benchmark rows.

Tensor-parallel serving: construct the engine with ``mesh=``/``profile=``
(baseline | megatron) and every jitted dispatch traces under that
``sharding.MeshEnv`` — each CoLA site then routes through
``ops.cola_ae_sharded(mode='infer')``, whose shard_map body runs the
per-shard decode kernels with the profile's collectives
(``sharded_infer_*`` DISPATCH counters; bit-identical greedy streams are
proven by tests/test_serve_sharded.py).

Guardrails (chaos-tested in tests/test_chaos.py): every jitted admit /
decode chunk also returns a per-slot **finite-ness flag** computed in-jit
(``isfinite`` over the slot's logits — one cheap reduction riding the
scan), so one NaN-poisoned slot can be quarantined by the scheduler
without touching the other slots' bit streams; a host-side **stall
watchdog** flags chunks slower than ``stall_timeout_s``; and
``fault_hook`` lets the fault-injection harness
(repro/testing/faults.py) poison a chosen slot's logits or delay a chosen
dispatch deterministically.  All guardrail events land in ``stats()``
(quarantines / requeues / timeouts / rejected / stalls /
nonfinite_chunks) so serving incidents are auditable after the fact.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.kernels.cola_ae import ops as cola_ops
from repro.models.model import Model, build_model
from repro.serve import draft as draft_mod
from repro.serve.paging import PageAllocator
from repro.serve.scheduler import Request, Response, SlotScheduler


def _sample_batch(logits: jax.Array, temps: jax.Array, rng: jax.Array,
                  idx) -> jax.Array:
    """Per-slot sampling: greedy where temps == 0, categorical at the
    slot's temperature otherwise — one batched op, so mixed batches cost
    nothing.  ``idx`` is the global step index folded into the key (the
    same fold schedule as the old per-token loop, for parity)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(rng, idx)
    samp = jax.random.categorical(
        key, logits.astype(jnp.float32) /
        jnp.maximum(temps, 1e-6)[:, None], axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)[:, None]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Dict
    max_batch: int
    max_seq: int
    decode_block: int = 8     # tokens decoded per device dispatch
    prompt_bucket: int = 16   # prefill length quantum (bounds recompiles;
                              # non-overlap admission path only)
    # ---- chunked prefill / overlap ---------------------------------------
    # overlap=True (the default, attn-only archs) dissolves the admit-then-
    # decode round structure into ONE phase-tagged mixed dispatch: slots in
    # the prefilling phase consume their next prefill_chunk prompt tokens
    # while slots in the decoding phase advance k tokens — an admission no
    # longer fences the decode stream for the whole prompt.  Greedy streams
    # are bit-identical to overlap=False (chunked prefill writes the same
    # cache bytes as a monolithic one; batch rows are independent).  The
    # fixed chunk width also collapses the per-bucket prefill recompile
    # family into one compiled shape per (chunk, k).
    prefill_chunk: Optional[int] = None  # prompt tokens per chunk
                                         # (None = prompt_bucket)
    overlap: bool = True      # auto-off for recurrent archs (chunk re-entry
                              # needs positional caches)
    # ---- guardrails ------------------------------------------------------
    max_queue: Optional[int] = None   # admission-queue bound (None = ∞);
                                      # overflow -> finish_reason='rejected'
    max_slot_retries: int = 2         # re-queues per request after a
                                      # quarantine before 'error'
    stall_timeout_s: Optional[float] = None  # per-chunk stall watchdog
    # chaos hook: fault_hook(kind, dispatch_idx) -> None | dict with
    # optional 'poison' ((B,) bool slot mask -> NaN logits in-jit) and
    # 'delay_s' (host sleep inside the timed region).  Production: None.
    fault_hook: Optional[object] = None
    # ---- paged KV --------------------------------------------------------
    paged: Optional[bool] = None      # None = auto (attn-only archs)
    page_size: int = 16               # tokens per KV page
    n_pages: Optional[int] = None     # pool size incl. the sacrificial
                                      # page 0; None = dense-equivalent
    # ---- tensor parallelism ----------------------------------------------
    mesh: Optional[object] = None     # jax Mesh; dispatches trace under it
    profile: str = "baseline"         # sharding profile when mesh is set
    # ---- speculative decoding --------------------------------------------
    # draft_plan (serve/draft.py) switches the engine into spec-decode
    # mode: a truncated-rank/-depth self-draft (views into the same
    # weights) greedily drafts spec_window-1 tokens per round and the full
    # model verifies all spec_window positions in ONE decode dispatch —
    # greedy streams stay bit-identical to plain decode by construction
    # (every emitted token is the full model's greedy argmax).
    draft_plan: Optional[object] = None
    spec_window: int = 4              # verified positions per spec round
    # ---- quantized weight streaming --------------------------------------
    # 'bf16' | 'int8' | 'int4' — informational tag set by make_engine after
    # it ran quantize_params: the CoLA A/B factors in ``params`` are then
    # QuantFactors and every decode dispatch streams q-blocks + scales
    # through the quantized kernel twins (quant_* DISPATCH counters; no
    # silent bf16 fallback).  KV caches are unaffected.
    weight_dtype: str = "bf16"

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.is_encoder_decoder:
            raise ValueError("serve engine targets decoder-only LMs "
                             "(whisper serving needs a frames frontend)")
        self.supports_ragged = set(cfg.layer_kinds()) == {"attn"}
        if self.prefill_chunk is None:
            self.prefill_chunk = self.prompt_bucket
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        # chunk re-entry replays positional K/V; recurrent states would
        # absorb the other phases' pad tokens — fall back to the
        # admit-then-decode engine there
        self.overlap = bool(self.overlap) and self.supports_ragged
        if self.paged is None:
            self.paged = self.supports_ragged
        elif self.paged and not self.supports_ragged:
            raise ValueError("paged KV requires an attn-only architecture "
                             "(recurrent states are O(1) per slot already)")
        self._env = None
        if self.mesh is not None:
            from repro.distributed import sharding as _sh
            self._env = _sh.MeshEnv(self.mesh, self.profile)
        if self.paged:
            if self.n_pages is None:
                # dense-equivalent pool: every slot can hold max_seq rows
                self.n_pages = 1 + self.max_batch * \
                    (-(-self.max_seq // self.page_size))
            self.alloc = PageAllocator(self.n_pages, self.page_size,
                                       self.max_batch, self.max_seq)
            self._caches = self._init_paged_caches()
        else:
            self.alloc = None
            self._caches = self.model.init_caches(self.max_batch,
                                                  self.max_seq)
        self._draft_caches = None
        if self.draft_plan is not None:
            if not self.supports_ragged:
                raise ValueError(
                    "speculative decoding needs an attn-only architecture "
                    "(rejection rollback = positional KV truncation; "
                    "recurrent states cannot roll back)")
            if self.spec_window < 1:
                raise ValueError("spec_window must be >= 1")
            if self.max_batch * self.spec_window > cola_ops.DECODE_T_MAX:
                raise ValueError(
                    f"max_batch × spec_window = "
                    f"{self.max_batch * self.spec_window} exceeds "
                    f"DECODE_T_MAX={cola_ops.DECODE_T_MAX}: the verify "
                    "window would fall off the decode-kernel plan "
                    "(shrink spec_window or max_batch)")
            # the draft's K/V differ from the full model's, so it owns its
            # own cache pool (kept-period leading axis) — weights stay
            # shared views, caches do not
            self._draft_caches = draft_mod.draft_caches(
                self._caches, self.draft_plan)
        self._admit_fn = jax.jit(self._admit_impl, donate_argnums=(4, 11))
        # mixed (chunked-prefill + decode) dispatches jit per static
        # (chunk width c, decode steps k); c is always prefill_chunk and
        # k ≤ decode_block (or spec_window), so the family is tiny
        self._mixed_fns: Dict[Tuple[int, int], object] = {}
        self._spec_fns: Dict[int, object] = {}
        # decode chunks jit per (static) step count k: variable-k chunks
        # stop early when every live slot's budget is spent.  At most
        # decode_block entries ever exist.
        self._chunk_fns: Dict[int, object] = {}
        # the python-loop reference path keeps its own cached jits — fresh
        # wrappers per call would re-trace every invocation and poison the
        # scan-vs-loop benchmark's steady-state numbers
        self._loop_prefill = jax.jit(self.model.prefill)
        self._loop_decode = jax.jit(self.model.decode_step, donate_argnums=2)
        self._rng_step = 0
        self._no_poison = jnp.zeros((self.max_batch,), bool)
        self._stats = self._fresh_stats()
        self.events: List[dict] = []

    @property
    def speculating(self) -> bool:
        return self.draft_plan is not None

    def _init_paged_caches(self) -> Dict:
        """Flat physical-row pools: each dense leaf (periods, B, S, ...)
        becomes (periods, R, ...) with R = n_pages × page_size shared
        across slots (page 0 is the sacrificial row set)."""
        rows = self.n_pages * self.page_size
        ab = self.model.abstract_caches(1, 1)
        return jax.tree.map(
            lambda l: jnp.zeros((l.shape[0], rows) + l.shape[3:], l.dtype),
            ab)

    def _ctx(self):
        """Trace/dispatch context: re-enters the engine's MeshEnv so every
        jit trace (and retrace) sees the TP mesh + profile."""
        if self._env is None:
            return contextlib.nullcontext()
        from repro.distributed import sharding as _sh
        return _sh.use_env(self._env)

    def _page_map(self):
        return jnp.asarray(self.alloc.page_map) if self.paged else None

    def _fresh_stats(self) -> Dict:
        return {"prefill_dispatches": 0, "decode_dispatches": 0,
                "decode_tokens": 0, "decode_steps": 0,
                # mixed_dispatches counts fused chunked-prefill dispatches
                # (a mixed dispatch with a decode component also bumps
                # decode_dispatches; one with a prefill component bumps
                # prefill_dispatches — the legacy counters keep their
                # "dispatch that advanced this phase" meaning);
                # prefill_chunks counts per-slot chunks consumed
                "mixed_dispatches": 0, "prefill_chunks": 0,
                # per-request latency samples (scheduler feeds these):
                # ttft_s = submit→first-token per request; itl_s = arrival
                # gap between consecutive tokens of one request (tokens in
                # the same dispatch share a timestamp, so the tail
                # percentiles surface exactly the inter-dispatch stalls)
                "ttft_s": [], "itl_s": [],
                "chunk_s": [], "chunk_k": [], "prefill_s": [],
                "quarantines": 0, "requeues": 0, "timeouts": 0,
                "rejected": 0, "stalls": 0, "nonfinite_chunks": 0,
                "errors": 0,
                # speculative decoding (0 unless a draft_plan is set):
                # drafted = draft proposals, accepted = proposals the full
                # model agreed with, rejected = drafted - accepted; the
                # per-round bonus token is the full model's own and counts
                # in decode_tokens only
                "spec_rounds": 0, "spec_drafted": 0, "spec_accepted": 0,
                "spec_rejected": 0, "spec_emitted": 0,
                "spec_slot_rounds": 0}

    def count(self, name: str, n: int = 1) -> None:
        """Guardrail event counter (scheduler + watchdog feed this)."""
        self._stats[name] = self._stats.get(name, 0) + n

    # ---- device functions -------------------------------------------------
    def _admit_impl(self, params, tokens, positions, admit_mask, caches,
                    temps, rng, idx, poison, page_map=None,
                    fresh_mask=None, dcaches=None):
        """Batched left-padded prefill over the full slot dim.  Rows not
        being admitted run an all-pad dummy prompt (their writes park in
        the sacrificial slot/row) and — dense mode — their cache rows are
        masked back to the previous tenant's contents.  Paged mode needs
        no merge: page ownership isolates tenants, and the freshly claimed
        physical rows (``fresh_mask`` over the pool's row axis) are zeroed
        before the prefill so a recycled page never leaks the previous
        tenant's K/V.  Also returns a per-slot finite-ness flag over the
        sampled-from logits (``poison`` is the chaos-injection mask).

        Spec-decode mode additionally prefills the self-draft's KV
        (``dcaches``) through the truncated parameter views — the draft
        needs the prompt's K/V under its own projections before it can
        scan; its logits are discarded (the first token is always the
        full model's)."""
        if fresh_mask is not None:
            def wipe(c):
                m = fresh_mask.reshape((1, -1) + (1,) * (c.ndim - 2))
                return jnp.where(m, jnp.zeros_like(c), c)
            caches = jax.tree.map(wipe, caches)
            if dcaches is not None:
                dcaches = jax.tree.map(wipe, dcaches)
        logits, new_caches = self.model.prefill(
            params, {"tokens": tokens}, caches, positions=positions,
            page_map=page_map)
        new_dcaches = dcaches
        if dcaches is not None:
            dp = draft_mod.draft_params(params, self.draft_plan)
            with cola_ops.dispatch_scope("draft_"):
                _, new_dcaches = self.model.prefill(
                    dp, {"tokens": tokens}, dcaches, positions=positions,
                    page_map=page_map)
        if page_map is None:
            def merge(n, o):
                # cache leaves are period-stacked: (periods, B, ...) — the
                # slot dim is axis 1, so the admit mask must broadcast over
                # axis 1 (masking axis 0 would mix periods across tenants)
                m = admit_mask.reshape((1, -1) + (1,) * (n.ndim - 2))
                return jnp.where(m, n, o)
            caches = jax.tree.map(merge, new_caches, caches)
            if dcaches is not None:
                new_dcaches = jax.tree.map(merge, new_dcaches, dcaches)
        else:
            caches = new_caches
        last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
        ok = jnp.all(jnp.isfinite(last), axis=-1)
        tok = _sample_batch(last, temps, rng, idx)
        return tok, caches, ok, new_dcaches

    def _chunk_impl(self, k, params, tok, pos, temps, caches, rng, base,
                    poison, page_map=None):
        """k decode steps in one dispatch (k static, ≤ decode_block — the
        variable-k policy jits one scan per distinct step count): the scan
        body is one model.decode_step (mode='infer') + batched sampling;
        the KV caches ride the carry and never leave the device
        (``page_map`` is loop-invariant, closed over).  A per-slot
        finite-ness flag (AND over the chunk's logits) rides out with the
        tokens; ``poison`` NaNs a chosen slot's logits for chaos tests."""
        def body(carry, i):
            tok, pos, caches, ok = carry
            logits, caches = self.model.decode_step(params, tok, caches,
                                                    pos[:, None],
                                                    page_map=page_map)
            last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
            ok = ok & jnp.all(jnp.isfinite(last), axis=-1)
            nxt = _sample_batch(last, temps, rng, base + i)
            pos = jnp.minimum(pos + 1, self.max_seq - 1)
            return (nxt, pos, caches, ok), nxt[:, 0]

        ok0 = jnp.ones((self.max_batch,), bool)
        (tok, pos, caches, ok), toks = jax.lax.scan(
            body, (tok, pos, caches, ok0), jnp.arange(k))
        return toks.T, tok, pos, caches, ok

    def _get_chunk_fn(self, k: int):
        fn = self._chunk_fns.get(k)
        if fn is None:
            fn = jax.jit(functools.partial(self._chunk_impl, k),
                         donate_argnums=4)
            self._chunk_fns[k] = fn
        return fn

    # ---- chunked prefill / mixed dispatch --------------------------------
    def _prefill_part(self, params, ptoks, ppos, caches, dcaches, page_map,
                      fresh_mask, temps, rng, base, poison):
        """The prefill half of a mixed dispatch: one (B, c) left-padded
        prompt chunk at its true cache positions.  Rows with no chunk this
        dispatch carry an all-pad slice (negative positions park their
        writes in the sacrificial row; no merge needed — attn-only archs
        only, so non-chunk rows' live cache rows are untouched).  Chunks
        are left-padded, so every row's newest token sits in the last
        column and one ``logits[:, -1]`` read samples the first token of
        any row whose prompt ends in this chunk (the host ignores it for
        mid-prompt rows).  Spec mode also prefills the draft KV through
        the truncated views, chunk by chunk — speculation composes with
        overlap."""
        if fresh_mask is not None:
            def wipe(c):
                m = fresh_mask.reshape((1, -1) + (1,) * (c.ndim - 2))
                return jnp.where(m, jnp.zeros_like(c), c)
            caches = jax.tree.map(wipe, caches)
            if dcaches is not None:
                dcaches = jax.tree.map(wipe, dcaches)
        logits, caches = self.model.prefill(
            params, {"tokens": ptoks}, caches, positions=ppos,
            page_map=page_map)
        if dcaches is not None:
            dp = draft_mod.draft_params(params, self.draft_plan)
            with cola_ops.dispatch_scope("draft_"):
                _, dcaches = self.model.prefill(
                    dp, {"tokens": ptoks}, dcaches, positions=ppos,
                    page_map=page_map)
        last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
        ok = jnp.all(jnp.isfinite(last), axis=-1)
        first = _sample_batch(last, temps, rng, base)
        return first, ok, caches, dcaches

    def _mixed_chunk_impl(self, c, k, params, ptoks, ppos, cur_tok, pos,
                          decode_mask, temps, caches, rng, base, poison,
                          page_map=None, fresh_mask=None, dcaches=None):
        """ONE fused mixed-phase dispatch (c, k static): prefilling slots
        consume their next c-token prompt chunk while decoding slots
        advance k tokens — admission no longer fences the decode stream.
        ``decode_mask`` tags the decoding rows; all other rows run the
        decode scan at position -1, so their queries are fully masked and
        their K/V writes park in the sacrificial row (their carry values
        pass through unchanged).  Decoding rows execute the exact per-row
        math of ``_chunk_impl`` — batch rows are independent, so their
        greedy streams are bit-identical to the non-overlapped engine."""
        B = self.max_batch
        first = jnp.zeros((B, 1), jnp.int32)
        ok_p = jnp.ones((B,), bool)
        if c:
            first, ok_p, caches, dcaches = self._prefill_part(
                params, ptoks, ppos, caches, dcaches, page_map, fresh_mask,
                temps, rng, base, poison)
        dbase = base + (1 if c else 0)

        def body(carry, i):
            tok, p, caches, ok = carry
            qpos = jnp.where(decode_mask, p, -1)
            logits, caches = self.model.decode_step(params, tok, caches,
                                                    qpos[:, None],
                                                    page_map=page_map)
            last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
            ok = ok & jnp.all(jnp.isfinite(last), axis=-1)
            nxt = _sample_batch(last, temps, rng, dbase + i)
            nxt = jnp.where(decode_mask[:, None], nxt, tok)
            p = jnp.where(decode_mask,
                          jnp.minimum(p + 1, self.max_seq - 1), p)
            return (nxt, p, caches, ok), nxt[:, 0]

        ok_d = jnp.ones((B,), bool)
        if k:
            (cur_tok, pos, caches, ok_d), toks = jax.lax.scan(
                body, (cur_tok, pos, caches, ok_d), jnp.arange(k))
            toks = toks.T
        else:
            toks = jnp.zeros((B, 0), jnp.int32)
        return first, ok_p, toks, cur_tok, pos, caches, dcaches, ok_d

    def _mixed_spec_impl(self, c, k, params, ptoks, ppos, cur_tok, pos,
                         decode_mask, temps, caches, dcaches, rng, base,
                         poison, page_map=None, fresh_mask=None):
        """Mixed dispatch, speculative flavour: the prefill half is
        identical to ``_mixed_chunk_impl`` (and also advances the draft
        KV), the decode half is one spec round restricted to
        ``decode_mask`` rows — masked rows draft/verify at position -1
        (parked writes), their rollback entries are forced non-stale, and
        their tok/pos carries pass through untouched, so a prefilling
        neighbour can never perturb a speculating slot's stream or the
        paged pool bytes."""
        B = self.max_batch
        first = jnp.zeros((B, 1), jnp.int32)
        ok_p = jnp.ones((B,), bool)
        if c:
            first, ok_p, caches, dcaches = self._prefill_part(
                params, ptoks, ppos, caches, dcaches, page_map, fresh_mask,
                temps, rng, base, poison)
        if not k:
            return (first, ok_p, jnp.zeros((B, 0), jnp.int32),
                    jnp.zeros((B,), jnp.int32), cur_tok, pos, caches,
                    dcaches, jnp.ones((B,), bool))
        dp = draft_mod.draft_params(params, self.draft_plan)

        with cola_ops.dispatch_scope("draft_"):
            def dbody(carry, _):
                t, p, dc = carry
                qpos = jnp.where(decode_mask, p, -1)
                lg, dc = self.model.decode_step(dp, t, dc, qpos[:, None],
                                                page_map=page_map)
                nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                nt = jnp.where(decode_mask[:, None], nt, t)
                p = jnp.where(decode_mask,
                              jnp.minimum(p + 1, self.max_seq - 1), p)
                return (nt, p, dc), nt[:, 0]
            (_, _, dcaches), drafts = jax.lax.scan(
                dbody, (cur_tok, pos, dcaches), jnp.arange(k - 1))
        drafts = drafts.T                                   # (B, k-1)

        window = jnp.concatenate([cur_tok, drafts], axis=1)  # (B, k)
        wpos = jnp.minimum(pos[:, None] + jnp.arange(k)[None, :],
                           self.max_seq - 1)
        # masked rows verify at -1: queries fully masked, writes parked
        wpos = jnp.where(decode_mask[:, None], wpos, -1)
        with cola_ops.dispatch_scope("verify_"):
            logits, caches = self.model.decode_step(
                params, window, caches, wpos, page_map=page_map)
        logits = jnp.where(poison[:, None, None], jnp.nan, logits)
        ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, k)

        match = jnp.concatenate(
            [drafts == targets[:, :k - 1],
             jnp.zeros((B, 1), bool)], axis=1)
        n_acc = jnp.argmin(match.astype(jnp.int32), axis=1)  # first False
        n_emit = n_acc + 1                                   # ∈ [1, k]
        new_tok = jnp.take_along_axis(targets, n_acc[:, None], axis=1)
        new_tok = jnp.where(decode_mask[:, None], new_tok, cur_tok)
        new_pos = jnp.where(decode_mask,
                            jnp.minimum(pos + n_emit, self.max_seq - 1),
                            pos)

        offs = jnp.arange(k)[None, :]
        stale = (offs >= n_emit[:, None]) & decode_mask[:, None]
        caches = self._zero_stale(caches, wpos, stale, page_map)
        if k > 1:  # draft wrote rows at window offsets 0..k-2 only
            dcaches = self._zero_stale(dcaches, wpos[:, :k - 1],
                                       stale[:, :k - 1], page_map)
        return (first, ok_p, targets, n_emit, new_tok, new_pos, caches,
                dcaches, ok)

    def _get_mixed_fn(self, c: int, k: int):
        fn = self._mixed_fns.get((c, k))
        if fn is None:
            if self.speculating:
                fn = jax.jit(functools.partial(self._mixed_spec_impl, c, k),
                             donate_argnums=(7, 8))
            else:
                fn = jax.jit(functools.partial(self._mixed_chunk_impl, c, k),
                             donate_argnums=(7, 13))
            self._mixed_fns[(c, k)] = fn
        return fn

    # ---- speculative decoding --------------------------------------------
    def _zero_stale(self, caches, wpos, stale, page_map):
        """Rollback: zero exactly the cache rows written for rejected
        window positions.  ``wpos`` (B, k) are the written logical
        positions, ``stale`` (B, k) marks the rejected ones.  Paged mode
        maps logical→physical through the page table and exempts the
        sacrificial row 0 (it absorbs unowned-position writes in plain
        decode too — zeroing it would *create* a byte difference); dense
        mode parks non-stale entries on the sacrificial last column and
        exempts it the same way.  After this, the cache bytes equal a
        never-drafted run's: accepted rows were computed from identical
        token history, rejected rows are zero exactly like the
        admission-time fresh wipe left them."""
        if page_map is not None:
            bidx = jnp.arange(self.max_batch)[:, None]
            rows = jnp.where(stale, page_map[bidx, wpos], 0)
            n_rows = self.n_pages * self.page_size
            keep = jnp.ones((n_rows,), bool).at[rows.reshape(-1)].set(False)
            keep = keep.at[0].set(True)

            def z(l):
                m = keep.reshape((1, -1) + (1,) * (l.ndim - 2))
                return jnp.where(m, l, jnp.zeros_like(l))
            return jax.tree.map(z, caches)
        bidx = jnp.arange(self.max_batch)[:, None]
        cols = jnp.where(stale, wpos, self.max_seq - 1)
        keep = jnp.ones((self.max_batch, self.max_seq), bool)
        keep = keep.at[bidx, cols].set(False)
        keep = keep.at[:, self.max_seq - 1].set(True)

        def z(l):
            m = keep.reshape((1,) + keep.shape + (1,) * (l.ndim - 3))
            return jnp.where(m, l, jnp.zeros_like(l))
        return jax.tree.map(z, caches)

    def _spec_chunk_impl(self, k, params, tok, pos, caches, dcaches,
                         poison, page_map=None):
        """One speculative round in one dispatch (k = spec_window,
        static):

        1. the self-draft (truncated parameter views, derived in-trace —
           zero persistent draft weights) greedily scans k-1 tokens
           through the decode GEMV path, writing its own KV,
        2. the full model scores all k window positions [t0, d1..d_{k-1}]
           in a single decode_step — the resident-token-tile decode
           kernel streams the weights once for the whole window,
        3. greedy accept: the longest prefix of drafts matching the full
           model's argmax targets is accepted, plus the bonus/correction
           token targets[n_acc] — so every emitted token is the full
           model's greedy choice and the stream is bit-identical to plain
           decode by construction,
        4. rollback: rows written for rejected positions are zeroed in
           both cache sets (_zero_stale), page-map-aware.

        Returns (targets (B,k), n_emit (B,), new token, new pos, caches,
        dcaches, per-slot finite-ness over the verify logits)."""
        B = self.max_batch
        dp = draft_mod.draft_params(params, self.draft_plan)

        with cola_ops.dispatch_scope("draft_"):
            def dbody(carry, _):
                t, p, dc = carry
                lg, dc = self.model.decode_step(dp, t, dc, p[:, None],
                                                page_map=page_map)
                nt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)[:, None]
                p = jnp.minimum(p + 1, self.max_seq - 1)
                return (nt, p, dc), nt[:, 0]
            (_, _, dcaches), drafts = jax.lax.scan(
                dbody, (tok, pos, dcaches), jnp.arange(k - 1))
        drafts = drafts.T                                   # (B, k-1)

        window = jnp.concatenate([tok, drafts], axis=1)     # (B, k)
        wpos = jnp.minimum(pos[:, None] + jnp.arange(k)[None, :],
                           self.max_seq - 1)
        with cola_ops.dispatch_scope("verify_"):
            logits, caches = self.model.decode_step(
                params, window, caches, wpos, page_map=page_map)
        logits = jnp.where(poison[:, None, None], jnp.nan, logits)
        ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
        targets = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, k)

        match = jnp.concatenate(
            [drafts == targets[:, :k - 1], jnp.zeros((B, 1), bool)], axis=1)
        n_acc = jnp.argmin(match.astype(jnp.int32), axis=1)  # first False
        n_emit = n_acc + 1                                   # ∈ [1, k]
        new_tok = jnp.take_along_axis(targets, n_acc[:, None], axis=1)
        new_pos = jnp.minimum(pos + n_emit, self.max_seq - 1)

        offs = jnp.arange(k)[None, :]
        stale = offs >= n_emit[:, None]                      # (B, k)
        caches = self._zero_stale(caches, wpos, stale, page_map)
        if k > 1:  # draft wrote rows at window offsets 0..k-2 only
            dcaches = self._zero_stale(dcaches, wpos[:, :k - 1],
                                       stale[:, :k - 1], page_map)
        return targets, n_emit, new_tok, new_pos, caches, dcaches, ok

    def _get_spec_fn(self, k: int):
        fn = self._spec_fns.get(k)
        if fn is None:
            fn = jax.jit(functools.partial(self._spec_chunk_impl, k),
                         donate_argnums=(3, 4))
            self._spec_fns[k] = fn
        return fn

    # ---- scheduler-facing API --------------------------------------------
    def _rng(self, rng) -> jax.Array:
        return jax.random.PRNGKey(0) if rng is None else rng

    def _fault(self, kind: str, idx: int) -> Tuple[jax.Array, float]:
        """Consult the chaos hook for this dispatch; returns the logits
        poison mask and a host delay (0 in production)."""
        if self.fault_hook is None:
            return self._no_poison, 0.0
        act = self.fault_hook(kind, idx) or {}
        poison = act.get("poison")
        poison = (self._no_poison if poison is None
                  else jnp.asarray(poison, bool))
        return poison, float(act.get("delay_s", 0.0))

    def _watch_stall(self, kind: str, idx: int, elapsed: float) -> None:
        if self.stall_timeout_s is not None and \
                elapsed > self.stall_timeout_s:
            self.count("stalls")
            self.events.append({"kind": "stall", "dispatch": kind,
                                "idx": idx, "elapsed_s": elapsed,
                                "timeout_s": self.stall_timeout_s})

    def admit(self, tokens: np.ndarray, positions: np.ndarray,
              admit_mask: np.ndarray, temps: np.ndarray,
              rng, budgets: Optional[np.ndarray] = None
              ) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (first token per slot, per-slot finite-ness flag).

        ``budgets``: per-slot token spans (prompt + generation budget) for
        the newly admitted rows — paged mode claims exactly that many
        pages per slot up front (the scheduler's ``prompt + max_new ≤
        max_seq - 1`` invariant bounds it) and zeroes them in-dispatch."""
        idx = self._stats["prefill_dispatches"]
        poison, delay_s = self._fault("prefill", idx)
        page_map = fresh = None
        if self.paged:
            if budgets is None:
                raise ValueError("paged engine: admit() needs per-slot "
                                 "token budgets")
            fresh_np = np.zeros((self.n_pages * self.page_size,), bool)
            for i in np.nonzero(np.asarray(admit_mask))[0]:
                self.alloc.release(int(i))  # idempotent (normally a no-op:
                # the scheduler releases on finish/quarantine)
                fresh_np[self.alloc.allocate(int(i), int(budgets[i]))] = True
            page_map, fresh = self._page_map(), jnp.asarray(fresh_np)
        t0 = time.perf_counter()
        with self._ctx():
            tok, self._caches, ok, self._draft_caches = self._admit_fn(
                self.params, jnp.asarray(tokens), jnp.asarray(positions),
                jnp.asarray(admit_mask), self._caches, jnp.asarray(temps),
                self._rng(rng), self._rng_step, poison, page_map, fresh,
                self._draft_caches)
        tok, ok = np.asarray(tok), np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += 1
        self._stats["prefill_dispatches"] += 1
        self._stats["prefill_s"].append(elapsed)
        self._watch_stall("prefill", idx, elapsed)
        return tok[:, 0], ok

    def release_slot(self, slot: int) -> None:
        """Return a finished/quarantined slot's pages to the pool (no-op
        for the dense layout — the admit-mask merge recycles its rows)."""
        if self.paged:
            self.alloc.release(slot)

    def decode_chunk(self, cur_tok: np.ndarray, pos: np.ndarray,
                     temps: np.ndarray, rng,
                     remaining: Optional[np.ndarray] = None
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
        """Returns (chunk tokens (B, k), next token, next pos, per-slot
        finite-ness flag — False means the slot's logits went NaN/inf
        somewhere in the chunk and its tokens are garbage).

        ``remaining``: per-slot tokens still owed (0 for free/finished
        slots).  The chunk scans ``k = min(decode_block, min(remaining
        over live slots))`` steps: the *smallest* live budget bounds the
        chunk, so one freshly admitted long request can no longer inflate
        k past a nearly-done slot's budget (tokens decoded past a slot's
        budget are dropped by the scheduler — pure waste, previously
        visible as decode_steps > Σ per-slot tokens).  A slot that
        finishes at the clamp boundary frees its slot for the next admit
        round instead of idling through the tail of a long chunk."""
        k = self.decode_block
        if remaining is not None:
            rem = np.asarray(remaining)
            live = rem > 0
            if live.any():
                k = min(k, int(rem[live].min()))
        idx = self._stats["decode_dispatches"]
        poison, delay_s = self._fault("decode", idx)
        t0 = time.perf_counter()
        with self._ctx():
            toks, tok, pos, self._caches, ok = self._get_chunk_fn(k)(
                self.params, jnp.asarray(cur_tok), jnp.asarray(pos),
                jnp.asarray(temps), self._caches, self._rng(rng),
                self._rng_step, poison, self._page_map())
        toks = np.asarray(toks)  # (B, k) — the one host sync per chunk
        ok = np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += k
        self._stats["decode_dispatches"] += 1
        self._stats["decode_steps"] += k
        self._stats["decode_tokens"] += toks.shape[0] * toks.shape[1]
        self._stats["chunk_s"].append(elapsed)
        self._stats["chunk_k"].append(k)
        self._watch_stall("decode", idx, elapsed)
        if not ok.all():
            self.count("nonfinite_chunks")
        # writable copies: the scheduler mutates these host mirrors in place
        return toks, np.array(tok), np.array(pos), ok

    def spec_chunk(self, cur_tok: np.ndarray, pos: np.ndarray,
                   temps: np.ndarray, rng,
                   remaining: Optional[np.ndarray] = None
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                              np.ndarray, np.ndarray]:
        """One speculative round (spec-decode counterpart of
        ``decode_chunk``).  Returns (window tokens (B, k), per-slot valid
        count n_emit (B,), next token, next pos, per-slot finite-ness
        flag).  Only ``toks[i, :n_emit[i]]`` are real output — every one
        of them is the full model's greedy argmax, so the consumed stream
        is bit-identical to plain decode.

        ``temps``/``rng`` are accepted for signature symmetry with
        ``decode_chunk`` but unused: speculative mode is greedy-only (the
        scheduler enforces temperature == 0).  The window is clamped by
        the smallest live budget exactly like decode_chunk's k — drafting
        past a slot's budget is pure waste."""
        k = self.spec_window
        if remaining is not None:
            rem = np.asarray(remaining)
            live = rem > 0
            if live.any():
                k = max(1, min(k, int(rem[live].min())))
        else:
            live = np.ones((self.max_batch,), bool)
        idx = self._stats["decode_dispatches"]
        poison, delay_s = self._fault("decode", idx)
        t0 = time.perf_counter()
        with self._ctx():
            (toks, n_emit, tok, new_pos, self._caches, self._draft_caches,
             ok) = self._get_spec_fn(k)(
                self.params, jnp.asarray(cur_tok), jnp.asarray(pos),
                self._caches, self._draft_caches, poison, self._page_map())
        toks = np.asarray(toks)      # (B, k) — the one host sync per round
        n_emit = np.asarray(n_emit)  # (B,)
        ok = np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        n_live = int(live.sum())
        emitted = int(n_emit[live].sum())
        self._stats["decode_dispatches"] += 1
        self._stats["decode_steps"] += k
        self._stats["decode_tokens"] += emitted
        self._stats["spec_rounds"] += 1
        self._stats["spec_slot_rounds"] += n_live
        drafted = n_live * (k - 1)
        accepted = int((n_emit[live] - 1).sum())
        self._stats["spec_drafted"] += drafted
        self._stats["spec_accepted"] += accepted
        self._stats["spec_rejected"] += drafted - accepted
        self._stats["spec_emitted"] += emitted
        self._stats["chunk_s"].append(elapsed)
        # per-token normalization uses *accepted* tokens per live slot —
        # the quantity the throughput table reports
        self._stats["chunk_k"].append(emitted / max(n_live, 1))
        self._watch_stall("decode", idx, elapsed)
        if not ok.all():
            self.count("nonfinite_chunks")
        # writable copies: the scheduler mutates these host mirrors in place
        return toks, n_emit, np.array(tok), np.array(new_pos), ok

    def mixed_chunk(self, ptoks: np.ndarray, ppos: np.ndarray,
                    cur_tok: np.ndarray, pos: np.ndarray,
                    decode_mask: np.ndarray, temps: np.ndarray, rng,
                    remaining: Optional[np.ndarray] = None,
                    admit_budgets: Optional[np.ndarray] = None
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        """One fused mixed-phase dispatch: prefilling slots consume the
        (B, c) left-padded prompt chunk ``ptoks``/``ppos`` (pad = -1)
        while ``decode_mask`` slots advance up to decode_block (or one
        spec round of up to spec_window) tokens.  The scheduler calls
        this only when at least one slot is prefilling — pure-decode
        rounds go through decode_chunk / spec_chunk unchanged.

        Returns (first_tok (B,), ok_prefill (B,), toks (B, k),
        n_valid (B,), next token, next pos, ok_decode (B,)).
        ``first_tok[i]`` is meaningful only for rows whose prompt ended
        in this chunk; only ``toks[i, :n_valid[i]]`` of decode rows are
        real output.

        ``admit_budgets``: per-slot token spans (> 0 exactly for slots
        admitted this dispatch) — paged mode claims their pages up front
        and fresh-wipes the claimed rows in-dispatch, exactly like
        ``admit``."""
        ptoks = np.asarray(ptoks, np.int32)
        c = int(ptoks.shape[1])
        ppos = np.asarray(ppos, np.int32)
        dec = np.asarray(decode_mask, bool)
        # rows carrying a real chunk this dispatch (newest column != pad)
        pre = ppos[:, -1] >= 0 if c else np.zeros((self.max_batch,), bool)
        k = 0
        if dec.any():
            k = self.spec_window if self.speculating else self.decode_block
            if remaining is not None:
                rem = np.asarray(remaining)
                live = dec & (rem > 0)
                if live.any():
                    k = max(1, min(k, int(rem[live].min())))
        # a mixed dispatch advances both phases: consult both chaos
        # tables and both watchdog identities so fault-injection keyed on
        # ("prefill"|"decode", idx) keeps firing under overlap
        pidx = self._stats["prefill_dispatches"]
        didx = self._stats["decode_dispatches"]
        poison, delay_s = self._no_poison, 0.0
        if c:
            pp, pd = self._fault("prefill", pidx)
            poison, delay_s = poison | pp, delay_s + pd
        if k:
            dp, dd = self._fault("decode", didx)
            poison, delay_s = poison | dp, delay_s + dd
        page_map = fresh = None
        if self.paged:
            # always ship a fresh mask (usually all-False) so the (c, k)
            # jit entry keeps one trace whether or not this chunk admits
            fresh_np = np.zeros((self.n_pages * self.page_size,), bool)
            if admit_budgets is not None:
                for i in np.nonzero(np.asarray(admit_budgets) > 0)[0]:
                    self.alloc.release(int(i))  # idempotent safety net
                    fresh_np[self.alloc.allocate(
                        int(i), int(admit_budgets[i]))] = True
            page_map, fresh = self._page_map(), jnp.asarray(fresh_np)
        t0 = time.perf_counter()
        with self._ctx():
            if self.speculating:
                (first, ok_p, toks, n_emit, tok, new_pos, self._caches,
                 self._draft_caches, ok_d) = self._get_mixed_fn(c, k)(
                    self.params, jnp.asarray(ptoks), jnp.asarray(ppos),
                    jnp.asarray(cur_tok), jnp.asarray(pos),
                    jnp.asarray(dec), jnp.asarray(temps), self._caches,
                    self._draft_caches, self._rng(rng), self._rng_step,
                    poison, page_map, fresh)
            else:
                (first, ok_p, toks, tok, new_pos, self._caches,
                 self._draft_caches, ok_d) = self._get_mixed_fn(c, k)(
                    self.params, jnp.asarray(ptoks), jnp.asarray(ppos),
                    jnp.asarray(cur_tok), jnp.asarray(pos),
                    jnp.asarray(dec), jnp.asarray(temps), self._caches,
                    self._rng(rng), self._rng_step, poison, page_map,
                    fresh, self._draft_caches)
                n_emit = np.full((self.max_batch,), k, np.int32)
        first = np.asarray(first)[:, 0]
        ok_p, ok_d = np.asarray(ok_p), np.asarray(ok_d)
        toks, n_emit = np.asarray(toks), np.asarray(n_emit)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._stats["mixed_dispatches"] += 1
        if c:
            # the prefill-part sample consumed one rng fold (greedy rows
            # are fold-independent; see _sample_batch)
            self._rng_step += 1
            self._stats["prefill_dispatches"] += 1
            self._stats["prefill_chunks"] += int(pre.sum())
            if not k:
                self._stats["prefill_s"].append(elapsed)
                self._watch_stall("prefill", pidx, elapsed)
        if k:
            self._stats["decode_dispatches"] += 1
            self._stats["decode_steps"] += k
            if self.speculating:
                n_live = int(dec.sum())
                emitted = int(n_emit[dec].sum())
                drafted = n_live * (k - 1)
                accepted = int((n_emit[dec] - 1).sum())
                self._stats["decode_tokens"] += emitted
                self._stats["spec_rounds"] += 1
                self._stats["spec_slot_rounds"] += n_live
                self._stats["spec_drafted"] += drafted
                self._stats["spec_accepted"] += accepted
                self._stats["spec_rejected"] += drafted - accepted
                self._stats["spec_emitted"] += emitted
                self._stats["chunk_k"].append(emitted / max(n_live, 1))
            else:
                self._rng_step += k
                self._stats["decode_tokens"] += toks.shape[0] * k
                self._stats["chunk_k"].append(k)
            self._stats["chunk_s"].append(elapsed)
            self._watch_stall("decode", didx, elapsed)
        if (k and not ok_d[dec].all()) or (c and not ok_p[pre].all()):
            self.count("nonfinite_chunks")
        # writable copies: the scheduler mutates these host mirrors in place
        return (first, ok_p, toks, n_emit, np.array(tok),
                np.array(new_pos), ok_d)

    def record_ttft(self, seconds: float) -> None:
        """Per-request time-to-first-token sample (scheduler feeds this
        the moment a request's first token is consumed)."""
        self._stats["ttft_s"].append(float(seconds))

    def record_itl(self, seconds: float) -> None:
        """Per-request inter-token arrival gap (tokens emitted by one
        dispatch share a timestamp — the tail percentiles are exactly the
        cross-dispatch stalls chunked prefill exists to shrink)."""
        self._stats["itl_s"].append(float(seconds))

    def cache_hbm_bytes(self, *, peak: bool = True) -> Dict[str, int]:
        """Measured KV-cache HBM footprint: bytes per logical row summed
        over every (period-stacked) leaf, × rows held.  ``paged`` counts
        the rows actually backed by claimed pages (+ the sacrificial
        page); ``dense`` is the B × max_seq layout the paged pool
        replaces.  Benchmarks emit both (serve_sharded/* rows).

        Weight quantization (``weight_dtype``) does NOT change these
        numbers: it shrinks the *streamed factor* bytes only
        (``kernel.decode_hbm_traffic(weight_bits=...)``) — KV rows keep
        the model's activation dtype."""
        ab = self.model.abstract_caches(1, 1)
        row_bytes = sum(
            l.shape[0] * int(np.prod(l.shape[3:], dtype=np.int64))
            * jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(ab))
        dense_rows = self.max_batch * self.max_seq
        out = {"row_bytes": int(row_bytes),
               "dense_bytes": int(row_bytes * dense_rows)}
        if self.paged:
            pages = (self.alloc.peak_pages if peak
                     else self.alloc.pages_in_use)
            out["paged_bytes"] = int(
                row_bytes * (pages + 1) * self.page_size)
            out["pool_bytes"] = int(
                row_bytes * self.n_pages * self.page_size)
        return out

    def stats(self) -> Dict:
        s = dict(self._stats)
        chunks = s.pop("chunk_s")
        ks = s.pop("chunk_k")
        pre = s.pop("prefill_s")
        ttft = s.pop("ttft_s")
        itl = s.pop("itl_s")
        # per-REQUEST latency (the serving SLO view, distinct from the
        # per-dispatch wall times below): TTFT includes queue wait +
        # (possibly chunked) prefill; ITL gaps include every stall a
        # request's stream experienced — admission fences, spec rounds,
        # page waits — not just its own decode chunks
        if ttft:
            for p in (50, 95, 99):
                s[f"ttft_p{p}_s"] = float(np.percentile(ttft, p))
        if itl:
            for p in (50, 95, 99):
                s[f"itl_p{p}_s"] = float(np.percentile(itl, p))
        # steady-state: the first chunk carries compile time
        steady = [t / kk for t, kk in zip(chunks, ks)]
        steady = steady[1:] or steady
        if chunks:
            s["per_token_p50_s"] = float(np.percentile(steady, 50))
            s["per_token_p95_s"] = float(np.percentile(steady, 95))
            s["decode_s"] = float(np.sum(chunks))
        if pre:
            s["prefill_s"] = float(np.sum(pre))
        if self.paged:
            s["pages_in_use"] = self.alloc.pages_in_use
            s["peak_pages"] = self.alloc.peak_pages
            s["page_size"] = self.page_size
        if self.speculating:
            s["spec_acceptance_rate"] = (
                s["spec_accepted"] / max(s["spec_drafted"], 1))
            s["spec_mean_emitted"] = (
                s["spec_emitted"] / max(s["spec_slot_rounds"], 1))
        return s

    def reset_stats(self) -> None:
        self._rng_step = 0
        self._stats = self._fresh_stats()
        self.events = []

    # ---- request-level entry points --------------------------------------
    def serve(self, requests: List[Request], *,
              rng: Optional[jax.Array] = None) -> List[Response]:
        """Run a request list through the continuous-batching scheduler."""
        return SlotScheduler(self).run(requests, rng=rng)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, Dict]:
        """Equal-length batched generation (benchmark-harness compat):
        B prompts admitted together, decoded to completion through the
        scan engine.  Returns ((B, max_new_tokens) tokens, stats)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch
        assert p + max_new_tokens <= self.max_seq - 1
        self.reset_stats()
        reqs = [Request(uid=i, prompt=prompts[i],
                        max_new_tokens=max_new_tokens,
                        temperature=temperature) for i in range(b)]
        resps = self.serve(reqs, rng=rng)
        toks = np.stack([r.tokens for r in resps])
        stats = self.stats()
        dec_s = max(stats.get("decode_s", 0.0), 1e-9)
        stats["decode_tok_per_s"] = b * max_new_tokens / dec_s
        return toks, stats

    def generate_python_loop(self, prompts: np.ndarray,
                             max_new_tokens: int, temperature: float = 0.0,
                             rng: Optional[jax.Array] = None
                             ) -> Tuple[np.ndarray, Dict]:
        """The pre-refactor per-token Python loop: one device dispatch per
        decoded token over fresh caches.  Kept as the scan-vs-python-loop
        benchmark baseline and the greedy-parity oracle for the new
        engine (token streams must match bit for bit)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch and p + max_new_tokens <= self.max_seq
        caches = self.model.init_caches(b, self.max_seq)
        prefill, decode = self._loop_prefill, self._loop_decode
        key = self._rng(rng)
        temps = jnp.full((b,), temperature, jnp.float32)
        t0 = time.perf_counter()
        with self._ctx():
            logits, caches = prefill(self.params,
                                     {"tokens": jnp.asarray(prompts)},
                                     caches)
        t_prefill = time.perf_counter() - t0
        tok = _sample_batch(logits[:, -1], temps, key, 0)
        out = [tok]
        t1 = time.perf_counter()
        with self._ctx():
            for i in range(max_new_tokens - 1):
                pos = jnp.full((b, 1), p + i, jnp.int32)
                logits, caches = decode(self.params, tok, caches, pos)
                tok = _sample_batch(logits[:, -1], temps, key, i + 1)
                out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return tokens, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_dispatches": max_new_tokens - 1,
            "decode_tok_per_s": b * max_new_tokens / max(t_decode, 1e-9),
        }


def make_engine(cfg: ModelConfig, params: Optional[Dict] = None, *,
                max_batch: int = 8, max_seq: int = 256, seed: int = 0,
                decode_block: int = 8,
                prefill_chunk: Optional[int] = None,
                overlap: bool = True,
                mesh: Optional[object] = None,
                profile: str = "baseline", paged: Optional[bool] = None,
                page_size: int = 16, n_pages: Optional[int] = None,
                speculate: bool = False,
                draft_alpha: Optional[float] = None,
                draft_depth: Optional[int] = None,
                draft_depth_mode: str = "stride",
                spec_window: int = 4,
                weight_dtype: str = "bf16") -> ServeEngine:
    if weight_dtype not in ("bf16", "int8", "int4"):
        raise ValueError(f"weight_dtype must be bf16|int8|int4, "
                         f"got {weight_dtype!r}")
    if weight_dtype != "bf16":
        # quantized factors only exist on the fused kernel path (the
        # unfused einsum fallback cannot consume QuantFactors) — force it
        # on before the model facade is built
        cfg = cfg.with_overrides(
            cola=dataclasses.replace(cfg.cola, use_fused_kernel=True))
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    if weight_dtype != "bf16":
        # quantize ONCE, globally, at engine build: under TP the q/scale
        # *arrays* are then sharded (scale layouts commute with the
        # sharding), keeping sharded streams bit-identical to the
        # single-device quantized engine — per-shard re-quantization
        # would pick different scales at rank-sharded sites
        from repro.kernels.cola_ae import quant as _quant
        params = _quant.quantize_params(params,
                                        bits=int(weight_dtype[3:]))
    plan = None
    if speculate:
        if draft_alpha is None and draft_depth is None:
            draft_alpha = 0.95  # rank-energy default (paper Eq. (1) level)
        # planned on the (possibly quantized) factors the engine will
        # serve: the rank ordering is computed from the dequantized
        # values, so a reference engine built on dequantize(params)
        # resolves the identical plan
        plan = draft_mod.plan_draft(params, alpha=draft_alpha,
                                    depth=draft_depth,
                                    depth_mode=draft_depth_mode)
    return ServeEngine(model, params, max_batch, max_seq,
                       decode_block=decode_block,
                       prefill_chunk=prefill_chunk, overlap=overlap,
                       mesh=mesh, profile=profile,
                       paged=paged, page_size=page_size, n_pages=n_pages,
                       draft_plan=plan, spec_window=spec_window,
                       weight_dtype=weight_dtype)
