"""Serving engine: persistent slot caches + jitted admission prefill +
a jitted ``lax.scan`` decode loop advancing every slot k tokens per device
dispatch.  Policy (admission order, EOS, slot recycling) lives in
serve/scheduler.py; this module owns the device state and the compiled
functions.

CoLA inference advantage (paper Table 11): the 2× smaller projections
halve both weight traffic and decode FLOPs.  The whole serving stack runs
``mode='infer'`` (model facade → linear_apply → cola_apply → the ops
planner): no residuals are saved anywhere, and each decode step's B×1
token batch lands below ``ops.DECODE_T_MAX`` so every CoLA site dispatches
the GEMV-shaped ``cola_ae_decode`` kernel — single launch, weights
streamed, z in VMEM — instead of the training-shaped token-tile grids
that are degenerate at T=1.

Dispatch discipline: the old engine issued one device dispatch per token
(84-line Python loop).  Here ``decode_chunk`` is one jitted call that
scans ``decode_block`` decode steps on device; the per-token Python loop
survives only as ``generate_python_loop``, the parity/benchmark
reference.  ``stats()['decode_dispatches']`` counts the jitted calls so
tests can assert dispatches == ceil(tokens / k).

Guardrails (chaos-tested in tests/test_chaos.py): every jitted admit /
decode chunk also returns a per-slot **finite-ness flag** computed in-jit
(``isfinite`` over the slot's logits — one cheap reduction riding the
scan), so one NaN-poisoned slot can be quarantined by the scheduler
without touching the other slots' bit streams; a host-side **stall
watchdog** flags chunks slower than ``stall_timeout_s``; and
``fault_hook`` lets the fault-injection harness
(repro/testing/faults.py) poison a chosen slot's logits or delay a chosen
dispatch deterministically.  All guardrail events land in ``stats()``
(quarantines / requeues / timeouts / rejected / stalls /
nonfinite_chunks) so serving incidents are auditable after the fact.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import Model, build_model
from repro.serve.scheduler import Request, Response, SlotScheduler


def _sample_batch(logits: jax.Array, temps: jax.Array, rng: jax.Array,
                  idx) -> jax.Array:
    """Per-slot sampling: greedy where temps == 0, categorical at the
    slot's temperature otherwise — one batched op, so mixed batches cost
    nothing.  ``idx`` is the global step index folded into the key (the
    same fold schedule as the old per-token loop, for parity)."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    key = jax.random.fold_in(rng, idx)
    samp = jax.random.categorical(
        key, logits.astype(jnp.float32) /
        jnp.maximum(temps, 1e-6)[:, None], axis=-1).astype(jnp.int32)
    return jnp.where(temps > 0, samp, greedy)[:, None]


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Dict
    max_batch: int
    max_seq: int
    decode_block: int = 8     # tokens decoded per device dispatch
    prompt_bucket: int = 16   # prefill length quantum (bounds recompiles)
    # ---- guardrails ------------------------------------------------------
    max_queue: Optional[int] = None   # admission-queue bound (None = ∞);
                                      # overflow -> finish_reason='rejected'
    max_slot_retries: int = 2         # re-queues per request after a
                                      # quarantine before 'error'
    stall_timeout_s: Optional[float] = None  # per-chunk stall watchdog
    # chaos hook: fault_hook(kind, dispatch_idx) -> None | dict with
    # optional 'poison' ((B,) bool slot mask -> NaN logits in-jit) and
    # 'delay_s' (host sleep inside the timed region).  Production: None.
    fault_hook: Optional[object] = None

    def __post_init__(self):
        cfg = self.model.cfg
        if cfg.is_encoder_decoder:
            raise ValueError("serve engine targets decoder-only LMs "
                             "(whisper serving needs a frames frontend)")
        self.supports_ragged = set(cfg.layer_kinds()) == {"attn"}
        self._caches = self.model.init_caches(self.max_batch, self.max_seq)
        self._admit_fn = jax.jit(self._admit_impl, donate_argnums=4)
        self._chunk_fn = jax.jit(self._chunk_impl, donate_argnums=4)
        # the python-loop reference path keeps its own cached jits — fresh
        # wrappers per call would re-trace every invocation and poison the
        # scan-vs-loop benchmark's steady-state numbers
        self._loop_prefill = jax.jit(self.model.prefill)
        self._loop_decode = jax.jit(self.model.decode_step, donate_argnums=2)
        self._rng_step = 0
        self._no_poison = jnp.zeros((self.max_batch,), bool)
        self._stats = self._fresh_stats()
        self.events: List[dict] = []

    def _fresh_stats(self) -> Dict:
        return {"prefill_dispatches": 0, "decode_dispatches": 0,
                "decode_tokens": 0, "chunk_s": [], "prefill_s": [],
                "quarantines": 0, "requeues": 0, "timeouts": 0,
                "rejected": 0, "stalls": 0, "nonfinite_chunks": 0,
                "errors": 0}

    def count(self, name: str, n: int = 1) -> None:
        """Guardrail event counter (scheduler + watchdog feed this)."""
        self._stats[name] = self._stats.get(name, 0) + n

    # ---- device functions -------------------------------------------------
    def _admit_impl(self, params, tokens, positions, admit_mask, caches,
                    temps, rng, idx, poison):
        """Batched left-padded prefill over the full slot dim.  Rows not
        being admitted run an all-pad dummy prompt (their writes park in
        the sacrificial slot) and their cache rows are masked back to the
        previous tenant's contents — in-flight requests are untouched.
        Also returns a per-slot finite-ness flag over the sampled-from
        logits (``poison`` is the chaos-injection mask)."""
        logits, new_caches = self.model.prefill(
            params, {"tokens": tokens}, caches, positions=positions)

        def merge(n, o):
            # cache leaves are period-stacked: (periods, B, ...) — the slot
            # dim is axis 1, so the admit mask must broadcast over axis 1
            # (masking axis 0 would mix periods across tenants)
            m = admit_mask.reshape((1, -1) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)

        caches = jax.tree.map(merge, new_caches, caches)
        last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
        ok = jnp.all(jnp.isfinite(last), axis=-1)
        tok = _sample_batch(last, temps, rng, idx)
        return tok, caches, ok

    def _chunk_impl(self, params, tok, pos, temps, caches, rng, base,
                    poison):
        """k = decode_block decode steps in one dispatch: the scan body is
        one model.decode_step (mode='infer') + batched sampling; the KV
        caches ride the carry and never leave the device.  A per-slot
        finite-ness flag (AND over the chunk's logits) rides out with the
        tokens; ``poison`` NaNs a chosen slot's logits for chaos tests."""
        def body(carry, i):
            tok, pos, caches, ok = carry
            logits, caches = self.model.decode_step(params, tok, caches,
                                                    pos[:, None])
            last = jnp.where(poison[:, None], jnp.nan, logits[:, -1])
            ok = ok & jnp.all(jnp.isfinite(last), axis=-1)
            nxt = _sample_batch(last, temps, rng, base + i)
            pos = jnp.minimum(pos + 1, self.max_seq - 1)
            return (nxt, pos, caches, ok), nxt[:, 0]

        ok0 = jnp.ones((self.max_batch,), bool)
        (tok, pos, caches, ok), toks = jax.lax.scan(
            body, (tok, pos, caches, ok0), jnp.arange(self.decode_block))
        return toks.T, tok, pos, caches, ok

    # ---- scheduler-facing API --------------------------------------------
    def _rng(self, rng) -> jax.Array:
        return jax.random.PRNGKey(0) if rng is None else rng

    def _fault(self, kind: str, idx: int) -> Tuple[jax.Array, float]:
        """Consult the chaos hook for this dispatch; returns the logits
        poison mask and a host delay (0 in production)."""
        if self.fault_hook is None:
            return self._no_poison, 0.0
        act = self.fault_hook(kind, idx) or {}
        poison = act.get("poison")
        poison = (self._no_poison if poison is None
                  else jnp.asarray(poison, bool))
        return poison, float(act.get("delay_s", 0.0))

    def _watch_stall(self, kind: str, idx: int, elapsed: float) -> None:
        if self.stall_timeout_s is not None and \
                elapsed > self.stall_timeout_s:
            self.count("stalls")
            self.events.append({"kind": "stall", "dispatch": kind,
                                "idx": idx, "elapsed_s": elapsed,
                                "timeout_s": self.stall_timeout_s})

    def admit(self, tokens: np.ndarray, positions: np.ndarray,
              admit_mask: np.ndarray, temps: np.ndarray,
              rng) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (first token per slot, per-slot finite-ness flag)."""
        idx = self._stats["prefill_dispatches"]
        poison, delay_s = self._fault("prefill", idx)
        t0 = time.perf_counter()
        tok, self._caches, ok = self._admit_fn(
            self.params, jnp.asarray(tokens), jnp.asarray(positions),
            jnp.asarray(admit_mask), self._caches, jnp.asarray(temps),
            self._rng(rng), self._rng_step, poison)
        tok, ok = np.asarray(tok), np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += 1
        self._stats["prefill_dispatches"] += 1
        self._stats["prefill_s"].append(elapsed)
        self._watch_stall("prefill", idx, elapsed)
        return tok[:, 0], ok

    def decode_chunk(self, cur_tok: np.ndarray, pos: np.ndarray,
                     temps: np.ndarray, rng
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray,
                                np.ndarray]:
        """Returns (chunk tokens (B, k), next token, next pos, per-slot
        finite-ness flag — False means the slot's logits went NaN/inf
        somewhere in the chunk and its tokens are garbage)."""
        idx = self._stats["decode_dispatches"]
        poison, delay_s = self._fault("decode", idx)
        t0 = time.perf_counter()
        toks, tok, pos, self._caches, ok = self._chunk_fn(
            self.params, jnp.asarray(cur_tok), jnp.asarray(pos),
            jnp.asarray(temps), self._caches, self._rng(rng),
            self._rng_step, poison)
        toks = np.asarray(toks)  # (B, k) — the one host sync per chunk
        ok = np.asarray(ok)
        if delay_s:
            time.sleep(delay_s)  # simulated device stall (chaos)
        elapsed = time.perf_counter() - t0
        self._rng_step += self.decode_block
        self._stats["decode_dispatches"] += 1
        self._stats["decode_tokens"] += toks.shape[0] * toks.shape[1]
        self._stats["chunk_s"].append(elapsed)
        self._watch_stall("decode", idx, elapsed)
        if not ok.all():
            self.count("nonfinite_chunks")
        # writable copies: the scheduler mutates these host mirrors in place
        return toks, np.array(tok), np.array(pos), ok

    def stats(self) -> Dict:
        s = dict(self._stats)
        chunks = s.pop("chunk_s")
        pre = s.pop("prefill_s")
        k = self.decode_block
        # steady-state: the first chunk carries compile time
        steady = chunks[1:] or chunks
        if chunks:
            s["per_token_p50_s"] = float(np.percentile(steady, 50)) / k
            s["per_token_p95_s"] = float(np.percentile(steady, 95)) / k
            s["decode_s"] = float(np.sum(chunks))
        if pre:
            s["prefill_s"] = float(np.sum(pre))
        return s

    def reset_stats(self) -> None:
        self._rng_step = 0
        self._stats = self._fresh_stats()
        self.events = []

    # ---- request-level entry points --------------------------------------
    def serve(self, requests: List[Request], *,
              rng: Optional[jax.Array] = None) -> List[Response]:
        """Run a request list through the continuous-batching scheduler."""
        return SlotScheduler(self).run(requests, rng=rng)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, Dict]:
        """Equal-length batched generation (benchmark-harness compat):
        B prompts admitted together, decoded to completion through the
        scan engine.  Returns ((B, max_new_tokens) tokens, stats)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch
        assert p + max_new_tokens <= self.max_seq - 1
        self.reset_stats()
        reqs = [Request(uid=i, prompt=prompts[i],
                        max_new_tokens=max_new_tokens,
                        temperature=temperature) for i in range(b)]
        resps = self.serve(reqs, rng=rng)
        toks = np.stack([r.tokens for r in resps])
        stats = self.stats()
        dec_s = max(stats.get("decode_s", 0.0), 1e-9)
        stats["decode_tok_per_s"] = b * max_new_tokens / dec_s
        return toks, stats

    def generate_python_loop(self, prompts: np.ndarray,
                             max_new_tokens: int, temperature: float = 0.0,
                             rng: Optional[jax.Array] = None
                             ) -> Tuple[np.ndarray, Dict]:
        """The pre-refactor per-token Python loop: one device dispatch per
        decoded token over fresh caches.  Kept as the scan-vs-python-loop
        benchmark baseline and the greedy-parity oracle for the new
        engine (token streams must match bit for bit)."""
        prompts = np.asarray(prompts, np.int32)
        b, p = prompts.shape
        assert b <= self.max_batch and p + max_new_tokens <= self.max_seq
        caches = self.model.init_caches(b, self.max_seq)
        prefill, decode = self._loop_prefill, self._loop_decode
        key = self._rng(rng)
        temps = jnp.full((b,), temperature, jnp.float32)
        t0 = time.perf_counter()
        logits, caches = prefill(self.params,
                                 {"tokens": jnp.asarray(prompts)}, caches)
        t_prefill = time.perf_counter() - t0
        tok = _sample_batch(logits[:, -1], temps, key, 0)
        out = [tok]
        t1 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            pos = jnp.full((b, 1), p + i, jnp.int32)
            logits, caches = decode(self.params, tok, caches, pos)
            tok = _sample_batch(logits[:, -1], temps, key, i + 1)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        return tokens, {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_dispatches": max_new_tokens - 1,
            "decode_tok_per_s": b * max_new_tokens / max(t_decode, 1e-9),
        }


def make_engine(cfg: ModelConfig, params: Optional[Dict] = None, *,
                max_batch: int = 8, max_seq: int = 256, seed: int = 0,
                decode_block: int = 8) -> ServeEngine:
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    return ServeEngine(model, params, max_batch, max_seq,
                       decode_block=decode_block)
