"""Batched serving engine: prefill + greedy/temperature decode over the
model facade's KV caches (contiguous per-layer caches; SSM/RWKV archs carry
O(1) recurrent state instead).

CoLA inference advantage (paper Table 11): the 2× smaller projections halve
both weight traffic and decode FLOPs; the engine is the harness the
inference benchmark drives.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class ServeEngine:
    model: Model
    params: Dict
    max_batch: int
    max_seq: int

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step, donate_argnums=2)

    # -----------------------------------------------------------------
    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 temperature: float = 0.0, rng: Optional[jax.Array] = None
                 ) -> Tuple[np.ndarray, Dict]:
        """prompts: (B, P) int32 (right-aligned, no padding support needed
        for the benchmark harness — equal-length prompts)."""
        b, p = prompts.shape
        assert b <= self.max_batch and p + max_new_tokens <= self.max_seq
        caches = self.model.init_caches(b, self.max_seq)
        t0 = time.perf_counter()
        batch = {"tokens": jnp.asarray(prompts, jnp.int32)}
        logits, caches = self._prefill(self.params, batch, caches)
        t_prefill = time.perf_counter() - t0

        tok = self._sample(logits[:, -1], temperature, rng, 0)
        # Accumulate generated tokens on device: np.asarray(tok) inside the
        # loop would force a host sync per step, serializing dispatch.
        out = [tok]
        t1 = time.perf_counter()
        for i in range(max_new_tokens - 1):
            pos = jnp.full((b, 1), p + i, jnp.int32)
            logits, caches = self._decode(self.params, tok, caches, pos)
            tok = self._sample(logits[:, -1], temperature, rng, i + 1)
            out.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        tokens = np.asarray(jnp.concatenate(out, axis=1))
        stats = {
            "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_per_s": b * max_new_tokens / max(t_decode, 1e-9),
        }
        return tokens, stats

    def _sample(self, logits: jax.Array, temperature: float,
                rng: Optional[jax.Array], i: int) -> jax.Array:
        if temperature <= 0.0 or rng is None:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        k = jax.random.fold_in(rng, i)
        return jax.random.categorical(
            k, logits.astype(jnp.float32) / temperature, axis=-1
        ).astype(jnp.int32)[:, None]


def make_engine(cfg: ModelConfig, params: Optional[Dict] = None, *,
                max_batch: int = 8, max_seq: int = 256,
                seed: int = 0) -> ServeEngine:
    model = build_model(cfg)
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
    return ServeEngine(model, params, max_batch, max_seq)
